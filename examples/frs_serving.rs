//! End-to-end driver (DESIGN.md §6): loads the AOT-compiled HLO stages
//! produced by `make artifacts`, serves batched requests through the
//! coordinator on the PJRT CPU client, verifies every response against
//! the JAX-side numerics probe, and reports latency/throughput — proving
//! all three layers (Pallas kernels → JAX model → Rust runtime) compose
//! with Python off the request path.
//!
//!     make artifacts && cargo run --release --features pjrt --example frs_serving
#![allow(deprecated)] // serve_probe: kept as the AOT numerics check

use adms::coordinator::{serve_probe, ServeConfig};
use adms::runtime::{default_artifact_dir, Runtime};
use adms::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let dir = default_artifact_dir();
    let art = rt.load_dir(&dir)?;
    println!(
        "loaded model '{}' from {:?} on platform '{}'",
        art.model,
        dir,
        rt.platform()
    );
    for (name, s) in &art.stages {
        println!(
            "  stage {:5}: {:?} -> {:?}",
            name, s.input_shape, s.output_shape
        );
    }
    println!("pipeline: {:?}\n", art.pipeline);

    // Serve at increasing concurrency; every response is checked against
    // the fused-model logits exported at AOT time.
    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>12} {:>8}",
        "workers", "requests", "p50 ms", "p95 ms", "req/s", "verified"
    );
    for workers in [1usize, 2, 4] {
        let cfg = ServeConfig { workers, requests: 256, verify: true };
        let r = serve_probe(&art, &cfg)?;
        anyhow::ensure!(r.errors == 0, "{} execution errors", r.errors);
        anyhow::ensure!(
            r.verify_failures == 0,
            "{} responses diverged from the JAX probe",
            r.verify_failures
        );
        println!(
            "{:>8} {:>10} {:>10} {:>10} {:>12} {:>8}",
            workers,
            r.completed,
            fnum(r.latency.p50(), 3),
            fnum(r.latency.p95(), 3),
            fnum(r.throughput_rps, 1),
            "all"
        );
    }
    println!("\nstaged-pipeline outputs match the fused JAX model: OK");
    Ok(())
}
