//! Quickstart: partition a model, inspect the window-size sweep, and run
//! a 10-second multi-DNN simulation under all three schedulers.
//!
//!     cargo run --release --example quickstart

use adms::analyzer;
use adms::experiments::common::{run_framework, Framework};
use adms::metrics::{comparison_table, fps_table};
use adms::sim::{App, SimConfig};
use adms::soc::dimensity9000;
use adms::zoo;

fn main() -> anyhow::Result<()> {
    let soc = dimensity9000();

    // 1. Partition a model and look at its unit subgraphs.
    let model = zoo::deeplab_v3();
    println!("== partitioning {} on {} ==", model.name, soc.device);
    for ws in [1, 5, 10] {
        let p = analyzer::partition(&model, &soc, ws);
        println!(
            "  ws={ws:2}: {:3} units, {:4} merged candidates, {:4} total subgraphs",
            p.units.len(),
            p.merged_candidates,
            p.total_subgraphs
        );
    }
    let (best, _) = analyzer::tune_window_size(&model, &soc, 12);
    println!("  tuned window size: {best}");

    // 2. Serve three concurrent models for 10 simulated seconds.
    let apps = vec![
        App::closed_loop("mobilenet_v2"),
        App::closed_loop("east"),
        App::with_slo("arcface_mobile", 30.0),
    ];
    let cfg = SimConfig { duration_ms: 10_000.0, ..Default::default() };
    println!("\n== 10 s simulation: MobileNetV2 + East + ArcFace ==");
    let reports: Vec<_> = Framework::ALL
        .iter()
        .map(|&fw| run_framework(&soc, fw, apps.clone(), cfg.clone()))
        .collect();
    let refs: Vec<&_> = reports.iter().collect();
    println!("{}", fps_table("Per-model FPS", &refs).render());
    println!("{}", comparison_table("Summary", &refs).render());
    Ok(())
}
