//! Quickstart: partition a model, inspect the window-size sweep, then
//! serve a multi-DNN workload through the unified `exec::Server` API —
//! first evaluated on the calibrated SoC simulator under all three
//! schedulers, then wall-clock on the thread-pool backend.
//!
//!     cargo run --release --example quickstart

use adms::analyzer;
use adms::exec::{ArrivalMode, Server};
use adms::metrics::{comparison_table, fps_table};
use adms::soc::dimensity9000;
use adms::util::table::fnum;
use adms::zoo;

fn main() -> anyhow::Result<()> {
    let soc = dimensity9000();

    // 1. Partition a model and look at its unit subgraphs.
    let model = zoo::deeplab_v3();
    println!("== partitioning {} on {} ==", model.name, soc.device);
    for ws in [1, 5, 10] {
        let p = analyzer::partition(&model, &soc, ws);
        println!(
            "  ws={ws:2}: {:3} units, {:4} merged candidates, {:4} total subgraphs",
            p.units.len(),
            p.merged_candidates,
            p.total_subgraphs
        );
    }
    let (best, _) = analyzer::tune_window_size(&model, &soc, 12);
    println!("  tuned window size: {best}");

    // 2. Evaluate three concurrent models for 10 simulated seconds under
    //    each scheduler. One Server builder per arm; the window size
    //    defaults to the paper's per-arm granularity (tuned for ADMS,
    //    ws = 1 for the baselines).
    let workload = |server: Server| {
        server
            .session("mobilenet_v2", ArrivalMode::ClosedLoop, None)
            .session("east", ArrivalMode::ClosedLoop, None)
            .session("arcface_mobile", ArrivalMode::ClosedLoop, Some(30.0))
            .duration_ms(10_000.0)
    };
    println!("\n== 10 s simulation: MobileNetV2 + East + ArcFace ==");
    let reports: Vec<_> = ["vanilla", "band", "adms"]
        .iter()
        .map(|name| workload(Server::new(soc.clone()).scheduler_name(name)).run_sim())
        .collect::<Result<_, _>>()?;
    let refs: Vec<&_> = reports.iter().collect();
    println!("{}", fps_table("Per-model FPS", &refs).render());
    println!("{}", comparison_table("Summary", &refs).render());

    // 3. The same workload, same scheduler, served wall-clock: 16
    //    requests per session on the worker-pool backend (synthetic
    //    payloads paced by the cost model; real PJRT stages when
    //    artifacts are attached).
    println!("== wall-clock serving (thread pool, ADMS) ==");
    let r = workload(Server::new(soc.clone()).scheduler_name("adms"))
        .requests(16)
        .pace(0.25)
        .run_threadpool()?;
    for s in &r.sessions {
        println!(
            "  {:16} {:3} completed  p50 {:>8} ms  p95 {:>8} ms",
            s.model,
            s.completed,
            fnum(s.latency.p50(), 2),
            fnum(s.latency.p95(), 2)
        );
    }
    Ok(())
}
