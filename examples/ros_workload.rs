//! The paper's ROS scenario (§4.4): MobileNetV2 + EfficientNet +
//! InceptionV4 classifying a continuous video stream, with SLOs attached,
//! across all three frameworks on both evaluation devices.
//!
//!     cargo run --release --example ros_workload

use adms::experiments::common::{run_framework, Framework};
use adms::metrics::{comparison_table, fps_table};
use adms::sim::{App, SimConfig};
use adms::soc::soc_by_name;

fn main() -> anyhow::Result<()> {
    for soc_name in ["dimensity9000", "kirin970"] {
        let soc = soc_by_name(soc_name).unwrap();
        println!("==== ROS on {} ====", soc.device);
        let apps = vec![
            App::with_slo("mobilenet_v2", 50.0),
            App::with_slo("efficientnet4", 200.0),
            App::with_slo("inception_v4", 400.0),
        ];
        let cfg = SimConfig { duration_ms: 30_000.0, ..Default::default() };
        let reports: Vec<_> = Framework::ALL
            .iter()
            .map(|&fw| run_framework(&soc, fw, apps.clone(), cfg.clone()))
            .collect();
        let refs: Vec<&_> = reports.iter().collect();
        println!("{}", fps_table("Per-model FPS", &refs).render());
        println!("{}", comparison_table("Summary", &refs).render());
        for r in &reports {
            let slos: Vec<String> = r
                .sessions
                .iter()
                .map(|s| {
                    format!(
                        "{} {:.1}%",
                        s.model,
                        100.0 * s.slo_satisfaction.unwrap_or(0.0)
                    )
                })
                .collect();
            println!("{:>8} SLO satisfaction: {}", r.scheduler, slos.join(", "));
        }
        println!();
    }
    Ok(())
}
