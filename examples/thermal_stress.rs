//! The paper's §4.8 thermal stress test: a heavy 6-model workload at
//! 35 °C ambient, with live temperature / frequency / throttling readout
//! for TFLite vs ADMS on the Redmi K50 Pro.
//!
//!     cargo run --release --example thermal_stress

use adms::experiments::common::{run_framework, Framework};
use adms::sim::SimConfig;
use adms::soc::dimensity9000;
use adms::util::table::{ascii_chart, fnum};
use adms::workload::stress_mix;

fn main() -> anyhow::Result<()> {
    let soc = dimensity9000();
    let cfg = SimConfig {
        duration_ms: 600_000.0, // 10 minutes
        ambient_c: Some(35.0),
        ..Default::default()
    };
    for fw in [Framework::Tflite, Framework::Adms] {
        let r = run_framework(&soc, fw, stress_mix(6), cfg.clone());
        println!("==== {} — 10 min @ 35 °C ambient ====", r.scheduler);
        println!(
            "completed {} requests, failure rate {}%, pipeline {} FPS",
            r.total_completed(),
            fnum(100.0 * r.failure_rate(), 2),
            fnum(r.pipeline_fps(), 2)
        );
        for (i, p) in r.procs.iter().enumerate() {
            println!(
                "  {:22} busy {:5.1}%  peak {:5.1} °C  min freq {:6} MHz  throttle events {:4}  first throttle {}",
                p.name,
                100.0 * p.busy_frac,
                p.temp.max(),
                fnum(p.freq.min(), 0),
                p.throttle_events,
                p.first_throttle_ms
                    .map(|t| format!("{} min", fnum(t / 60_000.0, 1)))
                    .unwrap_or_else(|| "never".into()),
            );
            let _ = i;
        }
        let cpu_t = r.procs[0].temp.downsample(70);
        let gpu_t = r.procs[1].temp.downsample(70);
        println!(
            "{}",
            ascii_chart(
                "temperature (°C) over 10 min",
                &[("cpu", &cpu_t.values), ("gpu", &gpu_t.values)],
                9
            )
        );
    }
    Ok(())
}
