"""AOT export: lower the Layer-2 model to HLO text for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  <stage>.hlo.txt   one per stage: stem, body, head, full
  manifest.json     stage list, input/output shapes, pipeline order,
                    and a numerics probe (input + expected output) the
                    Rust side uses as an end-to-end correctness check.

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *arg_specs) -> str:
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked model weights live in the HLO as
    # literal constants; the default printer elides them as `{...}`,
    # which the text parser would silently read back as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    params = model.init_params(args.seed)
    fns = model.stage_fns(params)
    shapes = model.stage_input_shapes()

    manifest = {
        "model": "mobilenet_tiny",
        "seed": args.seed,
        "pipeline": ["stem", "body", "head"],
        "stages": {},
    }

    # Lower every stage and record shapes.
    outputs = {}
    for name, fn in fns.items():
        spec = jax.ShapeDtypeStruct(shapes[name], jnp.float32)
        text = to_hlo_text(fn, spec)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        # Output shape from an eval on zeros (cheap at these sizes).
        out = fn(jnp.zeros(shapes[name], jnp.float32))[0]
        outputs[name] = out
        manifest["stages"][name] = {
            "file": f"{name}.hlo.txt",
            "input_shape": list(shapes[name]),
            "output_shape": list(out.shape),
        }
        print(f"wrote {path} ({len(text)} chars)")

    # Numerics probe: a fixed input and the fused model's output, plus the
    # staged composition (must agree) — the Rust integration test replays
    # both paths through PJRT and asserts against these.
    rng = np.random.RandomState(1234)
    x = rng.uniform(-1.0, 1.0, size=shapes["full"]).astype(np.float32)
    fused = np.asarray(fns["full"](jnp.asarray(x))[0])
    staged = np.asarray(
        fns["head"](fns["body"](fns["stem"](jnp.asarray(x))[0])[0])[0]
    )
    np.testing.assert_allclose(fused, staged, rtol=1e-5, atol=1e-5)
    manifest["probe"] = {
        "input": x.reshape(-1).tolist(),
        "expected_logits": fused.reshape(-1).tolist(),
    }

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    print(f"wrote manifest with {len(manifest['stages'])} stages; "
          f"staged==fused verified (max logit {float(np.abs(fused).max()):.4f})")


if __name__ == "__main__":
    main()
