"""Layer-1 Pallas kernels: the fused depthwise-separable block.

TPU adaptation of the paper's mobile hot path (DESIGN.md §2). The
MobileNet-family models the paper serves spend almost all their FLOPs in
depthwise-separable convolutions. On a mobile GPU these are threadblock
kernels over shared memory; on TPU we restructure:

* the 1x1 **pointwise** stage is an (HW, C) x (C, Cout) matmul tiled for
  the MXU systolic array — ``pointwise_matmul`` below is a classic
  BlockSpec-tiled matmul whose (block_hw, block_cout) output tile and its
  (block_hw, C) / (C, block_cout) operand slabs are sized to sit in VMEM;
* the 3x3 **depthwise** stage is elementwise-heavy VPU work: 9 shifted
  multiply-accumulates over an (H+2, W+2, C) padded slab, fused with the
  folded batch-norm affine and ReLU6.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness path
(numerics identical); real-TPU efficiency is estimated from the BlockSpec
footprint in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-friendly default tile sizes (f32): a (256, 128) output tile plus
# its operand slabs stays well under ~4 MiB for C <= 1024.
BLOCK_HW = 256
BLOCK_COUT = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (block_hw, block_cout) output tile: full-K matmul on the MXU."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_hw", "block_cout"))
def pointwise_matmul(x, w, *, block_hw=BLOCK_HW, block_cout=BLOCK_COUT):
    """Tiled (HW, C) @ (C, Cout) matmul via Pallas.

    Pads HW and Cout up to tile multiples, grids over output tiles, and
    slices the result back. The BlockSpec index maps express the
    HBM->VMEM schedule: each grid step streams one x-row-slab and one
    w-column-slab into VMEM and writes one output tile.
    """
    hw, c = x.shape
    c2, cout = w.shape
    assert c == c2, f"contraction mismatch {c} vs {c2}"
    bh = min(block_hw, _ceil_to(hw, 8))
    bc = min(block_cout, _ceil_to(cout, 8))
    hw_p = _ceil_to(hw, bh)
    cout_p = _ceil_to(cout, bc)
    xp = jnp.pad(x, ((0, hw_p - hw), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, cout_p - cout)))
    grid = (hw_p // bh, cout_p // bc)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bh, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bh, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((hw_p, cout_p), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:hw, :cout]


def _dws_kernel(xp_ref, dw_ref, scale_ref, bias_ref, o_ref, *, h, w):
    """Depthwise 3x3 + BN + ReLU6 over the full (padded) activation slab.

    The padded input (H+2, W+2, C) sits in VMEM; the 3x3 stencil unrolls
    into 9 shifted multiply-adds — pure VPU work with unit-stride access.
    """
    acc = jnp.zeros_like(o_ref[...])
    for di in range(3):
        for dj in range(3):
            acc = acc + xp_ref[di : di + h, dj : dj + w, :] * dw_ref[di, dj, :]
    o_ref[...] = jnp.clip(acc * scale_ref[...] + bias_ref[...], 0.0, 6.0)


@jax.jit
def depthwise_bn_relu6(x, dw, scale, bias):
    """Fused depthwise 3x3 (SAME, stride 1) + folded-BN affine + ReLU6.

    x: (H, W, C); dw: (3, 3, C); scale/bias: (C,). Returns (H, W, C).
    """
    h, w, c = x.shape
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(_dws_kernel, h=h, w=w)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w, c), jnp.float32),
        interpret=True,
    )(xp, dw, scale, bias)


def dws_block(x, dw, scale, bias, pw):
    """The fused depthwise-separable block (Layer-1 entry point).

    depthwise 3x3 -> BN/ReLU6 (VPU stage) -> pointwise 1x1 (MXU stage).
    Matches ``ref.dws_block_ref`` bit-for-bit up to f32 accumulation
    ordering.
    """
    h, w, _ = x.shape
    a = depthwise_bn_relu6(x, dw, scale, bias)
    o = pointwise_matmul(a.reshape(h * w, a.shape[-1]), pw)
    return o.reshape(h, w, pw.shape[1])


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
