"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes and dtypes).
"""

import jax.numpy as jnp
from jax import lax


def depthwise3x3_ref(x, w):
    """Depthwise 3x3 convolution, SAME padding, stride 1, NHWC.

    x: (H, W, C); w: (3, 3, C). Returns (H, W, C).
    """
    xb = x[None]  # (1, H, W, C)
    # lax depthwise conv: feature_group_count = C, kernel (3, 3, 1, C).
    kernel = w[:, :, None, :]
    out = lax.conv_general_dilated(
        xb,
        kernel,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1],
    )
    return out[0]


def bn_relu6_ref(x, scale, bias):
    """Per-channel affine + ReLU6 (the BN-at-inference fold)."""
    return jnp.clip(x * scale + bias, 0.0, 6.0)


def pointwise_ref(x, w):
    """1x1 convolution as a matmul. x: (H, W, C); w: (C, Cout)."""
    h, wdt, c = x.shape
    return (x.reshape(h * wdt, c) @ w).reshape(h, wdt, w.shape[1])


def dws_block_ref(x, dw, scale, bias, pw):
    """Fused depthwise-separable block: depthwise 3x3 -> BN/ReLU6 ->
    pointwise 1x1 (the MobileNet building block, the paper's dominant
    compute — Table 1 shows C2D+DW ops are 70-78% of these models)."""
    d = depthwise3x3_ref(x, dw)
    a = bn_relu6_ref(d, scale, bias)
    return pointwise_ref(a, pw)
