"""Layer-2 JAX model: MobileNet-tiny built from the Layer-1 kernels.

A small (~0.4 MFLOP/px) MobileNet-style classifier that the Rust
coordinator serves end-to-end through PJRT. The network is split into
three stages matching the subgraph-serving story: *stem* (dense conv),
*body* (a chain of Pallas depthwise-separable blocks), and *head*
(global pool + classifier matmul). ``aot.py`` lowers each stage — and
the fused full model — to HLO text; the Rust side chains them across
worker "processors" and checks the staged composition against the fused
output.

Weights are generated deterministically from a seed and baked into the
lowered HLO as constants, so the served artifact is self-contained.
"""

import jax
import jax.numpy as jnp

from .kernels import dws_conv

# Default architecture: 32x32 input, 3->C stem, BLOCKS dws blocks, 10-way
# classifier — big enough to exercise every kernel path, small enough to
# AOT and serve in milliseconds on the CPU PJRT backend.
INPUT_HW = 32
WIDTH = 16
BLOCKS = 4
CLASSES = 10


def init_params(seed: int = 0, width: int = WIDTH, blocks: int = BLOCKS,
                classes: int = CLASSES):
    """Deterministic parameter pytree."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 3 + 4 * blocks)
    k = iter(keys)
    scale = 0.3
    params = {
        "stem_w": jax.random.normal(next(k), (3, 3, 3, width)) * scale,
        "head_w": jax.random.normal(next(k), (width, classes)) * scale,
        "head_b": jax.random.normal(next(k), (classes,)) * 0.01,
        "blocks": [],
    }
    for _ in range(blocks):
        params["blocks"].append({
            "dw": jax.random.normal(next(k), (3, 3, width)) * scale,
            "scale": jnp.ones((width,)) + 0.1 * jax.random.normal(next(k), (width,)),
            "bias": 0.1 * jax.random.normal(next(k), (width,)),
            "pw": jax.random.normal(jax.random.fold_in(next(k), 7),
                                    (width, width)) * scale,
        })
    return params


def stem(params, x):
    """Dense 3x3 stride-1 conv + ReLU6. x: (H, W, 3) -> (H, W, width)."""
    out = jax.lax.conv_general_dilated(
        x[None],
        params["stem_w"],
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    return jnp.clip(out, 0.0, 6.0)


def body(params, h):
    """The Pallas hot path: a chain of fused depthwise-separable blocks
    with residual connections."""
    for blk in params["blocks"]:
        o = dws_conv.dws_block(h, blk["dw"], blk["scale"], blk["bias"], blk["pw"])
        h = h + o  # residual (width-preserving blocks)
    return h


def head(params, h):
    """Global average pool + classifier (Pallas pointwise matmul)."""
    pooled = jnp.mean(h, axis=(0, 1), keepdims=False)  # (width,)
    logits = dws_conv.pointwise_matmul(pooled[None, :], params["head_w"])[0]
    return logits + params["head_b"]


def full(params, x):
    """Fused end-to-end forward pass."""
    return head(params, body(params, stem(params, x)))


def stage_fns(params):
    """The three serving stages with parameters closed over (baked into
    the HLO as constants), plus the fused reference."""
    return {
        "stem": lambda x: (stem(params, x),),
        "body": lambda h: (body(params, h),),
        "head": lambda h: (head(params, h),),
        "full": lambda x: (full(params, x),),
    }


def stage_input_shapes(width: int = WIDTH, hw: int = INPUT_HW):
    """Input shape per stage (single example, NHWC without N)."""
    return {
        "stem": (hw, hw, 3),
        "body": (hw, hw, width),
        "head": (hw, hw, width),
        "full": (hw, hw, 3),
    }
