"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; every kernel must match ``ref.py`` to f32
accumulation tolerance. This is the core correctness signal for the
compute path the Rust coordinator serves.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dws_conv, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape,
                              minval=lo, maxval=hi, dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    c=st.integers(1, 8),
)
def test_depthwise_matches_ref(h, w, c):
    x = rand(1, (h, w, c))
    dw = rand(2, (3, 3, c))
    scale = rand(3, (c,), 0.5, 1.5)
    bias = rand(4, (c,), -0.5, 0.5)
    ours = dws_conv.depthwise_bn_relu6(x, dw, scale, bias)
    want = ref.bn_relu6_ref(ref.depthwise3x3_ref(x, dw), scale, bias)
    np.testing.assert_allclose(ours, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    hw=st.integers(1, 96),
    c=st.integers(1, 16),
    cout=st.integers(1, 24),
)
def test_pointwise_matmul_matches_ref(hw, c, cout):
    x = rand(5, (hw, c))
    w = rand(6, (c, cout))
    ours = dws_conv.pointwise_matmul(x, w)
    want = x @ w
    np.testing.assert_allclose(ours, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    hw=st.integers(260, 600),  # force multi-tile grids (BLOCK_HW = 256)
    cout=st.integers(130, 200),  # force multi-tile Cout (BLOCK_COUT = 128)
)
def test_pointwise_matmul_multi_tile_grid(hw, cout):
    c = 8
    x = rand(7, (hw, c))
    w = rand(8, (c, cout))
    ours = dws_conv.pointwise_matmul(x, w)
    np.testing.assert_allclose(ours, x @ w, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(4, 10),
    w=st.integers(4, 10),
    c=st.integers(2, 8),
    cout=st.integers(1, 12),
)
def test_dws_block_matches_ref(h, w, c, cout):
    x = rand(10, (h, w, c))
    dw = rand(11, (3, 3, c))
    scale = rand(12, (c,), 0.5, 1.5)
    bias = rand(13, (c,), -0.5, 0.5)
    pw = rand(14, (c, cout))
    ours = dws_conv.dws_block(x, dw, scale, bias, pw)
    want = ref.dws_block_ref(x, dw, scale, bias, pw)
    assert ours.shape == (h, w, cout)
    np.testing.assert_allclose(ours, want, rtol=1e-4, atol=1e-5)


def test_relu6_clamps_both_sides():
    x = jnp.array([[[-100.0, 0.5, 100.0]]])
    dw = jnp.zeros((3, 3, 3)).at[1, 1, :].set(1.0)  # identity stencil
    scale = jnp.ones((3,))
    bias = jnp.zeros((3,))
    out = dws_conv.depthwise_bn_relu6(x, dw, scale, bias)
    np.testing.assert_allclose(out[0, 0], [0.0, 0.5, 6.0], atol=1e-6)


def test_identity_depthwise_stencil():
    x = rand(20, (6, 6, 4))
    dw = jnp.zeros((3, 3, 4)).at[1, 1, :].set(1.0)
    out = dws_conv.depthwise_bn_relu6(x, dw, jnp.ones((4,)), jnp.zeros((4,)))
    np.testing.assert_allclose(out, jnp.clip(x, 0, 6), atol=1e-6)


@pytest.mark.parametrize("block_hw,block_cout", [(8, 8), (16, 32), (256, 128)])
def test_matmul_tile_size_invariance(block_hw, block_cout):
    x = rand(30, (50, 12))
    w = rand(31, (12, 20))
    out = dws_conv.pointwise_matmul(x, w, block_hw=block_hw,
                                    block_cout=block_cout)
    np.testing.assert_allclose(out, x @ w, rtol=1e-4, atol=1e-5)
