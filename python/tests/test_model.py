"""Layer-2 correctness: model stages, staged-vs-fused equivalence, and
AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_stage_shapes():
    params = model.init_params(0)
    shapes = model.stage_input_shapes()
    x = jnp.zeros(shapes["full"], jnp.float32)
    s = model.stem(params, x)
    assert s.shape == (model.INPUT_HW, model.INPUT_HW, model.WIDTH)
    b = model.body(params, s)
    assert b.shape == s.shape
    logits = model.head(params, b)
    assert logits.shape == (model.CLASSES,)


def test_staged_equals_fused():
    params = model.init_params(0)
    x = jax.random.uniform(jax.random.PRNGKey(9),
                           model.stage_input_shapes()["full"],
                           minval=-1, maxval=1)
    fused = model.full(params, x)
    staged = model.head(params, model.body(params, model.stem(params, x)))
    np.testing.assert_allclose(fused, staged, rtol=1e-5, atol=1e-5)


def test_different_seeds_give_different_weights():
    a = model.init_params(0)
    b = model.init_params(1)
    assert not np.allclose(a["stem_w"], b["stem_w"])


def test_deterministic_params():
    a = model.init_params(0)
    b = model.init_params(0)
    np.testing.assert_array_equal(a["stem_w"], b["stem_w"])
    np.testing.assert_array_equal(a["blocks"][0]["pw"], b["blocks"][0]["pw"])


def test_aot_lowering_produces_hlo_text():
    params = model.init_params(0)
    fns = model.stage_fns(params)
    spec = jax.ShapeDtypeStruct(model.stage_input_shapes()["head"], jnp.float32)
    text = aot.to_hlo_text(fns["head"], spec)
    assert "HloModule" in text
    assert "f32" in text
    # Tuple-rooted (return_tuple=True) so the Rust side can to_tuple1().
    assert "tuple" in text.lower()


def test_full_output_is_finite_and_nontrivial():
    params = model.init_params(0)
    x = jax.random.uniform(jax.random.PRNGKey(3),
                           model.stage_input_shapes()["full"],
                           minval=-1, maxval=1)
    logits = model.full(params, x)
    assert np.all(np.isfinite(logits))
    assert float(np.abs(logits).max()) > 1e-3
