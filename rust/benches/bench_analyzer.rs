//! Analyzer hot path: unit formation, merged-candidate counting, and the
//! window-size tuner across the zoo. These run once per (model, device)
//! at install time in the paper's system but sit on the critical path of
//! the experiment harness, so they are first-class perf targets.

use adms::analyzer;
use adms::soc::dimensity9000;
use adms::testing::bench::Bench;
use adms::zoo;

fn main() {
    let soc = dimensity9000();
    let mut b = Bench::new("analyzer");
    for name in ["mobilenet_v1", "deeplab_v3", "yolo_v3"] {
        let g = zoo::by_name(name).unwrap();
        b.bench(&format!("unit_subgraphs/{name}"), || {
            std::hint::black_box(analyzer::get_unit_subgraphs(&g, &soc, 1));
        });
        let units = analyzer::get_unit_subgraphs(&g, &soc, 1);
        b.bench(&format!("merged_candidates/{name}"), || {
            std::hint::black_box(analyzer::count_merged_candidates(&units));
        });
        b.bench(&format!("full_partition_ws5/{name}"), || {
            std::hint::black_box(analyzer::partition(&g, &soc, 5));
        });
    }
    let g = zoo::deeplab_v3();
    // Bench the underlying sweep, not `tune_window_size`: the latter is
    // memoized process-wide, so after one warm-up call it would time a
    // cache lookup and hide any real tuner regression.
    b.bench("tune_sweep_uncached/deeplab_v3", || {
        std::hint::black_box(analyzer::tuner::sweep_window_sizes(&g, &soc, 12));
    });
    b.finish();
}
