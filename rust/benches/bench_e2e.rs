//! End-to-end wall-clock serving throughput through the coordinator +
//! PJRT (the `frs_serving` example's hot path), across worker counts.
#![allow(deprecated)] // serve_probe: kept as the PJRT numerics benchmark

use adms::coordinator::{serve_probe, ServeConfig};
use adms::runtime::{artifacts_available, default_artifact_dir, Runtime};
use adms::testing::bench::Bench;

fn main() {
    if !artifacts_available() {
        eprintln!("SKIP bench_e2e: artifacts/ missing — run `make artifacts`");
        return;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let art = rt.load_dir(&default_artifact_dir()).expect("artifacts");
    let mut b = Bench::new("e2e");
    for workers in [1usize, 2, 4] {
        let cfg = ServeConfig { workers, requests: 64, verify: false };
        b.bench(&format!("serve_64req/{workers}workers"), || {
            let r = serve_probe(&art, &cfg).unwrap();
            assert_eq!(r.errors, 0);
            std::hint::black_box(r);
        });
    }
    // Verified serving (adds the response-check cost).
    let cfg = ServeConfig { workers: 2, requests: 64, verify: true };
    b.bench("serve_64req/2workers_verified", || {
        let r = serve_probe(&art, &cfg).unwrap();
        assert_eq!(r.verify_failures, 0);
        std::hint::black_box(r);
    });
    b.finish();
}
