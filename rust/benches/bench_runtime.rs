//! PJRT execution latency of the AOT artifacts (the real-compute hot
//! path behind `adms serve`). Skips when `make artifacts` has not run.

use adms::runtime::{artifacts_available, default_artifact_dir, Runtime};
use adms::testing::bench::Bench;

fn main() {
    if !artifacts_available() {
        eprintln!("SKIP bench_runtime: artifacts/ missing — run `make artifacts`");
        return;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let art = rt.load_dir(&default_artifact_dir()).expect("artifacts");
    let probe = art.probe.clone().expect("probe");
    let mut b = Bench::new("runtime");
    for name in ["stem", "body", "head", "full"] {
        let stage = art.stage(name).unwrap();
        let input = if name == "stem" || name == "full" {
            probe.input.clone()
        } else {
            vec![0.1f32; stage.input_len()]
        };
        b.bench(&format!("execute/{name}"), || {
            std::hint::black_box(stage.execute_f32(&input).unwrap());
        });
    }
    // Staged pipeline end-to-end.
    let stages = art.pipeline_stages().unwrap();
    b.bench("execute/pipeline_staged", || {
        let mut buf = probe.input.clone();
        for s in &stages {
            buf = s.execute_f32(&buf).unwrap();
        }
        std::hint::black_box(buf);
    });
    b.finish();
}
