//! Scheduler decision latency: one `schedule()` call under a realistic
//! ready-queue (the paper's Loop_call_size trade-off). The coordinator's
//! dispatch loop runs this on every task completion, so decision time
//! bounds achievable scheduling throughput.

use adms::monitor::ProcView;
use adms::sched::{Adms, Band, ModelPlan, PendingTask, SchedCtx, Scheduler, VanillaTflite};
use adms::soc::dimensity9000;
use adms::testing::bench::Bench;
use adms::zoo;
use std::sync::Arc;

fn main() {
    let soc = dimensity9000();
    let plans: Vec<ModelPlan> = ["retinaface", "arcface_mobile", "arcface_resnet50"]
        .iter()
        .map(|m| ModelPlan::build(Arc::new(zoo::by_name(m).unwrap()), &soc, 5))
        .collect();
    let views: Vec<ProcView> = soc
        .processors
        .iter()
        .enumerate()
        .map(|(id, p)| {
            // Nameplate view under a realistic mid-run load profile.
            let mut v = ProcView::nameplate(id, p, 45.0);
            v.load = 0.25;
            v.backlog_ms = 8.0;
            v.active_sessions = 2;
            v.util = 0.5;
            v
        })
        .collect();
    // A 12-task ready queue across the three sessions.
    let ready: Vec<PendingTask> = (0..12)
        .map(|i| PendingTask {
            req: i as u64,
            session: i % 3,
            unit: (i / 3) % plans[i % 3].num_units(),
            ready_at: 0.0,
            req_arrival: 0.0,
            slo_ms: Some(40.0),
            remaining_ms: 6.0,
            dep_procs: vec![],
        })
        .collect();
    let ctx = SchedCtx {
        now: 10.0,
        soc: &soc,
        plans: &plans,
        procs: &views,
        batch: adms::sched::BatchCtx::OFF,
        weights: adms::sched::WeightsView::OFF,
        variants: None,
    };

    let mut b = Bench::new("sched");
    let mut out = Vec::new();
    let mut adms = Adms::default();
    b.bench("adms/decision_12ready", || {
        out.clear();
        adms.schedule(&ctx, &ready, &mut out);
        std::hint::black_box(&out);
    });
    let mut band = Band::new();
    b.bench("band/decision_12ready", || {
        out.clear();
        band.schedule(&ctx, &ready, &mut out);
        std::hint::black_box(&out);
    });
    let mut tfl = VanillaTflite::default_for(&soc, 3);
    b.bench("tflite/decision_12ready", || {
        out.clear();
        tfl.schedule(&ctx, &ready, &mut out);
        std::hint::black_box(&out);
    });
    b.finish();
}
