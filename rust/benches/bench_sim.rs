//! Discrete-event engine throughput: full simulated seconds per wall
//! second across the three framework arms on the FRS workload — the
//! quantity that bounds how fast the experiment harness regenerates the
//! paper's figures.
//!
//! The measurement set lives in `adms::testing::bench::run_sim_suite` so
//! `adms bench` (which also writes `BENCH_sim.json` for the tracked perf
//! trajectory) and this `cargo bench` target time exactly the same code.

use adms::testing::bench::{print_sim_suite, run_sim_suite};

fn main() {
    let (_, entries) = run_sim_suite();
    print_sim_suite(&entries);
}
