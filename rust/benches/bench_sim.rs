//! Discrete-event engine throughput: full simulated seconds per wall
//! second across the three framework arms on the FRS workload — the
//! quantity that bounds how fast the experiment harness regenerates the
//! paper's figures.

use adms::experiments::common::{run_framework, Framework};
use adms::sim::SimConfig;
use adms::soc::dimensity9000;
use adms::testing::bench::Bench;
use adms::workload::frs;

fn main() {
    let soc = dimensity9000();
    let mut b = Bench::new("sim");
    for fw in Framework::ALL {
        let cfg = SimConfig { duration_ms: 2_000.0, ..Default::default() };
        b.bench(&format!("frs_2s/{}", fw.label()), || {
            std::hint::black_box(run_framework(&soc, fw, frs(), cfg.clone()));
        });
    }
    // Scaling with concurrency (the Table 7 stress path).
    for n in [4usize, 8] {
        let cfg = SimConfig { duration_ms: 1_000.0, ..Default::default() };
        b.bench(&format!("stress_1s/{n}_models"), || {
            std::hint::black_box(run_framework(
                &soc,
                Framework::Adms,
                adms::workload::stress_mix(n),
                cfg.clone(),
            ));
        });
    }
    b.finish();
}
