//! Merged-subgraph candidate enumeration (Band's behaviour, paper §3.2 /
//! Tables 3 and 5).
//!
//! Band materializes, ahead of time, a schedulable subgraph for every
//! contiguous range of unit subgraphs whose processor supports intersect,
//! one per processor in the intersection. On fragmented models this
//! explodes combinatorially (DeepLabV3: 65 units → thousands of merged
//! candidates), which is exactly the memory / scheduling-complexity
//! problem ADMS's window-size filter removes at the source.

use super::UnitSubgraph;
use crate::soc::ProcId;

/// Common support of a unit range, or empty when the intersection dies.
fn common_support(units: &[UnitSubgraph], lo: usize, hi: usize) -> Vec<ProcId> {
    let mut acc: Vec<ProcId> = units[lo].support.clone();
    for u in &units[lo + 1..=hi] {
        acc.retain(|p| u.support.contains(p));
        if acc.is_empty() {
            break;
        }
    }
    acc
}

/// Number of merged candidates: one per (contiguous range of ≥ 2 units,
/// processor in the range's common support).
pub fn count_merged_candidates(units: &[UnitSubgraph]) -> usize {
    let n = units.len();
    let mut count = 0;
    for lo in 0..n {
        // Maintain the intersection incrementally; stop once empty (it can
        // never come back for a larger range).
        let mut acc = units[lo].support.clone();
        for hi in lo + 1..n {
            acc.retain(|p| units[hi].support.contains(p));
            if acc.is_empty() {
                break;
            }
            count += acc.len();
        }
    }
    count
}

/// Table 3's "Total" column: per-processor unit instances plus merged
/// candidates (each unit is materialized once per supporting processor).
pub fn count_total_subgraphs(units: &[UnitSubgraph]) -> usize {
    let unit_instances: usize = units.iter().map(|u| u.support.len()).sum();
    unit_instances + count_merged_candidates(units)
}

/// Materialize the merged candidate op lists for a range (used when a
/// scheduler actually dispatches a merged subgraph).
pub fn merged_ops(units: &[UnitSubgraph], lo: usize, hi: usize) -> Option<Vec<usize>> {
    if lo > hi || hi >= units.len() {
        return None;
    }
    if common_support(units, lo, hi).is_empty() {
        return None;
    }
    let mut ops = Vec::new();
    for u in &units[lo..=hi] {
        ops.extend_from_slice(&u.ops);
    }
    Some(ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(ops: &[usize], support: &[usize]) -> UnitSubgraph {
        UnitSubgraph { ops: ops.to_vec(), support: support.to_vec() }
    }

    #[test]
    fn single_unit_has_no_merges() {
        let units = [unit(&[0, 1, 2], &[0, 1, 2, 3])];
        assert_eq!(count_merged_candidates(&units), 0);
        // Paper Table 3, East: 1 unit × 4 processors, 0 merged → total 4.
        assert_eq!(count_total_subgraphs(&units), 4);
    }

    #[test]
    fn two_units_merge_once_per_common_processor() {
        let units = [unit(&[0], &[0, 1, 2, 3]), unit(&[1], &[0, 1, 2, 3])];
        // Paper Table 5, MobileNetV1 under ADMS: 2 units, 4 merged.
        assert_eq!(count_merged_candidates(&units), 4);
    }

    #[test]
    fn disjoint_support_blocks_merging() {
        let units = [unit(&[0], &[1]), unit(&[1], &[2]), unit(&[2], &[1])];
        assert_eq!(count_merged_candidates(&units), 0);
        assert_eq!(count_total_subgraphs(&units), 3);
    }

    #[test]
    fn intersection_is_monotone_over_ranges() {
        // Ranges crossing a CPU-only unit can only merge on the CPU.
        let units = [
            unit(&[0], &[0, 1]),
            unit(&[1], &[0]), // CPU-only
            unit(&[2], &[0, 1]),
        ];
        // Ranges: (0,1)->{0}: 1; (0,2)->{0}: 1; (1,2)->{0}: 1. Total 3.
        assert_eq!(count_merged_candidates(&units), 3);
    }

    #[test]
    fn quadratic_growth_on_uniform_support() {
        // n units all supported by p processors: p·n(n−1)/2 candidates —
        // the Band explosion the paper measures.
        let n = 30;
        let units: Vec<UnitSubgraph> =
            (0..n).map(|i| unit(&[i], &[0, 1, 2])).collect();
        assert_eq!(count_merged_candidates(&units), 3 * n * (n - 1) / 2);
    }

    #[test]
    fn merged_ops_concatenates_in_order() {
        let units = [unit(&[0, 1], &[0, 1]), unit(&[2], &[0, 1]), unit(&[3], &[2])];
        assert_eq!(merged_ops(&units, 0, 1).unwrap(), vec![0, 1, 2]);
        assert!(merged_ops(&units, 1, 2).is_none()); // no common support
        assert!(merged_ops(&units, 0, 9).is_none()); // out of range
    }
}
