//! Model Analyzer (paper §3.2): subgraph partitioning with hardware
//! granularity control.
//!
//! Pipeline:
//! 1. **Support resolution** — per op, the set of processors whose
//!    support table covers its kind.
//! 2. **Window-size filtering** (the ADMS contribution) — for each
//!    accelerator, maximal topo-contiguous runs of ops it supports that
//!    are *shorter than `window_size`* are ignored: the accelerator is
//!    removed from those ops' support sets (running a 2-op island on the
//!    DSP costs more in transfers than it saves). `window_size = 1`
//!    disables filtering and reproduces Band's behaviour.
//! 3. **Unit formation** — maximal topo-contiguous runs with identical
//!    (filtered) support signatures become unit subgraphs (Algorithm 1's
//!    `ResolveSubgraphs`).
//! 4. **Merged-candidate enumeration** — Band materializes a scheduling
//!    candidate for every contiguous unit range with common processor
//!    support, per processor in that common set; the count of these
//!    candidates is the paper's "Merged Subgraphs" metric (Tables 3/5)
//!    and the driver of its memory/scheduling-complexity findings.

pub mod merge;
pub mod tuner;

pub use merge::{count_merged_candidates, count_total_subgraphs};
pub use tuner::{
    estimate_chain_latency_ms, tune_cache_len, tune_plan_set, tune_window_size,
    tuned_window_size, TunedConfig,
};

use crate::graph::{Graph, NodeId, OpKind};
use crate::soc::{ProcId, SocSpec};

/// One unit subgraph: a topo-contiguous op run with a uniform support set.
#[derive(Debug, Clone)]
pub struct UnitSubgraph {
    /// Ops in topological order (contiguous ids).
    pub ops: Vec<NodeId>,
    /// Processors that support every op in this unit (always non-empty:
    /// the CPU supports everything).
    pub support: Vec<ProcId>,
}

impl UnitSubgraph {
    pub fn len(&self) -> usize {
        self.ops.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
    pub fn supports(&self, p: ProcId) -> bool {
        self.support.contains(&p)
    }
}

/// Result of partitioning one model for one SoC at one window size.
#[derive(Debug, Clone)]
pub struct Partition {
    pub window_size: usize,
    pub units: Vec<UnitSubgraph>,
    /// Band's merged-candidate count (Tables 3/5 "Merged Subgraphs").
    pub merged_candidates: usize,
    /// units-weighted-by-support + merged (Table 3 "Total").
    pub total_subgraphs: usize,
}

/// Per-op processor support sets after window-size filtering.
pub fn op_support_table(g: &Graph, soc: &SocSpec, window_size: usize) -> Vec<Vec<ProcId>> {
    let n = g.nodes.len();
    let cpu = soc.cpu_id();
    // Raw support.
    let mut table: Vec<Vec<ProcId>> = (0..n)
        .map(|i| {
            let kind = g.nodes[i].kind;
            (0..soc.num_processors())
                .filter(|&p| soc.processors[p].support.supports(kind))
                .collect()
        })
        .collect();
    // Window-size filtering per accelerator (Algorithm 1 lines 9-15):
    // drop accelerator support on runs shorter than the window.
    if window_size > 1 {
        for p in 0..soc.num_processors() {
            if p == cpu {
                continue; // the CPU is the fallback target, never filtered
            }
            let mut i = 0;
            while i < n {
                if table[i].contains(&p) && g.nodes[i].kind != OpKind::Input {
                    let start = i;
                    while i < n && table[i].contains(&p) && g.nodes[i].kind != OpKind::Input {
                        i += 1;
                    }
                    if i - start < window_size {
                        for t in table.iter_mut().take(i).skip(start) {
                            t.retain(|&q| q != p);
                        }
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    table
}

/// Does any processor fail to support some op (paper Algorithm 1's
/// `NeedFallbackSubgraph`)? If not, every processor can run the whole
/// model as a single subgraph.
pub fn needs_fallback(g: &Graph, soc: &SocSpec) -> bool {
    g.nodes.iter().any(|node| {
        node.kind != OpKind::Input
            && soc
                .processors
                .iter()
                .any(|p| !p.support.supports(node.kind))
    })
}

/// Algorithm 1: produce unit subgraphs for a model on an SoC.
pub fn get_unit_subgraphs(g: &Graph, soc: &SocSpec, window_size: usize) -> Vec<UnitSubgraph> {
    let all_ops: Vec<NodeId> = g
        .nodes
        .iter()
        .filter(|n| n.kind != OpKind::Input)
        .map(|n| n.id)
        .collect();
    if all_ops.is_empty() {
        return Vec::new();
    }
    if !needs_fallback(g, soc) {
        // Lines 3-7: one unit containing the whole model, supported by all.
        return vec![UnitSubgraph {
            ops: all_ops,
            support: (0..soc.num_processors()).collect(),
        }];
    }
    // Lines 9-19: build the filtered support table, then resolve maximal
    // runs of identical signatures.
    let table = op_support_table(g, soc, window_size);
    let mut units: Vec<UnitSubgraph> = Vec::new();
    for &op in &all_ops {
        let sig = &table[op];
        match units.last_mut() {
            Some(u) if u.support == *sig && *u.ops.last().unwrap() == op - 1 => {
                u.ops.push(op);
            }
            _ => units.push(UnitSubgraph { ops: vec![op], support: sig.clone() }),
        }
    }
    units
}

/// Full partitioning entry point: units + Band's merged-candidate census.
pub fn partition(g: &Graph, soc: &SocSpec, window_size: usize) -> Partition {
    let units = get_unit_subgraphs(g, soc, window_size);
    let merged = count_merged_candidates(&units);
    let total = count_total_subgraphs(&units);
    Partition { window_size, units, merged_candidates: merged, total_subgraphs: total }
}

/// Dependencies between units: `deps[j]` lists units that must complete
/// before unit `j` may start (derived from op-level edges).
pub fn unit_deps(g: &Graph, units: &[UnitSubgraph]) -> Vec<Vec<usize>> {
    // Map op -> unit.
    let mut op_unit = vec![usize::MAX; g.nodes.len()];
    for (ui, u) in units.iter().enumerate() {
        for &op in &u.ops {
            op_unit[op] = ui;
        }
    }
    let mut deps: Vec<Vec<usize>> = vec![Vec::new(); units.len()];
    for (ui, u) in units.iter().enumerate() {
        for &op in &u.ops {
            for &inp in &g.nodes[op].inputs {
                let pu = op_unit[inp];
                if pu != usize::MAX && pu != ui && !deps[ui].contains(&pu) {
                    deps[ui].push(pu);
                }
            }
        }
        deps[ui].sort_unstable();
    }
    deps
}

/// Bytes that flow from unit `from` into unit `to` (tensors produced in
/// `from` consumed by ops in `to`) — the transfer cost when the two units
/// execute on different processors.
pub fn inter_unit_bytes(g: &Graph, units: &[UnitSubgraph], from: usize, to: usize) -> u64 {
    let from_set: std::collections::HashSet<NodeId> = units[from].ops.iter().copied().collect();
    let mut counted = std::collections::HashSet::new();
    let mut bytes = 0;
    for &op in &units[to].ops {
        for &inp in &g.nodes[op].inputs {
            if from_set.contains(&inp) && counted.insert(inp) {
                bytes += g.nodes[inp].out_bytes(g.dtype_bytes);
            }
        }
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::dimensity9000;
    use crate::zoo;

    #[test]
    fn units_cover_all_ops_exactly_once() {
        let soc = dimensity9000();
        for g in zoo::all_models() {
            for ws in [1, 4, 8] {
                let units = get_unit_subgraphs(&g, &soc, ws);
                let mut seen = std::collections::HashSet::new();
                for u in &units {
                    assert!(!u.is_empty());
                    assert!(!u.support.is_empty(), "{}: unit with empty support", g.name);
                    for &op in &u.ops {
                        assert!(seen.insert(op), "{}: op {op} in two units", g.name);
                    }
                }
                assert_eq!(seen.len(), g.num_real_ops(), "{} ws={ws}", g.name);
            }
        }
    }

    #[test]
    fn every_unit_supports_cpu() {
        let soc = dimensity9000();
        let cpu = soc.cpu_id();
        for g in zoo::all_models() {
            for u in get_unit_subgraphs(&g, &soc, 5) {
                assert!(u.supports(cpu), "{}: unit without CPU fallback", g.name);
            }
        }
    }

    #[test]
    fn window_size_reduces_unit_count_monotonically_in_trend() {
        let soc = dimensity9000();
        let g = zoo::deeplab_v3();
        let u1 = get_unit_subgraphs(&g, &soc, 1).len();
        let u5 = get_unit_subgraphs(&g, &soc, 5).len();
        let u100 = get_unit_subgraphs(&g, &soc, 100).len();
        assert!(u1 > u5, "ws=1 gives {u1}, ws=5 gives {u5}");
        // Paper Fig 6: at the largest window the graph consolidates.
        assert!(u100 <= 3, "ws=100 still has {u100} units");
    }

    #[test]
    fn fragmentation_ranking_matches_table3() {
        // Paper Table 3 (Band, ws=1): DeepLabV3 is by far the most
        // fragmented model; MobileNetV1 and East are among the least.
        let soc = dimensity9000();
        let units =
            |name: &str| get_unit_subgraphs(&zoo::by_name(name).unwrap(), &soc, 1).len();
        let deeplab = units("deeplab_v3");
        let mnv1 = units("mobilenet_v1");
        let east = units("east");
        assert!(deeplab > 2 * east, "deeplab {deeplab} vs east {east}");
        assert!(deeplab > 2 * mnv1, "deeplab {deeplab} vs mnv1 {mnv1}");
        assert!(deeplab >= 10, "deeplab should fragment heavily, got {deeplab}");
        assert!(mnv1 <= 4, "mnv1 {mnv1} (paper: 4 units)");
        assert!(east <= 12, "east {east}");
    }

    #[test]
    fn unit_deps_are_acyclic_and_backward_only() {
        let soc = dimensity9000();
        let g = zoo::yolo_v3();
        let units = get_unit_subgraphs(&g, &soc, 3);
        let deps = unit_deps(&g, &units);
        for (ui, ds) in deps.iter().enumerate() {
            for &d in ds {
                assert!(d < ui, "unit {ui} depends on later/self unit {d}");
            }
        }
    }

    #[test]
    fn inter_unit_bytes_positive_across_boundary() {
        let soc = dimensity9000();
        let g = zoo::deeplab_v3();
        let units = get_unit_subgraphs(&g, &soc, 1);
        let deps = unit_deps(&g, &units);
        let mut found = false;
        for (ui, ds) in deps.iter().enumerate() {
            for &d in ds {
                if inter_unit_bytes(&g, &units, d, ui) > 0 {
                    found = true;
                }
            }
        }
        assert!(found, "no tensor bytes cross any unit boundary");
    }

    #[test]
    fn filtering_never_removes_cpu() {
        let soc = dimensity9000();
        let g = zoo::deeplab_v3();
        let table = op_support_table(&g, &soc, 50);
        let cpu = soc.cpu_id();
        for (i, sup) in table.iter().enumerate() {
            assert!(sup.contains(&cpu), "op {i} lost CPU support");
        }
    }
}
