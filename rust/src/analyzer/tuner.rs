//! Offline window-size auto-tuning (paper §3.2 / Fig 6).
//!
//! For each model-SoC pair, sweep the window size, estimate single-model
//! latency of the resulting partition with a dynamic program over
//! (unit, processor) placements — execution cost at the unit's fastest
//! admissible processor plus transfer costs at unit boundaries — and keep
//! the window that minimizes it. The paper determines these empirically
//! per device-model pair and stores them for runtime use; `TunedConfig`
//! is that store.

use super::{inter_unit_bytes, partition, unit_deps, Partition};
use crate::graph::Graph;
use crate::soc::{cost, SocSpec};
use crate::util::memo::Memo;
use crate::TimeMs;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Scheduling/management cost per dispatch, per candidate subgraph under
/// management. The paper measured that excessive subgraphs inflate
/// inference latency by up to 28 % purely through scheduling and memory
/// management; the runtime scans its candidate set on every dispatch
/// decision, so each unit dispatch is priced `candidates × this`.
/// Calibrated so DeepLabV3's ws=1 partition lands ~20-30 % above its
/// tuned optimum (Fig 6).
pub const MGMT_COST_MS_PER_CANDIDATE: f64 = 0.006;

/// Per-dispatch scheduling/management overhead for a partition with the
/// given number of candidate subgraphs.
pub fn management_overhead_ms(total_candidates: usize) -> TimeMs {
    total_candidates as f64 * MGMT_COST_MS_PER_CANDIDATE
}

/// Estimated single-model makespan for a partition using a placement DP.
///
/// Units are processed in topological order; `dp[p]` holds the earliest
/// completion time if the most recent unit ran on processor `p`. For
/// branchy graphs this chain approximation upper-bounds the true makespan
/// (no intra-model parallelism), matching how a single inference actually
/// executes in TFLite/Band: one subgraph at a time.
pub fn estimate_chain_latency_ms(g: &Graph, soc: &SocSpec, p: &Partition) -> TimeMs {
    let units = &p.units;
    if units.is_empty() {
        return 0.0;
    }
    let mgmt = management_overhead_ms(p.total_subgraphs);
    let deps = unit_deps(g, units);
    let np = soc.num_processors();
    let inf = f64::INFINITY;
    // completion[u][p]: earliest time unit u finishes if placed on p.
    let mut completion: Vec<Vec<TimeMs>> = vec![vec![inf; np]; units.len()];
    for (ui, u) in units.iter().enumerate() {
        for &proc in &u.support {
            let spec = &soc.processors[proc];
            let exec = match cost::subgraph_latency_ms(g, &u.ops, spec, 1.0) {
                Some(t) => t,
                None => continue,
            };
            // Earliest start: all deps done, including transfer when a dep
            // ran on a different processor (take each dep's best case).
            let mut start: TimeMs = 0.0;
            for &d in &deps[ui] {
                let mut best = inf;
                for (dp, &dc) in completion[d].iter().enumerate() {
                    if dc == inf {
                        continue;
                    }
                    let bytes = inter_unit_bytes(g, units, d, ui);
                    let t = dc + cost::transfer_ms(soc, dp, proc, bytes);
                    best = best.min(t);
                }
                start = start.max(best);
            }
            completion[ui][proc] = start + exec + mgmt;
        }
    }
    // Makespan: all sink units complete.
    let mut sinks: Vec<usize> = Vec::new();
    let mut has_consumer = vec![false; units.len()];
    for ds in &deps {
        for &d in ds {
            has_consumer[d] = true;
        }
    }
    for ui in 0..units.len() {
        if !has_consumer[ui] {
            sinks.push(ui);
        }
    }
    sinks
        .iter()
        .map(|&ui| {
            completion[ui]
                .iter()
                .copied()
                .fold(inf, f64::min)
        })
        .fold(0.0, f64::max)
}

/// The tuned `(model, soc) → window_size` store, plus the sweep trace for
/// Fig 6 reproduction.
#[derive(Debug, Clone, Default)]
pub struct TunedConfig {
    tuned: BTreeMap<(String, String), usize>,
}

/// One point of the window-size sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub window_size: usize,
    pub units: usize,
    pub merged: usize,
    pub total: usize,
    pub est_latency_ms: TimeMs,
}

/// Sweep window sizes and return the per-ws trace (Fig 6's series).
pub fn sweep_window_sizes(g: &Graph, soc: &SocSpec, max_ws: usize) -> Vec<SweepPoint> {
    (1..=max_ws)
        .map(|ws| {
            let p = partition(g, soc, ws);
            SweepPoint {
                window_size: ws,
                units: p.units.len(),
                merged: p.merged_candidates,
                total: p.total_subgraphs,
                est_latency_ms: estimate_chain_latency_ms(g, soc, &p),
            }
        })
        .collect()
}

/// Memoized tuning result. The sweep is a pure function of (model, SoC,
/// `max_ws`), and every serving run re-tunes the same model-SoC pairs —
/// the paper itself stores tuned window sizes in a configuration file
/// (§3.2), so a process-wide cache keyed like [`TunedConfig`] — plus the
/// structural fingerprints of *both* the graph and the SoC, so neither
/// same-name graphs with different structure nor same-name custom SoC
/// definitions can ever share a tuning — only makes that store implicit.
/// `Arc` keeps cache hits to a pointer clone.
static TUNE_CACHE: Memo<(String, u64, String, u64, usize), Arc<(usize, Vec<SweepPoint>)>> =
    Memo::new();

/// Entries currently resident in the tuning memo (see [`tune_cached`]) —
/// reported by `adms bench` alongside the plan-memo occupancy.
pub fn tune_cache_len() -> usize {
    TUNE_CACHE.len()
}

fn tune_cached(g: &Graph, soc: &SocSpec, max_ws: usize) -> Arc<(usize, Vec<SweepPoint>)> {
    let key = (
        g.name.clone(),
        g.fingerprint(),
        soc.name.clone(),
        soc.fingerprint(),
        max_ws,
    );
    TUNE_CACHE.get_or_insert_with(key, || {
        let sweep = sweep_window_sizes(g, soc, max_ws);
        let best = sweep
            .iter()
            .min_by(|a, b| {
                a.est_latency_ms
                    .partial_cmp(&b.est_latency_ms)
                    .unwrap()
                    .then(a.window_size.cmp(&b.window_size))
            })
            .map(|p| p.window_size)
            .unwrap_or(1);
        Arc::new((best, sweep))
    })
}

/// Pick the latency-minimizing window size (ties go to the smaller ws,
/// preserving scheduling flexibility). Memoized — see [`tune_cached`].
pub fn tune_window_size(g: &Graph, soc: &SocSpec, max_ws: usize) -> (usize, Vec<SweepPoint>) {
    let hit = tune_cached(g, soc, max_ws);
    (hit.0, hit.1.clone())
}

/// Just the tuned window size, without cloning the sweep out of the
/// cache — the serving paths only need this.
pub fn tuned_window_size(g: &Graph, soc: &SocSpec, max_ws: usize) -> usize {
    tune_cached(g, soc, max_ws).0
}

/// Multi-point tuning for adaptive re-partitioning: the granularity
/// ladder a `PlanSet` is built from. Three anchor points from the same
/// memoized sweep:
///
/// - **fine** — ws = 1, the maximally spreadable partition (most units,
///   most scheduling freedom, most management overhead);
/// - **medium** — the single-model optimum [`tuned_window_size`] picks;
/// - **coarse** — the smallest window reaching the sweep's minimum unit
///   count (minimum management overhead; larger windows past that point
///   only re-merge the same units).
///
/// Returned ascending and deduped (for a model whose tuned optimum is
/// already ws = 1 the ladder may collapse to fewer than three rungs).
pub fn tune_plan_set(g: &Graph, soc: &SocSpec, max_ws: usize) -> Vec<usize> {
    let hit = tune_cached(g, soc, max_ws);
    let (best, sweep) = (hit.0, &hit.1);
    let min_units = sweep.iter().map(|p| p.units).min().unwrap_or(1);
    let coarse = sweep
        .iter()
        .find(|p| p.units == min_units)
        .map(|p| p.window_size)
        .unwrap_or(best);
    let mut ws = vec![1, best, coarse];
    ws.sort_unstable();
    ws.dedup();
    ws
}

impl TunedConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Tune (or fetch the cached) window size for a model-SoC pair.
    pub fn get_or_tune(&mut self, g: &Graph, soc: &SocSpec) -> usize {
        let key = (g.name.clone(), soc.name.clone());
        if let Some(&ws) = self.tuned.get(&key) {
            return ws;
        }
        let (ws, _) = tune_window_size(g, soc, 12);
        self.tuned.insert(key, ws);
        ws
    }

    pub fn insert(&mut self, model: &str, soc: &str, ws: usize) {
        self.tuned.insert((model.to_string(), soc.to_string()), ws);
    }

    pub fn len(&self) -> usize {
        self.tuned.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tuned.is_empty()
    }

    /// Serialize to JSON (persisted next to the artifacts).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut obj = std::collections::BTreeMap::new();
        for ((m, s), ws) in &self.tuned {
            obj.insert(format!("{m}/{s}"), Json::Num(*ws as f64));
        }
        Json::Obj(obj)
    }

    /// Parse the persisted store. Malformed entries are a hard error, not
    /// a skip: a tuning file that silently loses entries re-tunes (or
    /// mis-tunes) at runtime with no visible symptom, which is exactly
    /// the failure mode a persisted config exists to prevent.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        use anyhow::{anyhow, bail};
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow!("tuned config: expected a JSON object"))?;
        let mut cfg = TunedConfig::new();
        for (k, v) in obj {
            let (m, s) = k
                .split_once('/')
                .ok_or_else(|| anyhow!("tuned config: key {k:?} is not \"model/soc\""))?;
            if m.is_empty() || s.is_empty() {
                bail!("tuned config: key {k:?} has an empty model or soc name");
            }
            let ws = v
                .as_u64()
                .ok_or_else(|| anyhow!("tuned config: {k:?} has a non-integer window size"))?;
            if ws == 0 {
                bail!("tuned config: {k:?} has window size 0 (must be >= 1)");
            }
            cfg.insert(m, s, ws as usize);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::dimensity9000;
    use crate::zoo;

    #[test]
    fn chain_latency_positive_and_finite() {
        let soc = dimensity9000();
        for g in zoo::all_models() {
            let p = partition(&g, &soc, 4);
            let t = estimate_chain_latency_ms(&g, &soc, &p);
            assert!(t.is_finite() && t > 0.0, "{}: latency {t}", g.name);
        }
    }

    #[test]
    fn fig6_shape_latency_improves_then_saturates_or_worsens() {
        // Paper Fig 6 (DeepLabV3 on Redmi K50 Pro): increasing ws first
        // cuts latency (fewer subgraphs, less overhead), then very large
        // ws hurts (everything folds back to the CPU).
        let soc = dimensity9000();
        let g = zoo::deeplab_v3();
        let sweep = sweep_window_sizes(&g, &soc, 40);
        let ws1 = sweep[0].est_latency_ms;
        let best = sweep.iter().map(|p| p.est_latency_ms).fold(f64::INFINITY, f64::min);
        let last = sweep.last().unwrap().est_latency_ms;
        assert!(best < ws1, "no improvement over ws=1: best {best} vs {ws1}");
        assert!(last > best, "latency should degrade at extreme ws");
        // Subgraph count collapses monotonically-ish to a handful of
        // units (paper: "eventually to a single consolidated graph").
        assert!(sweep.last().unwrap().units <= 4);
        assert!(sweep[0].units > sweep.last().unwrap().units);
    }

    #[test]
    fn tuned_ws_in_plausible_band() {
        // Paper: optimal balance around ws = 5 for DeepLabV3 on the Redmi.
        let soc = dimensity9000();
        let g = zoo::deeplab_v3();
        let (ws, _) = tune_window_size(&g, &soc, 12);
        assert!((2..=12).contains(&ws), "tuned ws={ws}");
    }

    #[test]
    fn config_caches_and_roundtrips_json() {
        let soc = dimensity9000();
        let g = zoo::mobilenet_v1();
        let mut cfg = TunedConfig::new();
        let ws1 = cfg.get_or_tune(&g, &soc);
        let ws2 = cfg.get_or_tune(&g, &soc);
        assert_eq!(ws1, ws2);
        assert_eq!(cfg.len(), 1);
        let j = cfg.to_json();
        let cfg2 = TunedConfig::from_json(&j).unwrap();
        assert_eq!(cfg2.len(), 1);
        let mut cfg2 = cfg2;
        assert_eq!(cfg2.get_or_tune(&g, &soc), ws1);
    }

    /// Round trip `to_json` → `from_json` over randomized stores: every
    /// entry survives with its window size intact.
    #[test]
    fn prop_tuned_config_json_roundtrip() {
        use crate::testing::prop::{check, iters};
        check("TunedConfig JSON roundtrip", iters(200), |g| {
            let mut cfg = TunedConfig::new();
            let n = g.usize(0..12);
            for i in 0..n {
                let model = format!("model_{}", g.usize(0..8));
                let soc = format!("soc_{}", g.usize(0..4));
                let ws = g.usize(1..40);
                cfg.insert(&model, &soc, ws);
                let _ = i;
            }
            let text = cfg.to_json().to_string();
            let back =
                TunedConfig::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.len(), cfg.len());
            assert_eq!(back.to_json().to_string(), text, "roundtrip changed the store");
        });
    }

    /// Malformed entries must be rejected loudly, not skipped — a store
    /// that silently loses entries mis-tunes at runtime with no symptom.
    #[test]
    fn from_json_rejects_malformed_entries() {
        use crate::util::json::parse;
        for bad in [
            r#"[1,2,3]"#,                         // not an object
            r#"{"no_slash_key": 4}"#,             // key missing model/soc split
            r#"{"/soc": 4}"#,                     // empty model name
            r#"{"model/": 4}"#,                   // empty soc name
            r#"{"m/s": "four"}"#,                 // non-numeric window
            r#"{"m/s": 0}"#,                      // zero window
            r#"{"ok/soc": 3, "broken": 4}"#,      // one bad entry poisons the store
        ] {
            let j = parse(bad).unwrap();
            assert!(
                TunedConfig::from_json(&j).is_err(),
                "malformed store accepted: {bad}"
            );
        }
    }

    #[test]
    fn plan_set_ladder_is_sorted_and_anchored() {
        let soc = dimensity9000();
        for g in [zoo::deeplab_v3(), zoo::mobilenet_v1(), zoo::inception_v4()] {
            let ladder = tune_plan_set(&g, &soc, 12);
            assert!(!ladder.is_empty() && ladder.len() <= 3, "{}: {ladder:?}", g.name);
            assert!(ladder.windows(2).all(|w| w[0] < w[1]), "{}: {ladder:?}", g.name);
            assert_eq!(ladder[0], 1, "{}: fine rung must be ws=1", g.name);
            let tuned = tuned_window_size(&g, &soc, 12);
            assert!(ladder.contains(&tuned), "{}: tuned ws {tuned} not in {ladder:?}", g.name);
        }
    }
}
