//! Wall-clock serving coordinator: the legacy Layer-3 request path.
//!
//! This module predates the unified execution core: it fans a fixed batch
//! of requests over a round-robin worker pool with no scheduler, no
//! [`ModelPlan`](crate::sched::ModelPlan)s, and no SLOs. The
//! scheduler-driven replacement is [`crate::exec::Server`] with the
//! thread-pool backend (`adms serve`); what remains here is the numerics
//! probe path — replaying the AOT manifest probe through the staged
//! pipeline and verifying every response against the fused-model logits —
//! plus the generic pipeline executor it is built on.

use crate::runtime::{ArtifactSet, StageExec};
use crate::util::stats::Summary;
use anyhow::{anyhow, Result};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads (simulated processors).
    pub workers: usize,
    /// Total requests to serve.
    pub requests: usize,
    /// Verify each response against the expected logits (when the
    /// workload replays the manifest probe input).
    pub verify: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, requests: 64, verify: true }
    }
}

/// Serving results. Every request lands in exactly one of `completed`,
/// `errors`, or `verify_failures` — [`ServeReport::accounting_consistent`]
/// checks the invariant.
#[derive(Debug)]
pub struct ServeReport {
    /// Requests submitted.
    pub requests: u64,
    pub completed: u64,
    pub errors: u64,
    pub verify_failures: u64,
    /// End-to-end request latency (ms), completed requests only.
    pub latency: Summary,
    /// Requests per second over the serving window.
    pub throughput_rps: f64,
    pub wall_ms: f64,
    pub workers: usize,
}

impl ServeReport {
    /// Per-request accounting must partition the request set.
    pub fn accounting_consistent(&self) -> bool {
        self.completed + self.errors + self.verify_failures == self.requests
    }
}

/// One in-flight request: an input tensor and its (optional) expected
/// output for verification.
#[derive(Clone)]
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub expected: Option<Vec<f32>>,
}

/// How one request ended. Exactly one outcome per request, regardless of
/// how many stages it traversed before failing.
enum Outcome {
    Completed { latency_ms: f64 },
    StageError,
    VerifyMismatch,
}

/// Execute one request through the stage pipeline and classify it.
fn process_request<S: StageExec + ?Sized>(stages: &[Arc<S>], req: &Request) -> Outcome {
    let start = Instant::now();
    let mut buf = req.input.clone();
    for stage in stages {
        match stage.execute_f32(&buf) {
            Ok(out) => buf = out,
            Err(e) => {
                log::warn!("request {} stage '{}': {e:#}", req.id, stage.stage_name());
                return Outcome::StageError;
            }
        }
    }
    if let Some(exp) = &req.expected {
        let close = exp.len() == buf.len()
            && exp
                .iter()
                .zip(&buf)
                .all(|(a, b)| (a - b).abs() <= 1e-4 + 1e-4 * a.abs().max(b.abs()));
        if !close {
            return Outcome::VerifyMismatch;
        }
    }
    Outcome::Completed { latency_ms: start.elapsed().as_secs_f64() * 1e3 }
}

/// Serve `cfg.requests` copies of the manifest probe input through the
/// staged pipeline (stem → body → head) on a pool of worker threads.
/// Every response is checked against the fused-model logits exported at
/// AOT time, proving the three layers compose with real numerics.
#[deprecated(
    since = "0.2.0",
    note = "use exec::Server with run_threadpool() for scheduler-driven serving; \
            serve_probe remains only as the AOT numerics probe (see CHANGES.md)"
)]
pub fn serve_probe(artifacts: &ArtifactSet, cfg: &ServeConfig) -> Result<ServeReport> {
    let probe = artifacts
        .probe
        .as_ref()
        .ok_or_else(|| anyhow!("manifest has no probe"))?;
    let stages = artifacts.pipeline_stages()?;
    anyhow::ensure!(!stages.is_empty(), "empty pipeline");
    let requests: Vec<Request> = (0..cfg.requests as u64)
        .map(|id| Request {
            id,
            input: probe.input.clone(),
            expected: if cfg.verify { Some(probe.expected_logits.clone()) } else { None },
        })
        .collect();
    serve(&stages, requests, cfg.workers)
}

/// Generic pipeline serving: execute each request through `stages` in
/// order, spread across `workers` threads. Accounting is per-request:
/// a request that fails mid-pipeline counts exactly one error, and
/// `completed + errors + verify_failures == requests` always holds.
pub fn serve<S: StageExec + ?Sized>(
    stages: &[Arc<S>],
    requests: Vec<Request>,
    workers: usize,
) -> Result<ServeReport> {
    let workers = workers.max(1);
    let (tx, rx) = mpsc::channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    let tally = Arc::new(Mutex::new((0u64, 0u64, 0u64, Summary::new())));

    let n = requests.len() as u64;
    for r in requests {
        tx.send(r).expect("queue send");
    }
    drop(tx);

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let tally = Arc::clone(&tally);
            let stages = stages.to_vec();
            scope.spawn(move || loop {
                let req = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(req) = req else { break };
                let outcome = process_request(&stages, &req);
                let mut t = tally.lock().unwrap();
                match outcome {
                    Outcome::Completed { latency_ms } => {
                        t.0 += 1;
                        t.3.add(latency_ms);
                    }
                    Outcome::StageError => t.1 += 1,
                    Outcome::VerifyMismatch => t.2 += 1,
                }
            });
        }
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let (completed, errors, verify_failures, latency) = {
        let t = tally.lock().unwrap();
        (t.0, t.1, t.2, t.3.clone())
    };
    let report = ServeReport {
        requests: n,
        completed,
        errors,
        verify_failures,
        latency,
        throughput_rps: n as f64 / (wall_ms / 1e3),
        wall_ms,
        workers,
    };
    debug_assert!(report.accounting_consistent());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock stage: doubles the input, errors when `input[0] < 0`.
    struct MockStage {
        name: String,
    }
    impl StageExec for MockStage {
        fn stage_name(&self) -> &str {
            &self.name
        }
        fn execute_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
            anyhow::ensure!(
                input.first().copied().unwrap_or(0.0) >= 0.0,
                "poisoned input"
            );
            Ok(input.iter().map(|v| v * 2.0).collect())
        }
    }

    fn mock_pipeline(n: usize) -> Vec<Arc<dyn StageExec>> {
        (0..n)
            .map(|i| {
                Arc::new(MockStage { name: format!("stage{i}") }) as Arc<dyn StageExec>
            })
            .collect()
    }

    /// A request failing mid-pipeline counts exactly one error (not one
    /// per traversed stage), verify mismatches count once, and the three
    /// buckets partition the request set.
    #[test]
    fn per_request_accounting_partitions_requests() {
        let stages = mock_pipeline(3); // 3 stages → ×8
        let mut requests = Vec::new();
        for id in 0..12u64 {
            let (input, expected) = match id % 3 {
                0 => (vec![1.0f32], Some(vec![8.0f32])), // completes
                1 => (vec![-1.0f32], Some(vec![8.0f32])), // stage error
                _ => (vec![1.0f32], Some(vec![999.0f32])), // verify mismatch
            };
            requests.push(Request { id, input, expected });
        }
        let report = serve(&stages, requests, 4).unwrap();
        assert_eq!(report.requests, 12);
        assert_eq!(report.completed, 4);
        assert_eq!(report.errors, 4, "one error per failing request, not per stage");
        assert_eq!(report.verify_failures, 4);
        assert!(report.accounting_consistent());
        // Latency recorded only for completed requests.
        assert_eq!(report.latency.count(), report.completed);
    }

    #[test]
    fn unverified_requests_complete() {
        let stages = mock_pipeline(2);
        let requests: Vec<Request> = (0..5)
            .map(|id| Request { id, input: vec![2.0], expected: None })
            .collect();
        let report = serve(&stages, requests, 2).unwrap();
        assert_eq!(report.completed, 5);
        assert_eq!(report.errors + report.verify_failures, 0);
        assert!(report.accounting_consistent());
        assert!(report.throughput_rps > 0.0);
    }
}
