//! Wall-clock serving coordinator: the Layer-3 request path.
//!
//! Where [`crate::sim`] reproduces the paper's *evaluation* against the
//! calibrated SoC model, this module is the real serving runtime: it
//! loads the AOT-compiled HLO stages ([`crate::runtime`]), fans requests
//! out to a pool of worker threads (the "processors"), executes each
//! request's stage pipeline through PJRT, and reports latency and
//! throughput. Python never runs here.

use crate::runtime::{ArtifactSet, Stage};
use crate::util::stats::Summary;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads (simulated processors).
    pub workers: usize,
    /// Total requests to serve.
    pub requests: usize,
    /// Verify each response against the expected logits (when the
    /// workload replays the manifest probe input).
    pub verify: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, requests: 64, verify: true }
    }
}

/// Serving results.
#[derive(Debug)]
pub struct ServeReport {
    pub completed: u64,
    pub errors: u64,
    pub verify_failures: u64,
    /// End-to-end request latency (ms).
    pub latency: Summary,
    /// Requests per second over the serving window.
    pub throughput_rps: f64,
    pub wall_ms: f64,
    pub workers: usize,
}

/// One in-flight request: an input tensor and its (optional) expected
/// output for verification.
#[derive(Clone)]
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub expected: Option<Vec<f32>>,
}

/// Serve `cfg.requests` copies of the manifest probe input through the
/// staged pipeline (stem → body → head) on a pool of worker threads.
/// Every response is checked against the fused-model logits exported at
/// AOT time, proving the three layers compose with real numerics.
pub fn serve_probe(artifacts: &ArtifactSet, cfg: &ServeConfig) -> Result<ServeReport> {
    let probe = artifacts
        .probe
        .as_ref()
        .ok_or_else(|| anyhow!("manifest has no probe"))?;
    let stages = artifacts.pipeline_stages()?;
    anyhow::ensure!(!stages.is_empty(), "empty pipeline");
    let requests: Vec<Request> = (0..cfg.requests as u64)
        .map(|id| Request {
            id,
            input: probe.input.clone(),
            expected: if cfg.verify { Some(probe.expected_logits.clone()) } else { None },
        })
        .collect();
    serve(&stages, requests, cfg.workers)
}

/// Generic pipeline serving: execute each request through `stages` in
/// order, spread across `workers` threads.
pub fn serve(stages: &[Arc<Stage>], requests: Vec<Request>, workers: usize) -> Result<ServeReport> {
    let workers = workers.max(1);
    let (tx, rx) = mpsc::channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    let completed = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let verify_failures = Arc::new(AtomicU64::new(0));
    let latencies = Arc::new(Mutex::new(Summary::new()));

    let n = requests.len();
    for r in requests {
        tx.send(r).expect("queue send");
    }
    drop(tx);

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let completed = Arc::clone(&completed);
            let errors = Arc::clone(&errors);
            let verify_failures = Arc::clone(&verify_failures);
            let latencies = Arc::clone(&latencies);
            let stages = stages.to_vec();
            scope.spawn(move || loop {
                let req = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(req) = req else { break };
                let start = Instant::now();
                let mut buf = req.input;
                let mut ok = true;
                for stage in &stages {
                    match stage.execute_f32(&buf) {
                        Ok(out) => buf = out,
                        Err(e) => {
                            log::warn!("request {}: {e}", req.id);
                            errors.fetch_add(1, Ordering::Relaxed);
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    continue;
                }
                if let Some(exp) = &req.expected {
                    let close = exp.len() == buf.len()
                        && exp
                            .iter()
                            .zip(&buf)
                            .all(|(a, b)| (a - b).abs() <= 1e-4 + 1e-4 * a.abs().max(b.abs()));
                    if !close {
                        verify_failures.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                }
                let ms = start.elapsed().as_secs_f64() * 1e3;
                latencies.lock().unwrap().add(ms);
                completed.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    Ok(ServeReport {
        completed: completed.load(Ordering::Relaxed),
        errors: errors.load(Ordering::Relaxed),
        verify_failures: verify_failures.load(Ordering::Relaxed),
        latency: Arc::try_unwrap(latencies)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|arc| arc.lock().unwrap().clone()),
        throughput_rps: n as f64 / (wall_ms / 1e3),
        wall_ms,
        workers,
    })
}
