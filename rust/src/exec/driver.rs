//! The shared scheduling loop: request lifecycle + scheduler invocation,
//! independent of the execution substrate.
//!
//! This is the dispatch loop that used to live inside the discrete-event
//! engine, now driving any [`ExecutionBackend`]: arrivals become per-unit
//! tasks, ready tasks are exposed to the [`Scheduler`] (respecting
//! session serialization), assignments are validated and priced, and
//! completions unlock dependent units until a request retires into the
//! latency/SLO statistics.
//!
//! Since the scenario engine the workload is an *open system*: sessions
//! may be admitted and retired mid-run and may switch arrival processes
//! ([`SessionEvent`]s riding the backend clock as timers). Every arrival
//! timer carries the session's *epoch* — bumped on stop/rate-change — so
//! stale timers from a replaced arrival process are ignored rather than
//! double-driving the session. Conservation holds per session on every
//! run: `issued == completed + failed + cancelled`.
//!
//! Hot-path discipline (see DESIGN.md §3b): the steady-state event loop
//! performs no per-event allocations. Ready tasks live in an indexed
//! [`ReadyQueue`] (O(1)-ish cancellation, recycled `dep_procs` buffers),
//! per-request bookkeeping vectors are pooled, the monitor snapshot is
//! borrowed rather than copied, serialized-session exposure reuses its
//! scratch, and schedulers append into a reusable assignment buffer.

use super::{
    App, ArrivalMode, ArrivalRecord, AssignRecord, DispatchCmd, EventKind, ExecEvent,
    ExecutionBackend, ReadyQueue, RunToken, SessionEvent, SimConfig,
};
use crate::monitor::{HardwareMonitor, Health};
use crate::sched::{
    Assignment, ModelPlan, PendingTask, PlanSet, ReqId, SchedCtx, Scheduler, SessId,
    VariantsView,
};
use crate::sim::report::{ReplanStats, SessionStats, SimReport};
use crate::util::rng::Pcg32;
use crate::util::stats::Summary;
use crate::TimeMs;
use std::collections::HashMap;
use std::sync::Arc;

/// Timer-key namespace: the top bit marks scenario-event timers, the low
/// 32 bits of arrival keys carry the session id and bits 32..62 its
/// epoch. Epochs are wrapped to 31 bits ([`EPOCH_MASK`]) before packing —
/// an unmasked epoch ≥ 2^31 would set bit 63 and collide with
/// [`EVENT_KEY`], turning an arrival timer into a phantom scenario event.
const EVENT_KEY: u64 = 1 << 63;

/// Batch-window poke timer: wakes the dispatch loop when a held
/// batchable task's coalescing window expires. Lives in the
/// [`EVENT_KEY`] namespace at an index (bit 62) no real scenario event
/// list can reach, so it can never be mistaken for an arrival key or a
/// scenario event.
const BATCH_POKE: u64 = EVENT_KEY | (1 << 62);

/// Retry-backoff timer namespace: bit 61 inside the [`EVENT_KEY`]
/// namespace, low bits a per-run retry sequence number. Distinct from
/// [`BATCH_POKE`] (bit 62) and unreachable by real scenario-event indices
/// (an event list would need 2^61 entries), and matched *before* the
/// generic scenario-event arm — the same precedence discipline
/// `BATCH_POKE` established.
const RETRY_KEY: u64 = EVENT_KEY | (1 << 61);

/// Session arrival epochs live in 31 bits (wrap on overflow). The epoch
/// only needs to distinguish a timer's arrival process from the session's
/// *current* one, so 2^31 generations between a timer being armed and
/// fired would be needed to alias — unreachable in practice.
const EPOCH_MASK: u32 = 0x7FFF_FFFF;

/// Bump an epoch, staying inside the 31-bit timer-key field.
fn next_epoch(epoch: u32) -> u32 {
    (epoch + 1) & EPOCH_MASK
}

fn arrival_key(session: SessId, epoch: u32) -> u64 {
    debug_assert!(epoch <= EPOCH_MASK, "epoch must be pre-masked");
    ((epoch as u64) << 32) | session as u64
}

fn decode_arrival(key: u64) -> (SessId, u32) {
    ((key & 0xFFFF_FFFF) as usize, (key >> 32) as u32)
}

/// Per-request bookkeeping.
#[derive(Debug)]
struct ReqState {
    session: SessId,
    arrival: TimeMs,
    slo_ms: Option<f64>,
    /// Arrival epoch the request was issued under (closed-loop re-arms
    /// only while its epoch is still the session's current one).
    epoch: u32,
    deps_remaining: Vec<usize>,
    unit_proc: Vec<Option<usize>>,
    units_left: usize,
    /// Aborted — failed (budget/exec error) or cancelled (session stop /
    /// run end). Units still resident on processors drain silently.
    dead: bool,
    /// Remaining fault/timeout retry budget (starts at
    /// `SimConfig::retry_limit`; only the fault layer consumes it).
    retries_left: u32,
}

/// Recycled `ReqState` vectors: requests arrive and retire on every
/// event in steady state, and these two per-request allocations were the
/// last ones on that path.
#[derive(Default)]
struct ReqStatePool {
    deps: Vec<Vec<usize>>,
    procs: Vec<Vec<Option<usize>>>,
}

impl ReqStatePool {
    fn recycle(&mut self, st: ReqState) {
        self.deps.push(st.deps_remaining);
        self.procs.push(st.unit_proc);
    }
}

/// A dispatched task group the driver is waiting on: the lead's identity
/// plus the non-lead members (empty — and allocation-free — for a
/// single-task dispatch). One backend completion fans out to every
/// member's per-request lifecycle.
#[derive(Debug, Clone)]
struct Inflight {
    req: ReqId,
    session: SessId,
    unit: usize,
    proc: usize,
    extra: Vec<(ReqId, SessId)>,
    /// Dispatch deadline (fault layer with `dispatch_timeout_mult > 0`
    /// only): still inflight past this instant → aborted by the tick
    /// sweep and retried. `None` whenever the deadline sweep is off.
    deadline: Option<TimeMs>,
}

/// Live per-session state (stats + arrival process).
struct Sess {
    app: App,
    started: bool,
    stopped: bool,
    start_ms: TimeMs,
    stop_ms: Option<TimeMs>,
    epoch: u32,
    issued: u64,
    completed: u64,
    failed: u64,
    cancelled: u64,
    /// Failure-reason split: `failed` stays the total, these four
    /// partition it exactly (`failed == budget + exec + faulted +
    /// retries_exhausted` — pinned by the chaos conservation property).
    failed_budget: u64,
    failed_exec: u64,
    faulted: u64,
    retries_exhausted: u64,
    /// Fault/timeout retries granted (audited separately from `issued`:
    /// a retried unit is the same request, not a new one).
    retries: u64,
    lat: Summary,
    slo_ok: u64,
    slo_n: u64,
    /// Cursor into a `Replay` schedule.
    replay_pos: usize,
}

impl Sess {
    fn new(app: App) -> Self {
        Sess {
            app,
            started: false,
            stopped: false,
            start_ms: 0.0,
            stop_ms: None,
            epoch: 0,
            issued: 0,
            completed: 0,
            failed: 0,
            cancelled: 0,
            failed_budget: 0,
            failed_exec: 0,
            faulted: 0,
            retries_exhausted: 0,
            retries: 0,
            lat: Summary::new(),
            slo_ok: 0,
            slo_n: 0,
            replay_pos: 0,
        }
    }

    fn closed_loop(&self) -> bool {
        matches!(self.app.mode, ArrivalMode::ClosedLoop)
    }
}

/// Next inter-arrival gap of the square-wave bursty process: thinning of
/// a Poisson stream at the burst-phase rate, so the gap depends only on
/// the RNG stream and the current clock — deterministic under a fixed
/// seed on the sim backend.
fn bursty_gap(
    rate_rps: f64,
    burst_factor: f64,
    period_ms: f64,
    now: TimeMs,
    rng: &mut Pcg32,
) -> f64 {
    let hi = (rate_rps.max(1e-9) * burst_factor.max(1.0)) / 1e3; // per ms
    let lo = rate_rps.max(1e-9) / 1e3;
    let half = (period_ms / 2.0).max(1e-9);
    let mut t = now;
    for _ in 0..100_000 {
        t += rng.exp(hi);
        let in_burst = ((t / half).floor() as i64).rem_euclid(2) == 0;
        let cur = if in_burst { hi } else { lo };
        if rng.next_f64() < cur / hi {
            break;
        }
    }
    t - now
}

/// Arm a session's next arrival timer at `now`. `restart = false` means
/// an arrival was just issued (closed loop re-arms on completion instead;
/// replay advances its cursor); `restart = true` means the process was
/// just (re)started by a rate change (closed loop seeds exactly one fresh
/// loop — requests of the old epoch no longer re-arm — and replay rescans
/// for the next scheduled time).
fn arm_arrival_timer(
    backend: &mut dyn ExecutionBackend,
    rng: &mut Pcg32,
    s: SessId,
    sess: &mut Sess,
    now: TimeMs,
    restart: bool,
) {
    let key = arrival_key(s, sess.epoch);
    match &sess.app.mode {
        ArrivalMode::ClosedLoop => {
            if restart {
                backend.arm_timer(now, key);
            }
        }
        ArrivalMode::Periodic(p) => backend.arm_timer(now + p, key),
        ArrivalMode::Poisson(rate) => {
            let gap = rng.exp(rate.max(1e-9) / 1e3);
            backend.arm_timer(now + gap, key);
        }
        ArrivalMode::Bursty { rate_rps, burst_factor, period_ms } => {
            let gap = bursty_gap(*rate_rps, *burst_factor, *period_ms, now, rng);
            backend.arm_timer(now + gap, key);
        }
        ArrivalMode::Replay(times) => {
            let times = Arc::clone(times);
            let pos = if restart {
                times.iter().position(|&t| t >= now).unwrap_or(times.len())
            } else {
                sess.replay_pos + 1
            };
            sess.replay_pos = pos;
            if let Some(&t) = times.get(pos) {
                backend.arm_timer(t.max(now), key);
            }
        }
    }
}

/// A dead (failed/cancelled) request stays alive only while units are
/// still resident on processors: clamp its remaining-unit count to
/// `floor` and retire it once nothing is left. `floor` is the backend's
/// `running_units` — plus one in the exec-error path, whose triggering
/// completion is decremented later in the same handler. All three
/// abort sites (session stop, exec error, failure sweep) share this so
/// the conservation invariant has one implementation.
fn clamp_dead_request(
    reqs: &mut HashMap<ReqId, ReqState>,
    id: ReqId,
    floor: usize,
    pool: &mut ReqStatePool,
) {
    if let Some(st) = reqs.get_mut(&id) {
        st.units_left = st.units_left.min(floor);
        if st.units_left == 0 {
            let st = reqs.remove(&id).unwrap();
            pool.recycle(st);
        }
    }
}

/// Re-seed a closed-loop session's arrival at `now` after one of its
/// requests retires or aborts. Fires only when the request belonged to
/// the session's *current* arrival epoch (a rate change must not
/// resurrect the replaced loop), the session is still live, and quota
/// remains — the single predicate all three retirement paths
/// (completion, exec error, failure sweep) share.
fn rearm_closed_loop(
    backend: &mut dyn ExecutionBackend,
    sess: &Sess,
    s: SessId,
    req_epoch: u32,
    quota: u64,
    now: TimeMs,
) {
    if req_epoch == sess.epoch
        && !sess.stopped
        && sess.closed_loop()
        && sess.issued < quota
    {
        backend.arm_timer(now, arrival_key(s, sess.epoch));
    }
}

/// Why a request failed — the reason split `SessionStats` audits
/// (satellite of the fault layer: `failed` alone cannot distinguish "the
/// model was too slow" from "the DSP died under it").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FailReason {
    /// Aged past `fail_mult ×` budget (the pre-existing failure sweep).
    Budget,
    /// Genuine payload execution error (never retried — as before).
    Exec,
    /// Fault/timeout abort with no retry machinery available
    /// (fault-blind, or `retry_limit = 0`).
    Faulted,
    /// Fault/timeout abort after the retry budget was consumed.
    RetriesExhausted,
}

fn fail_session(sess: &mut Sess, reason: FailReason, has_slo: bool) {
    sess.failed += 1;
    match reason {
        FailReason::Budget => sess.failed_budget += 1,
        FailReason::Exec => sess.failed_exec += 1,
        FailReason::Faulted => sess.faulted += 1,
        FailReason::RetriesExhausted => sess.retries_exhausted += 1,
    }
    if has_slo {
        sess.slo_n += 1;
    }
}

/// A fault/timeout-aborted unit waiting out its backoff timer.
#[derive(Debug)]
struct RetryTask {
    req: ReqId,
    session: SessId,
    unit: usize,
}

/// Driver-side fault layer (DESIGN.md §3g). Constructed only when the
/// compiled event list carries processor-fault events or the config's
/// fault knobs are engaged — faults-off runs never allocate it, which is
/// the structural half of the byte-identity no-op argument
/// (`prop_faults_off_is_byte_identical_noop` is the observational half).
struct FaultCtx {
    /// The driver's *belief* per processor — overlaid onto the monitor
    /// snapshot so schedulers react to a crash synchronously instead of
    /// at the cache interval. Backends keep reporting `Up`: they model
    /// hardware, not beliefs.
    health: Vec<Health>,
    /// Deadline at which a `Degraded` (quarantined) processor is trusted
    /// as `Up` again; promoted on the housekeeping tick.
    quarantine_until: Vec<TimeMs>,
    /// Armed transient faults: the next group completion on the
    /// processor is treated as a (retryable) execution error. Injected
    /// driver-side so both backends fail identically.
    transient_pending: Vec<u32>,
    /// Backoff timers armed but not yet fired, keyed by their
    /// `RETRY_KEY | seq` timer key.
    pending_retries: HashMap<u64, RetryTask>,
    retry_seq: u64,
    /// Fault-blind ablation: hardware still fails, but no health is
    /// tracked and nothing is retried.
    blind: bool,
    proc_fails: u64,
    proc_recovers: u64,
    timeouts: u64,
}

impl FaultCtx {
    fn new(nprocs: usize, blind: bool) -> Self {
        FaultCtx {
            health: vec![Health::Up; nprocs],
            quarantine_until: vec![f64::NEG_INFINITY; nprocs],
            transient_pending: vec![0; nprocs],
            pending_retries: Default::default(),
            retry_seq: 0,
            blind,
            proc_fails: 0,
            proc_recovers: 0,
            timeouts: 0,
        }
    }
}

/// EMA smoothing factor for the re-partition controller's pressure
/// signal: heavy enough to ride out single-tick spikes, light enough
/// that a sustained phase change crosses the threshold within a few
/// housekeeping ticks.
const REPLAN_EMA_ALPHA: f64 = 0.3;

/// Adaptive re-partition controller (DESIGN.md §3h). Constructed only
/// when `--adaptive-plan` is engaged AND the server handed over a
/// [`PlanSet`] per session — off runs never allocate it, which is the
/// structural half of the byte-identity no-op argument
/// (`prop_adaptive_off_is_byte_identical_noop` is the observational
/// half). It watches the monitor's pressure signal through an EMA and
/// steps each session's active granularity variant one rung at a time,
/// but only at a *safe boundary*: no request of the session in any
/// lifecycle stage, so every group priced under the old plan has fully
/// retired before unit ids, dep rows, or residency keys change meaning.
struct ReplanCtl {
    /// One granularity ladder per session (fine → coarse).
    sets: Vec<PlanSet>,
    /// Active rung per session (index into `sets[s]`).
    active: Vec<usize>,
    /// Smoothed pressure signal (see the tick handler for the metric).
    ema: f64,
    /// First sample primes the EMA instead of decaying from zero.
    primed: bool,
    /// Last switch instant per session (cooldown gate).
    last_switch: Vec<TimeMs>,
    stats: ReplanStats,
}

/// What happened to one group member in [`abort_member`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MemberAbort {
    /// Re-enqueued behind a backoff timer; the unit will run again.
    Retried,
    /// Marked dead and accounted as failed.
    Failed,
    /// Request unknown or already dead — nothing to do.
    Gone,
}

/// Abort one member of an aborted group: retry it if the fault layer can
/// (retryable abort, health-aware, budget left), otherwise fail it with
/// the right reason. This is the ONE implementation behind all three
/// abort paths — genuine/transient exec errors on completion
/// (`floor_extra = 1`: the triggering completion decrements afterwards),
/// crash aborts and the timeout sweep (`floor_extra = 0`: the backend
/// abort already dropped the unit) — so the two backends cannot drift in
/// their error accounting again (the cross-backend error-path trace test
/// pins this).
#[allow(clippy::too_many_arguments)]
fn abort_member(
    reqs: &mut HashMap<ReqId, ReqState>,
    sess: &mut [Sess],
    ready: &mut ReadyQueue,
    backend: &mut dyn ExecutionBackend,
    pool: &mut ReqStatePool,
    fault: &mut Option<FaultCtx>,
    cfg: &SimConfig,
    quota: u64,
    now: TimeMs,
    m_req: ReqId,
    unit: usize,
    floor_extra: usize,
    retryable: bool,
    reason: FailReason,
) -> MemberAbort {
    let Some(st) = reqs.get_mut(&m_req) else {
        return MemberAbort::Gone;
    };
    if st.dead {
        return MemberAbort::Gone;
    }
    if retryable {
        if let Some(fs) = fault.as_mut() {
            if !fs.blind && st.retries_left > 0 {
                // Attempt index before this consumption: 0 for the first
                // retry, doubling the backoff each attempt after.
                let attempt = cfg.retry_limit.saturating_sub(st.retries_left);
                st.retries_left -= 1;
                let s = st.session;
                sess[s].retries += 1;
                fs.retry_seq += 1;
                let key = RETRY_KEY | fs.retry_seq;
                let backoff =
                    cfg.retry_backoff_ms.max(0.0) * (1u64 << attempt.min(32)) as f64;
                fs.pending_retries.insert(key, RetryTask { req: m_req, session: s, unit });
                backend.arm_timer(now + backoff, key);
                return MemberAbort::Retried;
            }
        }
    }
    // No retry available: fail, with the reason refined by *why* no
    // retry was available.
    let reason = if retryable {
        match fault.as_ref() {
            Some(fs) if !fs.blind && cfg.retry_limit > 0 => FailReason::RetriesExhausted,
            _ => FailReason::Faulted,
        }
    } else {
        reason
    };
    st.dead = true;
    let s = st.session;
    let has_slo = st.slo_ms.is_some();
    let epoch = st.epoch;
    fail_session(&mut sess[s], reason, has_slo);
    ready.cancel_request(m_req);
    let running = backend.running_units(m_req);
    clamp_dead_request(reqs, m_req, running + floor_extra, pool);
    rearm_closed_loop(backend, &sess[s], s, epoch, quota, now);
    MemberAbort::Failed
}

/// Scheduler-driven execution of a multi-session workload on one backend.
pub struct Driver {
    cfg: SimConfig,
    apps: Vec<App>,
    plans: Vec<ModelPlan>,
    scheduler: Box<dyn Scheduler>,
    backend: Box<dyn ExecutionBackend>,
    events: Vec<SessionEvent>,
    plan_sets: Option<(Vec<PlanSet>, Vec<usize>)>,
}

impl Driver {
    pub fn new(
        cfg: SimConfig,
        apps: Vec<App>,
        plans: Vec<ModelPlan>,
        scheduler: Box<dyn Scheduler>,
        backend: Box<dyn ExecutionBackend>,
    ) -> Self {
        assert_eq!(apps.len(), plans.len(), "one plan per session");
        Driver { cfg, apps, plans, scheduler, backend, events: Vec::new(), plan_sets: None }
    }

    /// Attach session-lifecycle events (a compiled scenario). Sessions
    /// referenced by a `Start` event are admitted when it fires; all
    /// other sessions are active from t = 0.
    pub fn events(mut self, events: Vec<SessionEvent>) -> Self {
        self.events = events;
        self
    }

    /// Attach per-session granularity ladders ([`PlanSet`]s) plus the
    /// active rung each session starts on (`plans[s]` must equal
    /// `sets[s].variants[active[s]]`). The re-partition controller only
    /// engages when this is `Some` AND the config enables
    /// `--adaptive-plan` — either alone is inert.
    pub fn plan_sets(mut self, sets: Option<(Vec<PlanSet>, Vec<usize>)>) -> Self {
        self.plan_sets = sets;
        self
    }

    pub fn run(mut self) -> SimReport {
        let napps = self.apps.len();
        let mut rng = Pcg32::seeded(self.cfg.seed);
        let mut monitor = HardwareMonitor::new(self.cfg.monitor_cache_ms);
        let soc = self.backend.soc().clone();

        let mut sess: Vec<Sess> = self.apps.iter().cloned().map(Sess::new).collect();

        // Fault layer (DESIGN.md §3g). A configured fault profile is
        // expanded into ordinary timed events up front — ONE merge
        // point, so lookahead forks, record/replay, and fleet workers
        // all see plain timers riding the same heap as everything else.
        // Appended after the scenario's own events: distinct list
        // indices keep every `EVENT_KEY | i` timer unique.
        if let Some(profile) = self.cfg.fault_profile.clone().filter(|p| !p.is_off()) {
            let fseed = self.cfg.fault_seed.unwrap_or(self.cfg.seed);
            let mut storm =
                crate::faults::plan(&profile, &soc, fseed, self.cfg.duration_ms);
            self.events.append(&mut storm);
        }
        // The layer engages on explicit scenario fault events too, not
        // just config knobs — a `flaky_dsp` scenario needs no flags.
        let fault_events = self.events.iter().any(|e| {
            matches!(
                e.kind,
                EventKind::ProcFail { .. }
                    | EventKind::ProcRecover { .. }
                    | EventKind::ProcTransient { .. }
            )
        });
        let mut fault: Option<FaultCtx> = if fault_events || self.cfg.faults_configured() {
            Some(FaultCtx::new(soc.processors.len(), self.cfg.fault_blind))
        } else {
            None
        };

        // Weight residency (memory-budgeted runs only). With
        // `mem_budget_bytes = 0` no cache is ever constructed, no load
        // latency is ever charged, and the dispatch path is bit-exactly
        // the pre-residency one — the same provable-no-op contract
        // `batch_max = 1` gives batching.
        let mut wcache: Option<crate::weights::WeightCache> =
            if self.cfg.mem_budget_bytes > 0 {
                let manifests = self
                    .plans
                    .iter()
                    .map(crate::weights::ShardManifest::from_plan)
                    .collect();
                Some(crate::weights::WeightCache::new(
                    &soc,
                    self.cfg.mem_budget_bytes,
                    self.cfg.mem_policy,
                    manifests,
                ))
            } else {
                None
            };

        // Batching (group dispatch) configuration. With `batch_max = 1`
        // every batching structure below is inert and the dispatch path
        // is bit-exactly the pre-batching one.
        let batch_max = self.cfg.batch_max.max(1);
        let batching = batch_max > 1;
        let batch_window = self.cfg.batch_window_ms.max(0.0);
        // Per-session coalescing kind: graph fingerprint mixed with the
        // plan's window size. Sessions with equal kinds run the same
        // model *at the same granularity* — unit ids only line up (and
        // fused groups only share a shard) when both agree. On static
        // runs this partitions sessions exactly like the bare graph
        // fingerprint did (same model ⇒ same window size), so batching
        // behavior is unchanged; under adaptive re-partitioning it keeps
        // a switched session out of its unswitched siblings' groups.
        let mut sess_kinds: Vec<u64> =
            self.plans.iter().map(|p| p.coalesce_kind()).collect();
        // Whether a session has at least one same-kind sibling — only
        // then can a coalescing window ever pay off (a unique model waits
        // for peers that cannot exist). Recomputed on a granularity
        // switch (kinds change with the active variant).
        let mut kind_multi: Vec<bool> = sess_kinds
            .iter()
            .enumerate()
            .map(|(i, k)| sess_kinds.iter().enumerate().any(|(j, k2)| j != i && k2 == k))
            .collect();

        // Adaptive re-partition controller (DESIGN.md §3h): engaged only
        // when the config asks for it AND the server built granularity
        // ladders. `--adaptive-plan off` never constructs it, so the
        // whole layer is a provable no-op by construction.
        let mut replan: Option<ReplanCtl> = if self.cfg.adaptive_configured() {
            self.plan_sets.take().map(|(sets, active)| ReplanCtl {
                last_switch: vec![f64::NEG_INFINITY; napps],
                sets,
                active,
                ema: 0.0,
                primed: false,
                stats: ReplanStats::default(),
            })
        } else {
            None
        };

        // Request state.
        let mut reqs: HashMap<ReqId, ReqState> = Default::default();
        let mut pool = ReqStatePool::default();
        let mut next_req: ReqId = 0;
        let mut ready = if batching {
            ReadyQueue::with_kinds(sess_kinds.clone())
        } else {
            ReadyQueue::new(napps)
        };
        let mut run_seq: RunToken = 0;
        let mut inflight: HashMap<RunToken, Inflight> = Default::default();
        let mut assignments_trace: Vec<AssignRecord> = Vec::new();
        let mut arrivals_trace: Vec<ArrivalRecord> = Vec::new();

        // Reusable hot-path scratch (see module docs): none of these
        // allocate in steady state.
        let mut sched_out: Vec<Assignment> = Vec::new();
        let mut dispatched: Vec<usize> = Vec::new();
        let mut taken_stamp: Vec<u64> = Vec::new();
        let mut round: u64 = 0;
        let mut first_by_sess: Vec<(u32, u32)> = vec![(u32::MAX, u32::MAX); napps];
        let mut exposed_idx: Vec<usize> = Vec::new();
        let mut exposed_tasks: Vec<PendingTask> = Vec::new();
        let mut aborted: Vec<ReqId> = Vec::new();
        let mut open_scratch: Vec<ReqId> = Vec::new();
        // Fault-layer scratch (touched only when the layer is active).
        let mut overdue: Vec<RunToken> = Vec::new();
        // Batching scratch (touched only when `batching`).
        let mut cand_kinds: Vec<u64> = Vec::new();
        let mut cand_taken: Vec<bool> = Vec::new();
        let mut member_cand: Vec<usize> = Vec::new();
        let mut peer_scratch: Vec<u32> = Vec::new();
        let mut fanout: Vec<(ReqId, SessId)> = Vec::new();
        // Deadlines (f64 bits) of currently-armed BATCH_POKE timers, so a
        // group held across many dispatch rounds arms one poke per
        // deadline instead of one per round. Entries retire as the clock
        // passes them (see the BATCH_POKE handler).
        let mut armed_pokes: Vec<u64> = Vec::new();
        // Lookahead scratch (touched only when rollouts are active).
        let mut cand_procs: Vec<usize> = Vec::new();
        // Persistent scratch slot for lookahead rollout forks: the first
        // candidate of the run pays one deep clone, every later candidate
        // restores the same backend in place (`fork_into` →
        // `SimBackend::restore`), recycling the snapshot's allocations
        // across candidates AND decisions for the whole run.
        let mut rollout_scratch: Option<Box<dyn ExecutionBackend>> = None;

        let quota = self.cfg.max_requests.unwrap_or(u64::MAX);

        // Sim-in-the-loop lookahead (DESIGN.md §3f): active only when the
        // policy advertises rollout params (the `lookahead` wrapper).
        // Degenerate configurations never get here — the server builds
        // the bare base policy for horizon 0 / beam ≤ 1 — but filter
        // defensively so a hand-built wrapper cannot reach the rollout
        // path with parameters that could never discriminate.
        let rollout = self
            .scheduler
            .rollout_params()
            .filter(|r| r.horizon > 0 && r.beam > 1);

        // Scenario events ride the backend clock as timers. Only pending
        // `Start` events can create new work, so only they keep a
        // quota-bounded run alive.
        let mut pending_starts = 0usize;
        let mut late_start = vec![false; napps];
        for (i, ev) in self.events.iter().enumerate() {
            if let EventKind::Start { session } = ev.kind {
                if session < napps {
                    late_start[session] = true;
                }
                pending_starts += 1;
            }
            self.backend.arm_timer(ev.at_ms, EVENT_KEY | i as u64);
        }
        // Prime arrivals of the statically-admitted sessions (the backend
        // arms its own housekeeping tick).
        for s in 0..napps {
            if !late_start[s] {
                sess[s].started = true;
                if let Some(t0) = sess[s].app.mode.first_arrival(0.0) {
                    self.backend.arm_timer(t0, arrival_key(s, 0));
                }
            }
        }

        let debug = crate::util::env::sim_debug();
        let mut n_events: u64 = 0;
        let mut last_now: TimeMs = 0.0;
        loop {
            let ev = self.backend.next_event();
            let now = ev.at();
            if now > self.cfg.duration_ms {
                break;
            }
            last_now = now;
            n_events += 1;
            if debug && n_events % 2_000 == 0 {
                eprintln!(
                    "t={now:.0} events={n_events} ready={} reqs={} inflight={}",
                    ready.len(),
                    reqs.len(),
                    inflight.len()
                );
            }
            // Whether to give the scheduler a chance after this event.
            let mut dispatch_after = true;
            match ev {
                ExecEvent::Drained { .. } => break,
                ExecEvent::Timer { key, .. } if key == BATCH_POKE => {
                    // A held batchable task's coalescing window expired:
                    // retire every poke deadline the clock has reached
                    // (so a future hold at the same instant can re-arm),
                    // then give the scheduler a round — the hold
                    // predicate is now false for the expired task.
                    armed_pokes.retain(|&bits| f64::from_bits(bits) > now);
                }
                ExecEvent::Timer { key, .. } if key & RETRY_KEY == RETRY_KEY => {
                    // A backoff timer fired: re-enqueue the aborted unit
                    // if its request is still worth running. A request
                    // that was budget-failed, cancelled, or whose session
                    // stopped while the timer was pending is simply left
                    // alone (its own abort path already accounted it).
                    let task =
                        fault.as_mut().and_then(|fs| fs.pending_retries.remove(&key));
                    let alive = task.as_ref().is_some_and(|rt| {
                        !sess[rt.session].stopped
                            && reqs.get(&rt.req).is_some_and(|st| !st.dead)
                    });
                    match task {
                        Some(rt) if alive => {
                            let plan = &self.plans[rt.session];
                            let st = &reqs[&rt.req];
                            let nu = plan.num_units();
                            let mut dep_procs = ready.take_deps_buf();
                            // Every dependency of a once-ready unit has a
                            // recorded placement; the fallback is purely
                            // defensive (and deterministic).
                            dep_procs.extend(
                                plan.deps[rt.unit]
                                    .iter()
                                    .map(|&d| (d, st.unit_proc[d].unwrap_or(0))),
                            );
                            let remaining = plan.remaining_ms((0..nu).filter(|&u| {
                                u != rt.unit && st.unit_proc[u].is_none()
                            }));
                            ready.push(PendingTask {
                                req: rt.req,
                                session: rt.session,
                                unit: rt.unit,
                                ready_at: now,
                                req_arrival: st.arrival,
                                slo_ms: st.slo_ms,
                                remaining_ms: remaining,
                                dep_procs,
                            });
                        }
                        _ => {
                            dispatch_after = false;
                        }
                    }
                }
                ExecEvent::Timer { key, .. } if key & EVENT_KEY != 0 => {
                    let idx = (key & !EVENT_KEY) as usize;
                    let Some(tev) = self.events.get(idx).cloned() else {
                        continue;
                    };
                    match tev.kind {
                        EventKind::Start { session: s } => {
                            pending_starts = pending_starts.saturating_sub(1);
                            if s < napps && !sess[s].started && !sess[s].stopped {
                                sess[s].started = true;
                                sess[s].start_ms = now;
                                if let Some(t0) = sess[s].app.mode.first_arrival(now) {
                                    let key = arrival_key(s, sess[s].epoch);
                                    self.backend.arm_timer(t0, key);
                                }
                            }
                        }
                        EventKind::Stop { session: s } => {
                            if s < napps && sess[s].started && !sess[s].stopped {
                                sess[s].stopped = true;
                                sess[s].stop_ms = Some(now);
                                sess[s].epoch = next_epoch(sess[s].epoch);
                                // Cancel pending work deterministically:
                                // drop ready entries (indexed — no queue
                                // scan), abort open requests in id order;
                                // inflight units drain.
                                ready.cancel_session(s);
                                open_scratch.clear();
                                open_scratch.extend(
                                    reqs.iter()
                                        .filter(|(_, st)| st.session == s && !st.dead)
                                        .map(|(&id, _)| id),
                                );
                                open_scratch.sort_unstable();
                                for &id in open_scratch.iter() {
                                    sess[s].cancelled += 1;
                                    let running = self.backend.running_units(id);
                                    reqs.get_mut(&id).unwrap().dead = true;
                                    clamp_dead_request(&mut reqs, id, running, &mut pool);
                                }
                            }
                        }
                        EventKind::Rate { session: s, mode } => {
                            if s < napps && !sess[s].stopped {
                                sess[s].epoch = next_epoch(sess[s].epoch);
                                sess[s].app.mode = mode;
                                if sess[s].started {
                                    arm_arrival_timer(
                                        self.backend.as_mut(),
                                        &mut rng,
                                        s,
                                        &mut sess[s],
                                        now,
                                        true,
                                    );
                                }
                            }
                        }
                        EventKind::ProcFail { proc: p, hang } => {
                            // Out-of-range processors are ignored so fault
                            // scenarios stay SoC-portable (an NPU blackout
                            // is vacuous on a 3-processor chip).
                            if p < soc.processors.len() {
                                if let Some(fs) = fault.as_mut() {
                                    fs.proc_fails += 1;
                                    if !fs.blind {
                                        fs.health[p] = Health::Down;
                                    }
                                }
                                self.backend.set_proc_down(p, true);
                                // The dead processor's resident weights are
                                // gone with its driver context.
                                if let Some(c) = wcache.as_mut() {
                                    c.purge_proc(p);
                                }
                                // Resident groups, in token (dispatch)
                                // order for a deterministic abort sequence.
                                overdue.clear();
                                overdue.extend(
                                    inflight
                                        .iter()
                                        .filter(|(_, f)| f.proc == p)
                                        .map(|(&t, _)| t),
                                );
                                overdue.sort_unstable();
                                for i in 0..overdue.len() {
                                    let tk = overdue[i];
                                    // Free the slot and suppress the
                                    // completion on the backend either way.
                                    self.backend.abort(tk);
                                    if hang {
                                        // A hung group stays in the
                                        // driver's books — exactly how a
                                        // wedged vendor driver presents.
                                        // The timeout sweep (or run-end
                                        // cancellation) pays for it.
                                        continue;
                                    }
                                    let done = inflight.remove(&tk).unwrap();
                                    fanout.clear();
                                    fanout.push((done.req, done.session));
                                    fanout.extend(done.extra.iter().copied());
                                    for &(m_req, _) in fanout.iter() {
                                        abort_member(
                                            &mut reqs,
                                            &mut sess,
                                            &mut ready,
                                            self.backend.as_mut(),
                                            &mut pool,
                                            &mut fault,
                                            &self.cfg,
                                            quota,
                                            now,
                                            m_req,
                                            done.unit,
                                            0,
                                            true,
                                            FailReason::Faulted,
                                        );
                                    }
                                }
                            }
                        }
                        EventKind::ProcRecover { proc: p } => {
                            if p < soc.processors.len() {
                                if let Some(fs) = fault.as_mut() {
                                    fs.proc_recovers += 1;
                                    // Quarantine-and-probe: schedulable
                                    // again, but Degraded (re-priced) until
                                    // it has stayed up for the quarantine.
                                    if !fs.blind && fs.health[p] == Health::Down {
                                        fs.health[p] = Health::Degraded;
                                        fs.quarantine_until[p] =
                                            now + self.cfg.fault_quarantine_ms.max(0.0);
                                    }
                                }
                                self.backend.set_proc_down(p, false);
                            }
                        }
                        EventKind::ProcTransient { proc: p } => {
                            if p < soc.processors.len() {
                                if let Some(fs) = fault.as_mut() {
                                    fs.transient_pending[p] += 1;
                                }
                            }
                        }
                    }
                }
                ExecEvent::Timer { key, .. } => {
                    let (s, epoch) = decode_arrival(key);
                    let live = s < napps
                        && sess[s].started
                        && !sess[s].stopped
                        && epoch == sess[s].epoch;
                    if !live || sess[s].issued >= quota {
                        // Stale timer from a replaced arrival process (or
                        // quota already met): ignore.
                        dispatch_after = false;
                    } else {
                        sess[s].issued += 1;
                        arrivals_trace.push(ArrivalRecord { session: s, at: now });
                        let id = next_req;
                        next_req += 1;
                        let plan = &self.plans[s];
                        let nu = plan.num_units();
                        let mut deps_remaining = pool.deps.pop().unwrap_or_default();
                        deps_remaining.clear();
                        deps_remaining.extend(plan.deps.iter().map(|d| d.len()));
                        let mut unit_proc = pool.procs.pop().unwrap_or_default();
                        unit_proc.clear();
                        unit_proc.resize(nu, None);
                        let st = ReqState {
                            session: s,
                            arrival: now,
                            slo_ms: sess[s].app.slo_ms,
                            epoch,
                            deps_remaining,
                            unit_proc,
                            units_left: nu,
                            dead: false,
                            retries_left: self.cfg.retry_limit,
                        };
                        // Enqueue units with no dependencies.
                        for u in 0..nu {
                            if st.deps_remaining[u] == 0 {
                                let dep_procs = ready.take_deps_buf();
                                ready.push(PendingTask {
                                    req: id,
                                    session: s,
                                    unit: u,
                                    ready_at: now,
                                    req_arrival: now,
                                    slo_ms: st.slo_ms,
                                    remaining_ms: plan
                                        .remaining_ms((0..nu).filter(|&x| x != u)),
                                    dep_procs,
                                });
                            }
                        }
                        reqs.insert(id, st);
                        // Open-loop arrivals re-arm immediately.
                        if sess[s].issued < quota {
                            arm_arrival_timer(
                                self.backend.as_mut(),
                                &mut rng,
                                s,
                                &mut sess[s],
                                now,
                                false,
                            );
                        }
                    }
                }
                ExecEvent::Completed { token, error, .. } => {
                    let Some(done) = inflight.remove(&token) else {
                        // Stale completion (should not happen: tokens are
                        // unique) — nothing to schedule against.
                        continue;
                    };
                    // Release the residency pin the dispatch took (one
                    // per group — the lead's commit covered every
                    // member, which shares its shard by definition).
                    if let Some(c) = wcache.as_mut() {
                        c.unpin(done.session, done.unit, done.proc);
                    }
                    // Fan the (group) completion out per member, lead
                    // first then members in member order — for a
                    // single-task dispatch this loop runs exactly once
                    // over exactly the old body.
                    // Transient fault injection: consume one armed
                    // transient on this processor, turning an otherwise
                    // successful completion into a *retryable* execution
                    // error. Driver-side by design, so both backends fail
                    // bit-identically (the cross-backend error-path trace
                    // test rides this).
                    let mut transient = false;
                    if let Some(fs) = fault.as_mut() {
                        if fs.transient_pending.get(done.proc).copied().unwrap_or(0) > 0 {
                            fs.transient_pending[done.proc] -= 1;
                            if !error {
                                transient = true;
                            }
                        }
                    }
                    fanout.clear();
                    fanout.push((done.req, done.session));
                    fanout.extend(done.extra.iter().copied());
                    let mut processed = 0usize;
                    for &(m_req, m_session) in fanout.iter() {
                        if error || transient {
                            // Payload execution failed: abort the request
                            // (mirroring the failure sweep) so it is
                            // reported as failed, never as
                            // completed-within-SLO. A group error aborts
                            // every member — the fused execution is one
                            // payload. Genuine payload errors are final
                            // (as they always were); injected transients
                            // are retryable. `floor_extra = 1`: this
                            // event's own completion is decremented just
                            // below, in the shared retirement block.
                            let outcome = abort_member(
                                &mut reqs,
                                &mut sess,
                                &mut ready,
                                self.backend.as_mut(),
                                &mut pool,
                                &mut fault,
                                &self.cfg,
                                quota,
                                now,
                                m_req,
                                done.unit,
                                1,
                                transient,
                                if transient { FailReason::Faulted } else { FailReason::Exec },
                            );
                            if outcome == MemberAbort::Retried {
                                // The unit did NOT complete — it will run
                                // again after the backoff, so skip the
                                // retirement block (no `units_left`
                                // decrement, no consumer unlocks).
                                processed += 1;
                                continue;
                            }
                        }
                        let finished = {
                            let Some(st) = reqs.get_mut(&m_req) else { continue };
                            processed += 1;
                            if st.dead {
                                // Aborted while running; drop silently.
                                st.units_left -= 1;
                                st.units_left == 0
                            } else {
                                st.unit_proc[done.unit] = Some(done.proc);
                                st.units_left -= 1;
                                let plan = &self.plans[m_session];
                                let nu = plan.num_units();
                                // Unlock consumers. `deps_remaining` and
                                // `unit_proc` are borrowed apart so the
                                // remaining-work estimate streams over
                                // `unit_proc` without a collected scratch.
                                let ReqState {
                                    deps_remaining, unit_proc, arrival, slo_ms, ..
                                } = &mut *st;
                                for &c in &plan.consumers[done.unit] {
                                    deps_remaining[c] -= 1;
                                    if deps_remaining[c] == 0 {
                                        let mut dep_procs = ready.take_deps_buf();
                                        dep_procs.extend(plan.deps[c].iter().map(|&d| {
                                            (d, unit_proc[d].unwrap_or(done.proc))
                                        }));
                                        let remaining = plan.remaining_ms(
                                            (0..nu)
                                                .filter(|&u| u != c && unit_proc[u].is_none()),
                                        );
                                        ready.push(PendingTask {
                                            req: m_req,
                                            session: m_session,
                                            unit: c,
                                            ready_at: now,
                                            req_arrival: *arrival,
                                            slo_ms: *slo_ms,
                                            remaining_ms: remaining,
                                            dep_procs,
                                        });
                                    }
                                }
                                st.units_left == 0
                            }
                        };
                        if finished {
                            let st = reqs.remove(&m_req).unwrap();
                            let s = st.session;
                            if !st.dead {
                                let latency = now - st.arrival;
                                sess[s].completed += 1;
                                sess[s].lat.add(latency);
                                if let Some(slo) = st.slo_ms {
                                    sess[s].slo_n += 1;
                                    if latency <= slo {
                                        sess[s].slo_ok += 1;
                                    }
                                }
                                // Failed requests already re-armed their
                                // session at abort time — re-arming here
                                // too would double the closed loop and
                                // snowball under sustained overload.
                                rearm_closed_loop(
                                    self.backend.as_mut(),
                                    &sess[s],
                                    s,
                                    st.epoch,
                                    quota,
                                    now,
                                );
                            }
                            pool.recycle(st);
                        }
                    }
                    if processed == 0 {
                        // No member had live state (defensive — mirrors
                        // the old single-task `continue`): nothing to
                        // schedule against.
                        continue;
                    }
                }
                ExecEvent::Tick { .. } => {
                    if let Some(fs) = fault.as_mut() {
                        // Quarantine-and-probe promotion: a Degraded
                        // processor that has stayed up through its
                        // quarantine is trusted as Up again.
                        if !fs.blind {
                            for p in 0..fs.health.len() {
                                if fs.health[p] == Health::Degraded
                                    && now >= fs.quarantine_until[p]
                                {
                                    fs.health[p] = Health::Up;
                                }
                            }
                        }
                    }
                    {
                        // Dispatch-deadline sweep: groups inflight past
                        // `mult ×` their predicted latency are presumed
                        // lost (hung driver, silently dropped completion)
                        // — abort on the backend, retry the members.
                        // Token order keeps the abort sequence
                        // deterministic.
                        if fault.is_some() && self.cfg.dispatch_timeout_mult > 0.0 {
                            overdue.clear();
                            overdue.extend(
                                inflight
                                    .iter()
                                    .filter(|(_, f)| f.deadline.is_some_and(|d| now > d))
                                    .map(|(&t, _)| t),
                            );
                            overdue.sort_unstable();
                            for i in 0..overdue.len() {
                                let tk = overdue[i];
                                let done = inflight.remove(&tk).unwrap();
                                if let Some(fs) = fault.as_mut() {
                                    fs.timeouts += 1;
                                }
                                // `abort` returns false for a group whose
                                // backend residency is already gone (hang
                                // abort at ProcFail time) — benign.
                                self.backend.abort(tk);
                                if let Some(c) = wcache.as_mut() {
                                    c.unpin(done.session, done.unit, done.proc);
                                }
                                fanout.clear();
                                fanout.push((done.req, done.session));
                                fanout.extend(done.extra.iter().copied());
                                for &(m_req, _) in fanout.iter() {
                                    abort_member(
                                        &mut reqs,
                                        &mut sess,
                                        &mut ready,
                                        self.backend.as_mut(),
                                        &mut pool,
                                        &mut fault,
                                        &self.cfg,
                                        quota,
                                        now,
                                        m_req,
                                        done.unit,
                                        0,
                                        true,
                                        FailReason::Faulted,
                                    );
                                }
                            }
                        }
                    }
                    // Failure sweep: abort requests far past their budget.
                    aborted.clear();
                    for (&id, st) in reqs.iter_mut() {
                        if st.dead {
                            continue;
                        }
                        let budget = st
                            .slo_ms
                            .unwrap_or(self.plans[st.session].est_total_ms * 3.0)
                            * self.cfg.fail_mult;
                        if now - st.arrival > budget {
                            st.dead = true;
                            fail_session(
                                &mut sess[st.session],
                                FailReason::Budget,
                                st.slo_ms.is_some(),
                            );
                            aborted.push(id);
                        }
                    }
                    if !aborted.is_empty() {
                        // HashMap iteration order is not deterministic;
                        // sort so re-arm order (and thus the event
                        // sequence) is reproducible under a fixed seed.
                        aborted.sort_unstable();
                        ready.cancel_requests(&aborted);
                        // Closed-loop sessions re-arm after an abort.
                        for &id in aborted.iter() {
                            let (s, epoch) = {
                                let st = &reqs[&id];
                                (st.session, st.epoch)
                            };
                            let running = self.backend.running_units(id);
                            rearm_closed_loop(
                                self.backend.as_mut(),
                                &sess[s],
                                s,
                                epoch,
                                quota,
                                now,
                            );
                            // Unscheduled units will never run; account
                            // them as done so the request can retire.
                            clamp_dead_request(&mut reqs, id, running, &mut pool);
                        }
                    }
                    // Re-partition controller (DESIGN.md §3h): ride the
                    // housekeeping tick, never a timer of its own — the
                    // tick cadence IS the control cadence, and no new
                    // timer namespace means record/replay sees the same
                    // event stream modulo the switches themselves.
                    if let Some(rc) = replan.as_mut() {
                        // Pressure signal from the (possibly cached)
                        // monitor snapshot, with the driver's health
                        // beliefs overlaid exactly as the dispatch path
                        // does: max of mean utilization over online
                        // processors and the impaired fraction (offline,
                        // degraded, or thermally capped). Mean-util alone
                        // saturates too slowly when a processor dies;
                        // impairment alone ignores plain overload.
                        let backend = &mut self.backend;
                        monitor.sample_with(now, |buf| backend.fill_proc_views(buf));
                        if let Some(fs) = fault.as_ref() {
                            if !fs.blind {
                                monitor.overlay_health(&fs.health);
                            }
                        }
                        let pressure = {
                            let views = monitor.cached_views();
                            let mut online = 0usize;
                            let mut util_sum = 0.0f64;
                            let mut impaired = 0usize;
                            for v in views.iter() {
                                if v.offline
                                    || v.health != Health::Up
                                    || v.freq_scale < 0.999
                                {
                                    impaired += 1;
                                }
                                if !v.offline {
                                    online += 1;
                                    util_sum += v.util;
                                }
                            }
                            let avg_util = if online > 0 {
                                util_sum / online as f64
                            } else {
                                1.0
                            };
                            let impaired_frac = if views.is_empty() {
                                0.0
                            } else {
                                impaired as f64 / views.len() as f64
                            };
                            avg_util.max(impaired_frac).clamp(0.0, 1.0)
                        };
                        if rc.primed {
                            rc.ema += REPLAN_EMA_ALPHA * (pressure - rc.ema);
                        } else {
                            rc.ema = pressure;
                            rc.primed = true;
                        }
                        let thr = self.cfg.replan_threshold;
                        for s in 0..napps {
                            if rc.sets[s].len() < 2
                                || !sess[s].started
                                || sess[s].stopped
                                || now - rc.last_switch[s] < self.cfg.replan_cooldown_ms
                            {
                                continue;
                            }
                            let cur = rc.active[s];
                            // Sustained pressure → finer (more units, more
                            // co-execution headroom); a calm system →
                            // coarser (fewer boundaries, less dispatch and
                            // transfer overhead). Hysteresis: the coarsen
                            // threshold sits at half the refine one, so
                            // the controller cannot oscillate around a
                            // single operating point.
                            let next = if rc.ema > thr && cur > 0 {
                                cur - 1
                            } else if rc.ema < thr * 0.5 && cur + 1 < rc.sets[s].len() {
                                cur + 1
                            } else {
                                continue;
                            };
                            // Safe boundary: no request of this session in
                            // ANY lifecycle stage — not just "no open
                            // requests". Dead requests still draining on a
                            // processor unpin their shard at completion
                            // time under whatever manifest is then
                            // current, so the swap must wait until the
                            // books are empty.
                            if reqs.values().any(|st| st.session == s) {
                                continue;
                            }
                            let new_plan = rc.sets[s].variants[next].clone();
                            if batching {
                                sess_kinds[s] = new_plan.coalesce_kind();
                                ready.set_kind(s, sess_kinds[s]);
                                for i in 0..napps {
                                    kind_multi[i] = sess_kinds.iter().enumerate().any(
                                        |(j, k2)| j != i && *k2 == sess_kinds[i],
                                    );
                                }
                            }
                            if let Some(c) = wcache.as_mut() {
                                c.swap_manifest(
                                    s,
                                    crate::weights::ShardManifest::from_plan(&new_plan),
                                );
                            }
                            let new_ws = new_plan.partition.window_size;
                            self.plans[s] = new_plan;
                            rc.active[s] = next;
                            rc.last_switch[s] = now;
                            rc.stats.replans += 1;
                            if next < cur {
                                rc.stats.finer += 1;
                            } else {
                                rc.stats.coarser += 1;
                            }
                            rc.stats.events.push((now, s, new_ws));
                        }
                    }
                }
            }

            // Dispatch loop: keep asking the scheduler while it makes
            // progress and capacity remains.
            loop {
                if !dispatch_after || ready.is_empty() {
                    break;
                }
                // Monitor snapshot (respecting the cache interval) —
                // borrowed from the cache; a refresh fills it in place.
                let backend = &mut self.backend;
                monitor.sample_with(now, |buf| backend.fill_proc_views(buf));
                // Health overlay: the driver's beliefs ride on top of the
                // (possibly cached) snapshot, so a crash masks its
                // processor from scheduling synchronously instead of at
                // the cache interval. Faults-off runs never overlay (and
                // the backend always reports `Up`), so the snapshot is
                // bit-identical to the pre-fault-layer one; fault-blind
                // runs skip it on purpose — that arm schedules into the
                // hole.
                if let Some(fs) = fault.as_ref() {
                    if !fs.blind {
                        monitor.overlay_health(&fs.health);
                    }
                }
                let views = monitor.cached_views();
                // Serialized policies see only each session's earliest
                // ready unit; other policies see the queue directly (no
                // copy — this loop is the hot path).
                let serialized = self.scheduler.serializes_sessions();
                if serialized {
                    for e in first_by_sess.iter_mut() {
                        *e = (u32::MAX, u32::MAX);
                    }
                    for (i, t) in ready.as_slice().iter().enumerate() {
                        let e = &mut first_by_sess[t.session];
                        if e.0 == u32::MAX || (t.unit as u32) < e.1 {
                            *e = (i as u32, t.unit as u32);
                        }
                    }
                    exposed_idx.clear();
                    // Ascending session order — the exposure order the old
                    // BTreeMap gave.
                    for e in first_by_sess.iter() {
                        if e.0 != u32::MAX {
                            exposed_idx.push(e.0 as usize);
                        }
                    }
                    // Clone the exposure into reusable scratch
                    // (`clone_from` keeps each slot's dep buffer). Slots
                    // beyond this round's count are NOT truncated away —
                    // the scheduler sees a `..len` slice instead — so an
                    // exposure count that shrinks and regrows never
                    // drops and reallocates the slots' dep buffers.
                    let tasks = ready.as_slice();
                    for (j, &i) in exposed_idx.iter().enumerate() {
                        if j < exposed_tasks.len() {
                            exposed_tasks[j].clone_from(&tasks[i]);
                        } else {
                            exposed_tasks.push(tasks[i].clone());
                        }
                    }
                }
                // Batching view of the candidate slice: per-candidate
                // coalescing keys for the scheduler (and the canonical
                // member-resolution rule both sides share).
                if batching {
                    cand_kinds.clear();
                    if serialized {
                        cand_kinds.extend(
                            exposed_idx.iter().map(|&i| ready.kind_key_at(i)),
                        );
                    } else {
                        cand_kinds.extend((0..ready.len()).map(|i| ready.kind_key_at(i)));
                    }
                    cand_taken.clear();
                    cand_taken.resize(cand_kinds.len(), false);
                }
                let bctx = if batching {
                    crate::sched::BatchCtx { max: batch_max, kinds: &cand_kinds }
                } else {
                    crate::sched::BatchCtx::OFF
                };
                let ctx = SchedCtx {
                    now,
                    soc: &soc,
                    plans: &self.plans,
                    procs: views,
                    batch: bctx,
                    weights: crate::sched::WeightsView { cache: wcache.as_ref() },
                    variants: replan
                        .as_ref()
                        .map(|rc| VariantsView { sets: &rc.sets, active: &rc.active }),
                };
                sched_out.clear();
                if serialized {
                    let exposed = &exposed_tasks[..exposed_idx.len()];
                    self.scheduler.schedule(&ctx, exposed, &mut sched_out);
                } else {
                    self.scheduler.schedule(&ctx, ready.as_slice(), &mut sched_out);
                }
                if sched_out.is_empty() {
                    break;
                }
                // Apply (validate defensively), collecting indices to
                // drop. `taken_stamp` marks indices dispatched this round
                // (a stamp, not a set — no clearing between rounds).
                dispatched.clear();
                round += 1;
                if taken_stamp.len() < ready.len() {
                    taken_stamp.resize(ready.len(), 0);
                }
                for &a in &sched_out {
                    let cand_idx = a.ready_idx;
                    let ridx = if serialized {
                        match exposed_idx.get(cand_idx) {
                            Some(&r) => r,
                            None => continue,
                        }
                    } else {
                        if cand_idx >= ready.len() {
                            continue;
                        }
                        cand_idx
                    };
                    if taken_stamp[ridx] == round {
                        continue;
                    }
                    let t = &ready.as_slice()[ridx];
                    let plan = &self.plans[t.session];
                    if !plan.partition.units[t.unit].supports(a.proc) {
                        continue;
                    }
                    let Some(exec_unit) = plan.exec_ms[t.unit][a.proc] else {
                        continue;
                    };
                    // Resolve the group: the canonical member rule over
                    // the candidate slice, against what this round has
                    // already committed or reserved. Every resolved task
                    // (lead included) is reserved in `cand_taken` no
                    // matter how this assignment ends — held and rejected
                    // groups must not leak members into later groups the
                    // scheduler priced without them.
                    let b_want = if batching { a.batch.clamp(1, batch_max) } else { 1 };
                    member_cand.clear();
                    if b_want > 1 {
                        if serialized {
                            bctx.members(cand_idx, b_want, &cand_taken, &mut member_cand);
                        } else {
                            // Same canonical rule — first b−1 untaken
                            // same-key candidates in ascending order —
                            // resolved through the queue's coalescing
                            // index instead of a full-queue scan: here
                            // candidate index IS queue position, and
                            // `peers` returns exactly the same-key
                            // positions (sorted ascending = candidate
                            // order).
                            peer_scratch.clear();
                            peer_scratch.extend_from_slice(ready.peers(cand_idx));
                            peer_scratch.sort_unstable();
                            for &p in peer_scratch.iter() {
                                if member_cand.len() + 1 >= b_want {
                                    break;
                                }
                                let p = p as usize;
                                if p != cand_idx && !cand_taken[p] {
                                    member_cand.push(p);
                                }
                            }
                        }
                    }
                    if batching {
                        cand_taken[cand_idx] = true;
                        for &m in &member_cand {
                            cand_taken[m] = true;
                        }
                    }
                    let b = 1 + member_cand.len();
                    // Coalescing window: a growable group may wait for
                    // peers — but only while the task's model has a LIVE
                    // sibling session (a statically-known sibling that
                    // has stopped can never produce peers — waiting for
                    // it would add dead latency under churn), and never
                    // beyond the window. The hold predicate compares
                    // against `t.ready_at + batch_window` — the exact
                    // f64 the poke timer is armed at — so the fired
                    // timer's instant always falls outside the hold
                    // (`now - ready_at < window` would livelock the sim
                    // whenever `(a + w) - a < w` rounds true).
                    let hold_deadline = t.ready_at + batch_window;
                    if batching
                        && batch_window > 0.0
                        && b < batch_max
                        && kind_multi[t.session]
                        && now < hold_deadline
                        && {
                            let k = sess_kinds[t.session];
                            sess_kinds.iter().enumerate().any(|(j, &k2)| {
                                j != t.session
                                    && k2 == k
                                    && sess[j].started
                                    && !sess[j].stopped
                            })
                        }
                    {
                        // One poke per deadline: dispatch rounds re-visit
                        // held groups on every event, and re-arming the
                        // same instant each time would flood the heap.
                        if !armed_pokes.contains(&hold_deadline.to_bits()) {
                            armed_pokes.push(hold_deadline.to_bits());
                            self.backend.arm_timer(hold_deadline, BATCH_POKE);
                        }
                        continue;
                    }
                    // Transfer pricing, parameterized on the target
                    // processor (lookahead prices every candidate with
                    // the same rule): costs summed over every member's
                    // dependencies. Positional dep → bytes lookup (rows
                    // align with `deps[unit]`; no linear search).
                    let member_xfer = |t: &PendingTask, to: usize| -> f64 {
                        let plan = &self.plans[t.session];
                        t.dep_procs
                            .iter()
                            .enumerate()
                            .map(|(k, &(du, dp))| {
                                let bytes = plan.xfer_bytes_at(t.unit, k, du);
                                self.scheduler.transfer_cost_ms(&soc, dp, to, bytes)
                            })
                            .sum()
                    };
                    // Resolve the group's member identities once — the
                    // group is a coalescing-key fact, identical for every
                    // candidate processor — then the whole-group transfer
                    // price as a function of the target (lead first, then
                    // members in member order, preserving the summation
                    // order of the pre-lookahead code bit-exactly).
                    let mut extra: Vec<(ReqId, SessId)> = Vec::new();
                    if b > 1 {
                        extra.reserve_exact(member_cand.len());
                        for &m in &member_cand {
                            let mpos = if serialized { exposed_idx[m] } else { m };
                            let mt = &ready.as_slice()[mpos];
                            extra.push((mt.req, mt.session));
                        }
                    }
                    let group_xfer = |to: usize| -> f64 {
                        let mut x: f64 = member_xfer(t, to);
                        for &m in &member_cand {
                            let mpos = if serialized { exposed_idx[m] } else { m };
                            x += member_xfer(&ready.as_slice()[mpos], to);
                        }
                        x
                    };
                    let mgmt = self.scheduler.decision_overhead_ms(plan);
                    let (req, session, unit) = (t.req, t.session, t.unit);
                    // Sim-in-the-loop lookahead (DESIGN.md §3f): evaluate
                    // up to `beam` candidate processors by dispatching
                    // this group on a forked simulation and rolling the
                    // fork forward until the group itself completes AND
                    // `min(horizon, inflight + 1)` completions have been
                    // observed; commit the candidate with the earliest
                    // stop time. The base policy's pick is candidate 0
                    // and wins every tie (override requires a strictly
                    // better score), so a rollout that discriminates
                    // nothing changes nothing. Candidates the fork
                    // rejects (offline / no free slot) score ∞; rollouts
                    // that run past the sim horizon likewise. Backends
                    // that cannot fork (wall clock) skip the whole block,
                    // degenerating lookahead to its base policy. This is
                    // a documented hot-path carve-out (DESIGN.md §3b):
                    // O(beam) snapshot *copies* per decision buy placement
                    // quality, and only the `lookahead` arm pays them —
                    // the copies recycle one persistent scratch backend's
                    // allocations (`rollout_scratch` above), so the old
                    // per-candidate deep-clone allocation churn is gone.
                    let mut target = a.proc;
                    if let Some(rp) = rollout {
                        cand_procs.clear();
                        cand_procs.push(a.proc);
                        for p in 0..soc.processors.len() {
                            if cand_procs.len() >= rp.beam as usize {
                                break;
                            }
                            if p != a.proc
                                && plan.partition.units[unit].supports(p)
                                && plan.exec_ms[unit][p].is_some()
                            {
                                cand_procs.push(p);
                            }
                        }
                        if cand_procs.len() > 1 {
                            let need = (rp.horizon as usize).min(inflight.len() + 1).max(1);
                            let mut best = f64::INFINITY;
                            for &p in &cand_procs {
                                if !self.backend.fork_into(&mut rollout_scratch) {
                                    break;
                                }
                                let fb =
                                    rollout_scratch.as_mut().expect("fork_into filled scratch");
                                let Some(exec_p) = plan.exec_ms[unit][p] else {
                                    continue;
                                };
                                let token = run_seq + 1;
                                let ok = fb.try_dispatch(DispatchCmd {
                                    token,
                                    req,
                                    session,
                                    unit,
                                    proc: p,
                                    exec_full_ms: crate::soc::cost::batch_latency_ms(
                                        &soc.processors[p],
                                        exec_p,
                                        b,
                                    ),
                                    xfer_ms: group_xfer(p),
                                    mgmt_ms: mgmt,
                                    load_ms: match wcache.as_ref() {
                                        Some(c) => c.price(&soc, now, session, unit, p),
                                        None => 0.0,
                                    },
                                    extra: extra.clone(),
                                });
                                if !ok {
                                    continue;
                                }
                                let mut seen = 0usize;
                                let mut placed = false;
                                let score = loop {
                                    let fev = fb.next_event();
                                    if fev.at() > self.cfg.duration_ms {
                                        break f64::INFINITY;
                                    }
                                    match fev {
                                        ExecEvent::Drained { .. } => break f64::INFINITY,
                                        ExecEvent::Completed { at, token: tk, .. } => {
                                            seen += 1;
                                            if tk == token {
                                                placed = true;
                                            }
                                            if placed && seen >= need {
                                                break at;
                                            }
                                        }
                                        _ => {}
                                    }
                                };
                                if score < best {
                                    best = score;
                                    target = p;
                                }
                            }
                        }
                    }
                    // Group-curve execution price (bit-exact unit price
                    // at b = 1) on the committed target.
                    let exec_on_target = if target == a.proc {
                        exec_unit
                    } else {
                        plan.exec_ms[unit][target].unwrap_or(exec_unit)
                    };
                    let exec_full = crate::soc::cost::batch_latency_ms(
                        &soc.processors[target],
                        exec_on_target,
                        b,
                    );
                    let xfer: f64 = group_xfer(target);
                    // Weight residency: price the lead's shard on the
                    // chosen processor (pure — state only mutates on an
                    // accepted dispatch, so a lost slot race below cannot
                    // corrupt the cache). Members share the lead's shard
                    // by the coalescing-key definition, so one load
                    // covers the whole group.
                    let load = match wcache.as_ref() {
                        Some(c) => c.price(&soc, now, session, unit, target),
                        None => 0.0,
                    };
                    let token = run_seq + 1;
                    let accepted = self.backend.try_dispatch(DispatchCmd {
                        token,
                        req,
                        session,
                        unit,
                        proc: target,
                        exec_full_ms: exec_full,
                        xfer_ms: xfer,
                        mgmt_ms: mgmt,
                        load_ms: load,
                        extra: extra.clone(),
                    });
                    if !accepted {
                        continue;
                    }
                    if let Some(c) = wcache.as_mut() {
                        // Commit charges exactly what `price` quoted (the
                        // state is unchanged in between) and pins the
                        // shard until the group's completion event.
                        c.commit(&soc, now, session, unit, target);
                    }
                    run_seq = token;
                    assignments_trace.push(AssignRecord {
                        req,
                        session,
                        unit,
                        proc: target,
                        members: extra.clone(),
                    });
                    taken_stamp[ridx] = round;
                    dispatched.push(ridx);
                    for &m in &member_cand {
                        let mpos = if serialized { exposed_idx[m] } else { m };
                        taken_stamp[mpos] = round;
                        dispatched.push(mpos);
                    }
                    // Deadline for the timeout sweep: a multiple of the
                    // full predicted latency the backend was just charged.
                    // `None` (no sweep) whenever the fault layer or the
                    // timeout knob is off.
                    let deadline = if fault.is_some() && self.cfg.dispatch_timeout_mult > 0.0
                    {
                        Some(
                            now + self.cfg.dispatch_timeout_mult
                                * (exec_full + xfer + mgmt + load),
                        )
                    } else {
                        None
                    };
                    inflight.insert(
                        token,
                        Inflight { req, session, unit, proc: target, extra, deadline },
                    );
                }
                if dispatched.is_empty() {
                    break;
                }
                dispatched.sort_unstable_by(|a, b| b.cmp(a));
                for &i in dispatched.iter() {
                    ready.swap_remove(i);
                }
            }

            // Finite workloads end once every session's quota has retired
            // (stopped sessions are done regardless of quota progress) and
            // no pending admission can create new work.
            if self.cfg.max_requests.is_some()
                && pending_starts == 0
                && reqs.is_empty()
                && ready.is_empty()
                && sess.iter().all(|se| se.stopped || se.issued >= quota)
            {
                break;
            }
        }

        // Assemble the report. Quota-bounded runs usually end well before
        // the nominal horizon: normalizing throughput/utilization by the
        // unused horizon would deflate every rate metric, so use the
        // actual elapsed time instead. Unbounded runs keep the horizon
        // (the historical simulator semantics).
        let duration = if self.cfg.max_requests.is_some() {
            last_now.min(self.cfg.duration_ms).max(1e-9)
        } else {
            self.cfg.duration_ms
        };
        // Requests still open when the run ended count as cancelled, so
        // conservation (issued == completed + failed + cancelled) holds
        // exactly, per session, on every run.
        for st in reqs.into_values() {
            if !st.dead {
                sess[st.session].cancelled += 1;
            }
        }
        let sessions: Vec<SessionStats> = sess
            .iter()
            .map(|se| {
                let start = se.start_ms.min(duration);
                let end = se.stop_ms.unwrap_or(duration).min(duration);
                let active_ms = if se.started { (end - start).max(0.0) } else { 0.0 };
                SessionStats {
                    model: se.app.model.clone(),
                    issued: se.issued,
                    completed: se.completed,
                    failed: se.failed,
                    cancelled: se.cancelled,
                    failed_budget: se.failed_budget,
                    failed_exec: se.failed_exec,
                    faulted: se.faulted,
                    retries_exhausted: se.retries_exhausted,
                    retries: se.retries,
                    latency: se.lat.clone(),
                    fps: if active_ms > 0.0 {
                        se.completed as f64 / (active_ms / 1e3)
                    } else {
                        0.0
                    },
                    slo_satisfaction: if se.slo_n > 0 {
                        Some(se.slo_ok as f64 / se.slo_n as f64)
                    } else {
                        None
                    },
                    slo_ok: se.slo_ok,
                    slo_n: se.slo_n,
                    start_ms: se.start_ms,
                    stop_ms: se.stop_ms,
                    active_ms,
                }
            })
            .collect();
        let be = self.backend.finish(duration);
        SimReport {
            scheduler: self.scheduler.name().to_string(),
            backend: be.backend.to_string(),
            duration_ms: duration,
            sessions,
            procs: be.procs,
            power: be.power,
            energy_j: be.energy_j,
            timeline: be.timeline,
            monitor_refreshes: monitor.refresh_count(),
            exec_errors: be.exec_errors,
            faults: fault.as_ref().map(|fs| crate::sim::report::FaultStats {
                proc_fails: fs.proc_fails,
                proc_recovers: fs.proc_recovers,
                timeouts: fs.timeouts,
            }),
            // All-zero on unbudgeted runs (no cache constructed), so the
            // report serializes identically either way.
            cache: wcache.as_ref().map(|c| c.stats()).unwrap_or_default(),
            replans: replan.as_ref().map(|rc| rc.stats.clone()),
            assignments: assignments_trace,
            arrivals: arrivals_trace,
            events: n_events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_key_round_trips_and_stays_out_of_event_namespace() {
        for &epoch in &[0u32, 1, 7, 1 << 20, EPOCH_MASK - 1, EPOCH_MASK] {
            for &session in &[0usize, 3, 4_000_000_000usize.min(usize::MAX)] {
                let session = session & 0xFFFF_FFFF;
                let key = arrival_key(session, epoch);
                assert_eq!(key & EVENT_KEY, 0, "epoch {epoch} leaked into bit 63");
                assert_eq!(decode_arrival(key), (session, epoch));
            }
        }
    }

    /// Epoch 2^31 − 1 + 1 wraps to 0 instead of colliding with
    /// `EVENT_KEY` — the regression this namespace hazard fix is about.
    #[test]
    fn epoch_wraps_at_31_bits() {
        assert_eq!(next_epoch(EPOCH_MASK), 0);
        assert_eq!(next_epoch(0), 1);
        let key = arrival_key(5, next_epoch(EPOCH_MASK - 1));
        assert_eq!(key & EVENT_KEY, 0);
        assert_eq!(decode_arrival(key), (5, EPOCH_MASK));
    }
}
