//! The shared scheduling loop: request lifecycle + scheduler invocation,
//! independent of the execution substrate.
//!
//! This is the dispatch loop that used to live inside the discrete-event
//! engine, now driving any [`ExecutionBackend`]: arrivals become per-unit
//! tasks, ready tasks are exposed to the [`Scheduler`] (respecting
//! session serialization), assignments are validated and priced, and
//! completions unlock dependent units until a request retires into the
//! latency/SLO statistics.
//!
//! Since the scenario engine the workload is an *open system*: sessions
//! may be admitted and retired mid-run and may switch arrival processes
//! ([`SessionEvent`]s riding the backend clock as timers). Every arrival
//! timer carries the session's *epoch* — bumped on stop/rate-change — so
//! stale timers from a replaced arrival process are ignored rather than
//! double-driving the session. Conservation holds per session on every
//! run: `issued == completed + failed + cancelled`.

use super::{
    App, ArrivalMode, ArrivalRecord, AssignRecord, DispatchCmd, EventKind, ExecEvent,
    ExecutionBackend, RunToken, SessionEvent, SimConfig,
};
use crate::monitor::{HardwareMonitor, ProcView};
use crate::sched::{ModelPlan, PendingTask, ReqId, SchedCtx, Scheduler, SessId};
use crate::sim::report::{SessionStats, SimReport};
use crate::util::rng::Pcg32;
use crate::util::stats::Summary;
use crate::TimeMs;
use std::collections::HashMap;
use std::sync::Arc;

/// Timer-key namespace: the top bit marks scenario-event timers, the low
/// 32 bits of arrival keys carry the session id and bits 32..63 its epoch.
const EVENT_KEY: u64 = 1 << 63;

fn arrival_key(session: SessId, epoch: u32) -> u64 {
    ((epoch as u64) << 32) | session as u64
}

fn decode_arrival(key: u64) -> (SessId, u32) {
    ((key & 0xFFFF_FFFF) as usize, (key >> 32) as u32)
}

/// Per-request bookkeeping.
#[derive(Debug)]
struct ReqState {
    session: SessId,
    arrival: TimeMs,
    slo_ms: Option<f64>,
    /// Arrival epoch the request was issued under (closed-loop re-arms
    /// only while its epoch is still the session's current one).
    epoch: u32,
    deps_remaining: Vec<usize>,
    unit_proc: Vec<Option<usize>>,
    units_left: usize,
    /// Aborted — failed (budget/exec error) or cancelled (session stop /
    /// run end). Units still resident on processors drain silently.
    dead: bool,
}

/// A dispatched unit the driver is waiting on.
#[derive(Debug, Clone, Copy)]
struct Inflight {
    req: ReqId,
    session: SessId,
    unit: usize,
    proc: usize,
}

/// Live per-session state (stats + arrival process).
struct Sess {
    app: App,
    started: bool,
    stopped: bool,
    start_ms: TimeMs,
    stop_ms: Option<TimeMs>,
    epoch: u32,
    issued: u64,
    completed: u64,
    failed: u64,
    cancelled: u64,
    lat: Summary,
    slo_ok: u64,
    slo_n: u64,
    /// Cursor into a `Replay` schedule.
    replay_pos: usize,
}

impl Sess {
    fn new(app: App) -> Self {
        Sess {
            app,
            started: false,
            stopped: false,
            start_ms: 0.0,
            stop_ms: None,
            epoch: 0,
            issued: 0,
            completed: 0,
            failed: 0,
            cancelled: 0,
            lat: Summary::new(),
            slo_ok: 0,
            slo_n: 0,
            replay_pos: 0,
        }
    }

    fn closed_loop(&self) -> bool {
        matches!(self.app.mode, ArrivalMode::ClosedLoop)
    }
}

/// Next inter-arrival gap of the square-wave bursty process: thinning of
/// a Poisson stream at the burst-phase rate, so the gap depends only on
/// the RNG stream and the current clock — deterministic under a fixed
/// seed on the sim backend.
fn bursty_gap(
    rate_rps: f64,
    burst_factor: f64,
    period_ms: f64,
    now: TimeMs,
    rng: &mut Pcg32,
) -> f64 {
    let hi = (rate_rps.max(1e-9) * burst_factor.max(1.0)) / 1e3; // per ms
    let lo = rate_rps.max(1e-9) / 1e3;
    let half = (period_ms / 2.0).max(1e-9);
    let mut t = now;
    for _ in 0..100_000 {
        t += rng.exp(hi);
        let in_burst = ((t / half).floor() as i64).rem_euclid(2) == 0;
        let cur = if in_burst { hi } else { lo };
        if rng.next_f64() < cur / hi {
            break;
        }
    }
    t - now
}

/// Arm a session's next arrival timer at `now`. `restart = false` means
/// an arrival was just issued (closed loop re-arms on completion instead;
/// replay advances its cursor); `restart = true` means the process was
/// just (re)started by a rate change (closed loop seeds exactly one fresh
/// loop — requests of the old epoch no longer re-arm — and replay rescans
/// for the next scheduled time).
fn arm_arrival_timer(
    backend: &mut dyn ExecutionBackend,
    rng: &mut Pcg32,
    s: SessId,
    sess: &mut Sess,
    now: TimeMs,
    restart: bool,
) {
    let key = arrival_key(s, sess.epoch);
    match &sess.app.mode {
        ArrivalMode::ClosedLoop => {
            if restart {
                backend.arm_timer(now, key);
            }
        }
        ArrivalMode::Periodic(p) => backend.arm_timer(now + p, key),
        ArrivalMode::Poisson(rate) => {
            let gap = rng.exp(rate.max(1e-9) / 1e3);
            backend.arm_timer(now + gap, key);
        }
        ArrivalMode::Bursty { rate_rps, burst_factor, period_ms } => {
            let gap = bursty_gap(*rate_rps, *burst_factor, *period_ms, now, rng);
            backend.arm_timer(now + gap, key);
        }
        ArrivalMode::Replay(times) => {
            let times = Arc::clone(times);
            let pos = if restart {
                times.iter().position(|&t| t >= now).unwrap_or(times.len())
            } else {
                sess.replay_pos + 1
            };
            sess.replay_pos = pos;
            if let Some(&t) = times.get(pos) {
                backend.arm_timer(t.max(now), key);
            }
        }
    }
}

/// A dead (failed/cancelled) request stays alive only while units are
/// still resident on processors: clamp its remaining-unit count to
/// `floor` and retire it once nothing is left. `floor` is the backend's
/// `running_units` — plus one in the exec-error path, whose triggering
/// completion is decremented later in the same handler. All three
/// abort sites (session stop, exec error, failure sweep) share this so
/// the conservation invariant has one implementation.
fn clamp_dead_request(reqs: &mut HashMap<ReqId, ReqState>, id: ReqId, floor: usize) {
    if let Some(st) = reqs.get_mut(&id) {
        st.units_left = st.units_left.min(floor);
        if st.units_left == 0 {
            reqs.remove(&id);
        }
    }
}

/// Re-seed a closed-loop session's arrival at `now` after one of its
/// requests retires or aborts. Fires only when the request belonged to
/// the session's *current* arrival epoch (a rate change must not
/// resurrect the replaced loop), the session is still live, and quota
/// remains — the single predicate all three retirement paths
/// (completion, exec error, failure sweep) share.
fn rearm_closed_loop(
    backend: &mut dyn ExecutionBackend,
    sess: &Sess,
    s: SessId,
    req_epoch: u32,
    quota: u64,
    now: TimeMs,
) {
    if req_epoch == sess.epoch
        && !sess.stopped
        && sess.closed_loop()
        && sess.issued < quota
    {
        backend.arm_timer(now, arrival_key(s, sess.epoch));
    }
}

/// Scheduler-driven execution of a multi-session workload on one backend.
pub struct Driver {
    cfg: SimConfig,
    apps: Vec<App>,
    plans: Vec<ModelPlan>,
    scheduler: Box<dyn Scheduler>,
    backend: Box<dyn ExecutionBackend>,
    events: Vec<SessionEvent>,
}

impl Driver {
    pub fn new(
        cfg: SimConfig,
        apps: Vec<App>,
        plans: Vec<ModelPlan>,
        scheduler: Box<dyn Scheduler>,
        backend: Box<dyn ExecutionBackend>,
    ) -> Self {
        assert_eq!(apps.len(), plans.len(), "one plan per session");
        Driver { cfg, apps, plans, scheduler, backend, events: Vec::new() }
    }

    /// Attach session-lifecycle events (a compiled scenario). Sessions
    /// referenced by a `Start` event are admitted when it fires; all
    /// other sessions are active from t = 0.
    pub fn events(mut self, events: Vec<SessionEvent>) -> Self {
        self.events = events;
        self
    }

    pub fn run(mut self) -> SimReport {
        let napps = self.apps.len();
        let mut rng = Pcg32::seeded(self.cfg.seed);
        let mut monitor = HardwareMonitor::new(self.cfg.monitor_cache_ms);
        let soc = self.backend.soc().clone();

        let mut sess: Vec<Sess> = self.apps.iter().cloned().map(Sess::new).collect();

        // Request state.
        let mut reqs: HashMap<ReqId, ReqState> = Default::default();
        let mut next_req: ReqId = 0;
        let mut ready: Vec<PendingTask> = Vec::new();
        let mut run_seq: RunToken = 0;
        let mut inflight: HashMap<RunToken, Inflight> = Default::default();
        let mut assignments_trace: Vec<AssignRecord> = Vec::new();
        let mut arrivals_trace: Vec<ArrivalRecord> = Vec::new();

        let quota = self.cfg.max_requests.unwrap_or(u64::MAX);

        // Scenario events ride the backend clock as timers. Only pending
        // `Start` events can create new work, so only they keep a
        // quota-bounded run alive.
        let mut pending_starts = 0usize;
        let mut late_start = vec![false; napps];
        for (i, ev) in self.events.iter().enumerate() {
            if let EventKind::Start { session } = ev.kind {
                if session < napps {
                    late_start[session] = true;
                }
                pending_starts += 1;
            }
            self.backend.arm_timer(ev.at_ms, EVENT_KEY | i as u64);
        }
        // Prime arrivals of the statically-admitted sessions (the backend
        // arms its own housekeeping tick).
        for s in 0..napps {
            if !late_start[s] {
                sess[s].started = true;
                if let Some(t0) = sess[s].app.mode.first_arrival(0.0) {
                    self.backend.arm_timer(t0, arrival_key(s, 0));
                }
            }
        }

        let debug = std::env::var_os("ADMS_SIM_DEBUG").is_some();
        let mut n_events: u64 = 0;
        let mut last_now: TimeMs = 0.0;
        loop {
            let ev = self.backend.next_event();
            let now = ev.at();
            if now > self.cfg.duration_ms {
                break;
            }
            last_now = now;
            n_events += 1;
            if debug && n_events % 2_000 == 0 {
                eprintln!(
                    "t={now:.0} events={n_events} ready={} reqs={} inflight={}",
                    ready.len(),
                    reqs.len(),
                    inflight.len()
                );
            }
            // Whether to give the scheduler a chance after this event.
            let mut dispatch_after = true;
            match ev {
                ExecEvent::Drained { .. } => break,
                ExecEvent::Timer { key, .. } if key & EVENT_KEY != 0 => {
                    let idx = (key & !EVENT_KEY) as usize;
                    let Some(tev) = self.events.get(idx).cloned() else {
                        continue;
                    };
                    match tev.kind {
                        EventKind::Start { session: s } => {
                            pending_starts = pending_starts.saturating_sub(1);
                            if s < napps && !sess[s].started && !sess[s].stopped {
                                sess[s].started = true;
                                sess[s].start_ms = now;
                                if let Some(t0) = sess[s].app.mode.first_arrival(now) {
                                    let key = arrival_key(s, sess[s].epoch);
                                    self.backend.arm_timer(t0, key);
                                }
                            }
                        }
                        EventKind::Stop { session: s } => {
                            if s < napps && sess[s].started && !sess[s].stopped {
                                sess[s].stopped = true;
                                sess[s].stop_ms = Some(now);
                                sess[s].epoch += 1;
                                // Cancel pending work deterministically:
                                // drop ready entries, abort open requests
                                // in id order; inflight units drain.
                                ready.retain(|t| t.session != s);
                                let mut open: Vec<ReqId> = reqs
                                    .iter()
                                    .filter(|(_, st)| st.session == s && !st.dead)
                                    .map(|(&id, _)| id)
                                    .collect();
                                open.sort_unstable();
                                for id in open {
                                    sess[s].cancelled += 1;
                                    let running = self.backend.running_units(id);
                                    reqs.get_mut(&id).unwrap().dead = true;
                                    clamp_dead_request(&mut reqs, id, running);
                                }
                            }
                        }
                        EventKind::Rate { session: s, mode } => {
                            if s < napps && !sess[s].stopped {
                                sess[s].epoch += 1;
                                sess[s].app.mode = mode;
                                if sess[s].started {
                                    arm_arrival_timer(
                                        self.backend.as_mut(),
                                        &mut rng,
                                        s,
                                        &mut sess[s],
                                        now,
                                        true,
                                    );
                                }
                            }
                        }
                    }
                }
                ExecEvent::Timer { key, .. } => {
                    let (s, epoch) = decode_arrival(key);
                    let live = s < napps
                        && sess[s].started
                        && !sess[s].stopped
                        && epoch == sess[s].epoch;
                    if !live || sess[s].issued >= quota {
                        // Stale timer from a replaced arrival process (or
                        // quota already met): ignore.
                        dispatch_after = false;
                    } else {
                        sess[s].issued += 1;
                        arrivals_trace.push(ArrivalRecord { session: s, at: now });
                        let id = next_req;
                        next_req += 1;
                        let plan = &self.plans[s];
                        let nu = plan.num_units();
                        let st = ReqState {
                            session: s,
                            arrival: now,
                            slo_ms: sess[s].app.slo_ms,
                            epoch,
                            deps_remaining: plan.deps.iter().map(|d| d.len()).collect(),
                            unit_proc: vec![None; nu],
                            units_left: nu,
                            dead: false,
                        };
                        // Enqueue units with no dependencies.
                        for u in 0..nu {
                            if st.deps_remaining[u] == 0 {
                                ready.push(PendingTask {
                                    req: id,
                                    session: s,
                                    unit: u,
                                    ready_at: now,
                                    req_arrival: now,
                                    slo_ms: st.slo_ms,
                                    remaining_ms: plan
                                        .remaining_ms((0..nu).filter(|&x| x != u)),
                                    dep_procs: vec![],
                                });
                            }
                        }
                        reqs.insert(id, st);
                        // Open-loop arrivals re-arm immediately.
                        if sess[s].issued < quota {
                            arm_arrival_timer(
                                self.backend.as_mut(),
                                &mut rng,
                                s,
                                &mut sess[s],
                                now,
                                false,
                            );
                        }
                    }
                }
                ExecEvent::Completed { token, error, .. } => {
                    let Some(done) = inflight.remove(&token) else {
                        // Stale completion (should not happen: tokens are
                        // unique) — nothing to schedule against.
                        continue;
                    };
                    if error {
                        // Payload execution failed: abort the request
                        // (mirroring the failure sweep) so it is reported
                        // as failed, never as completed-within-SLO.
                        let newly_dead = match reqs.get_mut(&done.req) {
                            Some(st) if !st.dead => {
                                st.dead = true;
                                Some((st.session, st.slo_ms.is_some(), st.epoch))
                            }
                            _ => None,
                        };
                        if let Some((s, has_slo, epoch)) = newly_dead {
                            sess[s].failed += 1;
                            if has_slo {
                                sess[s].slo_n += 1;
                            }
                            ready.retain(|t| t.req != done.req);
                            // Not-yet-dispatched units will never run;
                            // only units still resident on processors
                            // (plus this one, decremented below) keep
                            // the request alive.
                            let running = self.backend.running_units(done.req);
                            // +1: this event's own completion is
                            // decremented just below, in the shared
                            // retirement block.
                            clamp_dead_request(&mut reqs, done.req, running + 1);
                            rearm_closed_loop(
                                self.backend.as_mut(),
                                &sess[s],
                                s,
                                epoch,
                                quota,
                                now,
                            );
                        }
                    }
                    let finished = {
                        let Some(st) = reqs.get_mut(&done.req) else { continue };
                        if st.dead {
                            // Aborted while running; drop silently.
                            st.units_left -= 1;
                            st.units_left == 0
                        } else {
                            st.unit_proc[done.unit] = Some(done.proc);
                            st.units_left -= 1;
                            let plan = &self.plans[done.session];
                            // Unlock consumers.
                            for &c in &plan.consumers[done.unit] {
                                st.deps_remaining[c] -= 1;
                                if st.deps_remaining[c] == 0 {
                                    let unfinished: Vec<usize> = (0..plan.num_units())
                                        .filter(|&u| u != c && st.unit_proc[u].is_none())
                                        .collect();
                                    ready.push(PendingTask {
                                        req: done.req,
                                        session: done.session,
                                        unit: c,
                                        ready_at: now,
                                        req_arrival: st.arrival,
                                        slo_ms: st.slo_ms,
                                        remaining_ms: plan
                                            .remaining_ms(unfinished.into_iter()),
                                        dep_procs: plan.deps[c]
                                            .iter()
                                            .map(|&d| {
                                                (d, st.unit_proc[d].unwrap_or(done.proc))
                                            })
                                            .collect(),
                                    });
                                }
                            }
                            st.units_left == 0
                        }
                    };
                    if finished {
                        let st = reqs.remove(&done.req).unwrap();
                        let s = st.session;
                        if !st.dead {
                            let latency = now - st.arrival;
                            sess[s].completed += 1;
                            sess[s].lat.add(latency);
                            if let Some(slo) = st.slo_ms {
                                sess[s].slo_n += 1;
                                if latency <= slo {
                                    sess[s].slo_ok += 1;
                                }
                            }
                            // Failed requests already re-armed their
                            // session at abort time — re-arming here too
                            // would double the closed loop and snowball
                            // under sustained overload.
                            rearm_closed_loop(
                                self.backend.as_mut(),
                                &sess[s],
                                s,
                                st.epoch,
                                quota,
                                now,
                            );
                        }
                    }
                }
                ExecEvent::Tick { .. } => {
                    // Failure sweep: abort requests far past their budget.
                    let mut aborted: Vec<ReqId> = Vec::new();
                    for (&id, st) in reqs.iter_mut() {
                        if st.dead {
                            continue;
                        }
                        let budget = st
                            .slo_ms
                            .unwrap_or(self.plans[st.session].est_total_ms * 3.0)
                            * self.cfg.fail_mult;
                        if now - st.arrival > budget {
                            st.dead = true;
                            sess[st.session].failed += 1;
                            if st.slo_ms.is_some() {
                                sess[st.session].slo_n += 1;
                            }
                            aborted.push(id);
                        }
                    }
                    if !aborted.is_empty() {
                        // HashMap iteration order is not deterministic;
                        // sort so re-arm order (and thus the event
                        // sequence) is reproducible under a fixed seed.
                        aborted.sort_unstable();
                        ready.retain(|t| !aborted.contains(&t.req));
                        // Closed-loop sessions re-arm after an abort.
                        for id in aborted {
                            let (s, epoch) = {
                                let st = &reqs[&id];
                                (st.session, st.epoch)
                            };
                            let running = self.backend.running_units(id);
                            rearm_closed_loop(
                                self.backend.as_mut(),
                                &sess[s],
                                s,
                                epoch,
                                quota,
                                now,
                            );
                            // Unscheduled units will never run; account
                            // them as done so the request can retire.
                            clamp_dead_request(&mut reqs, id, running);
                        }
                    }
                }
            }

            // Dispatch loop: keep asking the scheduler while it makes
            // progress and capacity remains.
            loop {
                if !dispatch_after || ready.is_empty() {
                    break;
                }
                // Monitor snapshot (respecting the cache interval).
                let views: Vec<ProcView> =
                    monitor.sample(now, || self.backend.proc_views()).to_vec();
                // Serialized policies see only each session's earliest
                // ready unit; other policies see the queue directly (no
                // copy — this loop is the hot path).
                let exposed: Option<Vec<usize>> = if self.scheduler.serializes_sessions() {
                    let mut first: std::collections::BTreeMap<SessId, (usize, usize)> =
                        Default::default();
                    for (i, t) in ready.iter().enumerate() {
                        let e = first.entry(t.session).or_insert((i, t.unit));
                        if t.unit < e.1 {
                            *e = (i, t.unit);
                        }
                    }
                    Some(first.values().map(|&(i, _)| i).collect())
                } else {
                    None
                };
                let ctx = SchedCtx { now, soc: &soc, plans: &self.plans, procs: &views };
                let assignments = match &exposed {
                    Some(idx) => {
                        let exposed_tasks: Vec<PendingTask> =
                            idx.iter().map(|&i| ready[i].clone()).collect();
                        self.scheduler.schedule(&ctx, &exposed_tasks)
                    }
                    None => self.scheduler.schedule(&ctx, &ready),
                };
                if assignments.is_empty() {
                    break;
                }
                // Apply (validate defensively), collecting indices to drop.
                let mut dispatched: Vec<usize> = Vec::new();
                for a in assignments {
                    let ridx = match &exposed {
                        Some(idx) => match idx.get(a.ready_idx) {
                            Some(&r) => r,
                            None => continue,
                        },
                        None => {
                            if a.ready_idx >= ready.len() {
                                continue;
                            }
                            a.ready_idx
                        }
                    };
                    if dispatched.contains(&ridx) {
                        continue;
                    }
                    let t = &ready[ridx];
                    let plan = &self.plans[t.session];
                    if !plan.partition.units[t.unit].supports(a.proc) {
                        continue;
                    }
                    let Some(exec_full) = plan.exec_ms[t.unit][a.proc] else {
                        continue;
                    };
                    let xfer: f64 = t
                        .dep_procs
                        .iter()
                        .map(|&(du, dp)| {
                            let bytes = plan.xfer_bytes[t.unit]
                                .iter()
                                .find(|(d, _)| *d == du)
                                .map(|(_, b)| *b)
                                .unwrap_or(0);
                            self.scheduler.transfer_cost_ms(&soc, dp, a.proc, bytes)
                        })
                        .sum();
                    let mgmt = self.scheduler.decision_overhead_ms(plan);
                    let token = run_seq + 1;
                    let accepted = self.backend.try_dispatch(DispatchCmd {
                        token,
                        req: t.req,
                        session: t.session,
                        unit: t.unit,
                        proc: a.proc,
                        exec_full_ms: exec_full,
                        xfer_ms: xfer,
                        mgmt_ms: mgmt,
                    });
                    if !accepted {
                        continue;
                    }
                    run_seq = token;
                    inflight.insert(
                        token,
                        Inflight { req: t.req, session: t.session, unit: t.unit, proc: a.proc },
                    );
                    assignments_trace.push(AssignRecord {
                        req: t.req,
                        session: t.session,
                        unit: t.unit,
                        proc: a.proc,
                    });
                    dispatched.push(ridx);
                }
                if dispatched.is_empty() {
                    break;
                }
                dispatched.sort_unstable_by(|a, b| b.cmp(a));
                for i in dispatched {
                    ready.swap_remove(i);
                }
            }

            // Finite workloads end once every session's quota has retired
            // (stopped sessions are done regardless of quota progress) and
            // no pending admission can create new work.
            if self.cfg.max_requests.is_some()
                && pending_starts == 0
                && reqs.is_empty()
                && ready.is_empty()
                && sess.iter().all(|se| se.stopped || se.issued >= quota)
            {
                break;
            }
        }

        // Assemble the report. Quota-bounded runs usually end well before
        // the nominal horizon: normalizing throughput/utilization by the
        // unused horizon would deflate every rate metric, so use the
        // actual elapsed time instead. Unbounded runs keep the horizon
        // (the historical simulator semantics).
        let duration = if self.cfg.max_requests.is_some() {
            last_now.min(self.cfg.duration_ms).max(1e-9)
        } else {
            self.cfg.duration_ms
        };
        // Requests still open when the run ended count as cancelled, so
        // conservation (issued == completed + failed + cancelled) holds
        // exactly, per session, on every run.
        for st in reqs.into_values() {
            if !st.dead {
                sess[st.session].cancelled += 1;
            }
        }
        let sessions: Vec<SessionStats> = sess
            .iter()
            .map(|se| {
                let start = se.start_ms.min(duration);
                let end = se.stop_ms.unwrap_or(duration).min(duration);
                let active_ms = if se.started { (end - start).max(0.0) } else { 0.0 };
                SessionStats {
                    model: se.app.model.clone(),
                    issued: se.issued,
                    completed: se.completed,
                    failed: se.failed,
                    cancelled: se.cancelled,
                    latency: se.lat.clone(),
                    fps: if active_ms > 0.0 {
                        se.completed as f64 / (active_ms / 1e3)
                    } else {
                        0.0
                    },
                    slo_satisfaction: if se.slo_n > 0 {
                        Some(se.slo_ok as f64 / se.slo_n as f64)
                    } else {
                        None
                    },
                    start_ms: se.start_ms,
                    stop_ms: se.stop_ms,
                    active_ms,
                }
            })
            .collect();
        let be = self.backend.finish(duration);
        SimReport {
            scheduler: self.scheduler.name().to_string(),
            backend: be.backend.to_string(),
            duration_ms: duration,
            sessions,
            procs: be.procs,
            power: be.power,
            energy_j: be.energy_j,
            timeline: be.timeline,
            monitor_refreshes: monitor.refresh_count(),
            exec_errors: be.exec_errors,
            assignments: assignments_trace,
            arrivals: arrivals_trace,
        }
    }
}
