//! The shared scheduling loop: request lifecycle + scheduler invocation,
//! independent of the execution substrate.
//!
//! This is the dispatch loop that used to live inside the discrete-event
//! engine, now driving any [`ExecutionBackend`]: arrivals become per-unit
//! tasks, ready tasks are exposed to the [`Scheduler`] (respecting
//! session serialization), assignments are validated and priced, and
//! completions unlock dependent units until a request retires into the
//! latency/SLO statistics.

use super::{
    App, ArrivalMode, AssignRecord, DispatchCmd, ExecEvent, ExecutionBackend, RunToken,
    SimConfig,
};
use crate::monitor::{HardwareMonitor, ProcView};
use crate::sched::{ModelPlan, PendingTask, ReqId, SchedCtx, Scheduler, SessId};
use crate::sim::report::{SessionStats, SimReport};
use crate::util::rng::Pcg32;
use crate::util::stats::Summary;
use crate::TimeMs;
use std::collections::HashMap;

/// Per-request bookkeeping.
#[derive(Debug)]
struct ReqState {
    session: SessId,
    arrival: TimeMs,
    slo_ms: Option<f64>,
    deps_remaining: Vec<usize>,
    unit_proc: Vec<Option<usize>>,
    units_left: usize,
    failed: bool,
}

/// A dispatched unit the driver is waiting on.
#[derive(Debug, Clone, Copy)]
struct Inflight {
    req: ReqId,
    session: SessId,
    unit: usize,
    proc: usize,
}

/// Scheduler-driven execution of a multi-session workload on one backend.
pub struct Driver {
    cfg: SimConfig,
    apps: Vec<App>,
    plans: Vec<ModelPlan>,
    scheduler: Box<dyn Scheduler>,
    backend: Box<dyn ExecutionBackend>,
}

impl Driver {
    pub fn new(
        cfg: SimConfig,
        apps: Vec<App>,
        plans: Vec<ModelPlan>,
        scheduler: Box<dyn Scheduler>,
        backend: Box<dyn ExecutionBackend>,
    ) -> Self {
        assert_eq!(apps.len(), plans.len(), "one plan per session");
        Driver { cfg, apps, plans, scheduler, backend }
    }

    pub fn run(mut self) -> SimReport {
        let napps = self.apps.len();
        let mut rng = Pcg32::seeded(self.cfg.seed);
        let mut monitor = HardwareMonitor::new(self.cfg.monitor_cache_ms);
        let soc = self.backend.soc().clone();

        // Session stats.
        let mut completed = vec![0u64; napps];
        let mut failed = vec![0u64; napps];
        let mut lat: Vec<Summary> = (0..napps).map(|_| Summary::new()).collect();
        let mut slo_ok = vec![0u64; napps];
        let mut slo_n = vec![0u64; napps];
        let mut issued = vec![0u64; napps];

        // Request state.
        let mut reqs: HashMap<ReqId, ReqState> = Default::default();
        let mut next_req: ReqId = 0;
        let mut ready: Vec<PendingTask> = Vec::new();
        let mut run_seq: RunToken = 0;
        let mut inflight: HashMap<RunToken, Inflight> = Default::default();
        let mut assignments_trace: Vec<AssignRecord> = Vec::new();

        let quota = self.cfg.max_requests.unwrap_or(u64::MAX);

        // Prime arrivals (the backend arms its own housekeeping tick).
        for s in 0..napps {
            self.backend.arm_timer(0.0, s as u64);
        }

        let debug = std::env::var_os("ADMS_SIM_DEBUG").is_some();
        let mut n_events: u64 = 0;
        let mut last_now: TimeMs = 0.0;
        loop {
            let ev = self.backend.next_event();
            let now = ev.at();
            if now > self.cfg.duration_ms {
                break;
            }
            last_now = now;
            n_events += 1;
            if debug && n_events % 2_000 == 0 {
                eprintln!(
                    "t={now:.0} events={n_events} ready={} reqs={} inflight={}",
                    ready.len(),
                    reqs.len(),
                    inflight.len()
                );
            }
            // Whether to give the scheduler a chance after this event.
            let mut dispatch_after = true;
            match ev {
                ExecEvent::Drained { .. } => break,
                ExecEvent::Timer { key, .. } => {
                    let s = key as usize;
                    if issued[s] >= quota {
                        dispatch_after = false;
                    } else {
                        issued[s] += 1;
                        let id = next_req;
                        next_req += 1;
                        let plan = &self.plans[s];
                        let nu = plan.num_units();
                        let st = ReqState {
                            session: s,
                            arrival: now,
                            slo_ms: self.apps[s].slo_ms,
                            deps_remaining: plan.deps.iter().map(|d| d.len()).collect(),
                            unit_proc: vec![None; nu],
                            units_left: nu,
                            failed: false,
                        };
                        // Enqueue units with no dependencies.
                        for u in 0..nu {
                            if st.deps_remaining[u] == 0 {
                                ready.push(PendingTask {
                                    req: id,
                                    session: s,
                                    unit: u,
                                    ready_at: now,
                                    req_arrival: now,
                                    slo_ms: st.slo_ms,
                                    remaining_ms: plan
                                        .remaining_ms((0..nu).filter(|&x| x != u)),
                                    dep_procs: vec![],
                                });
                            }
                        }
                        reqs.insert(id, st);
                        // Open-loop arrivals re-arm immediately.
                        if issued[s] < quota {
                            match self.apps[s].mode {
                                ArrivalMode::Periodic(p) => {
                                    self.backend.arm_timer(now + p, key)
                                }
                                ArrivalMode::Poisson(rate) => {
                                    let gap = rng.exp(rate / 1e3);
                                    self.backend.arm_timer(now + gap, key);
                                }
                                ArrivalMode::ClosedLoop => {}
                            }
                        }
                    }
                }
                ExecEvent::Completed { token, error, .. } => {
                    let Some(done) = inflight.remove(&token) else {
                        // Stale completion (should not happen: tokens are
                        // unique) — nothing to schedule against.
                        continue;
                    };
                    if error {
                        // Payload execution failed: abort the request
                        // (mirroring the failure sweep) so it is reported
                        // as failed, never as completed-within-SLO.
                        if let Some(st) = reqs.get_mut(&done.req) {
                            if !st.failed {
                                st.failed = true;
                                failed[st.session] += 1;
                                if st.slo_ms.is_some() {
                                    slo_n[st.session] += 1;
                                }
                                ready.retain(|t| t.req != done.req);
                                // Not-yet-dispatched units will never run;
                                // only units still resident on processors
                                // (plus this one, decremented below) keep
                                // the request alive.
                                let running = self.backend.running_units(done.req);
                                st.units_left = st.units_left.min(running + 1);
                                if matches!(
                                    self.apps[st.session].mode,
                                    ArrivalMode::ClosedLoop
                                ) && issued[st.session] < quota
                                {
                                    let key = st.session as u64;
                                    self.backend.arm_timer(now, key);
                                }
                            }
                        }
                    }
                    let finished = {
                        let Some(st) = reqs.get_mut(&done.req) else { continue };
                        if st.failed {
                            // Aborted while running; drop silently.
                            st.units_left -= 1;
                            st.units_left == 0
                        } else {
                            st.unit_proc[done.unit] = Some(done.proc);
                            st.units_left -= 1;
                            let plan = &self.plans[done.session];
                            // Unlock consumers.
                            for &c in &plan.consumers[done.unit] {
                                st.deps_remaining[c] -= 1;
                                if st.deps_remaining[c] == 0 {
                                    let unfinished: Vec<usize> = (0..plan.num_units())
                                        .filter(|&u| u != c && st.unit_proc[u].is_none())
                                        .collect();
                                    ready.push(PendingTask {
                                        req: done.req,
                                        session: done.session,
                                        unit: c,
                                        ready_at: now,
                                        req_arrival: st.arrival,
                                        slo_ms: st.slo_ms,
                                        remaining_ms: plan
                                            .remaining_ms(unfinished.into_iter()),
                                        dep_procs: plan.deps[c]
                                            .iter()
                                            .map(|&d| {
                                                (d, st.unit_proc[d].unwrap_or(done.proc))
                                            })
                                            .collect(),
                                    });
                                }
                            }
                            st.units_left == 0
                        }
                    };
                    if finished {
                        let st = reqs.remove(&done.req).unwrap();
                        let s = st.session;
                        if !st.failed {
                            let latency = now - st.arrival;
                            completed[s] += 1;
                            lat[s].add(latency);
                            if let Some(slo) = st.slo_ms {
                                slo_n[s] += 1;
                                if latency <= slo {
                                    slo_ok[s] += 1;
                                }
                            }
                            // Failed requests already re-armed their
                            // session at abort time — re-arming here too
                            // would double the closed loop and snowball
                            // under sustained overload.
                            if matches!(self.apps[s].mode, ArrivalMode::ClosedLoop)
                                && issued[s] < quota
                            {
                                self.backend.arm_timer(now, s as u64);
                            }
                        }
                    }
                }
                ExecEvent::Tick { .. } => {
                    // Failure sweep: abort requests far past their budget.
                    let mut aborted: Vec<ReqId> = Vec::new();
                    for (&id, st) in reqs.iter_mut() {
                        if st.failed {
                            continue;
                        }
                        let budget = st
                            .slo_ms
                            .unwrap_or(self.plans[st.session].est_total_ms * 3.0)
                            * self.cfg.fail_mult;
                        if now - st.arrival > budget {
                            st.failed = true;
                            failed[st.session] += 1;
                            if st.slo_ms.is_some() {
                                slo_n[st.session] += 1;
                            }
                            aborted.push(id);
                        }
                    }
                    if !aborted.is_empty() {
                        // HashMap iteration order is not deterministic;
                        // sort so re-arm order (and thus the event
                        // sequence) is reproducible under a fixed seed.
                        aborted.sort_unstable();
                        ready.retain(|t| !aborted.contains(&t.req));
                        // Closed-loop sessions re-arm after an abort.
                        for id in aborted {
                            let st = &reqs[&id];
                            let s = st.session;
                            let running = self.backend.running_units(id);
                            let pending_units = st.units_left > running;
                            if matches!(self.apps[s].mode, ArrivalMode::ClosedLoop)
                                && issued[s] < quota
                            {
                                self.backend.arm_timer(now, s as u64);
                            }
                            if pending_units {
                                // Unscheduled units will never run; account
                                // them as done so the request can retire.
                                if let Some(stm) = reqs.get_mut(&id) {
                                    stm.units_left = running;
                                    if stm.units_left == 0 {
                                        reqs.remove(&id);
                                    }
                                }
                            }
                        }
                    }
                }
            }

            // Dispatch loop: keep asking the scheduler while it makes
            // progress and capacity remains.
            loop {
                if !dispatch_after || ready.is_empty() {
                    break;
                }
                // Monitor snapshot (respecting the cache interval).
                let views: Vec<ProcView> =
                    monitor.sample(now, || self.backend.proc_views()).to_vec();
                // Serialized policies see only each session's earliest
                // ready unit; other policies see the queue directly (no
                // copy — this loop is the hot path).
                let exposed: Option<Vec<usize>> = if self.scheduler.serializes_sessions() {
                    let mut first: std::collections::BTreeMap<SessId, (usize, usize)> =
                        Default::default();
                    for (i, t) in ready.iter().enumerate() {
                        let e = first.entry(t.session).or_insert((i, t.unit));
                        if t.unit < e.1 {
                            *e = (i, t.unit);
                        }
                    }
                    Some(first.values().map(|&(i, _)| i).collect())
                } else {
                    None
                };
                let ctx = SchedCtx { now, soc: &soc, plans: &self.plans, procs: &views };
                let assignments = match &exposed {
                    Some(idx) => {
                        let exposed_tasks: Vec<PendingTask> =
                            idx.iter().map(|&i| ready[i].clone()).collect();
                        self.scheduler.schedule(&ctx, &exposed_tasks)
                    }
                    None => self.scheduler.schedule(&ctx, &ready),
                };
                if assignments.is_empty() {
                    break;
                }
                // Apply (validate defensively), collecting indices to drop.
                let mut dispatched: Vec<usize> = Vec::new();
                for a in assignments {
                    let ridx = match &exposed {
                        Some(idx) => match idx.get(a.ready_idx) {
                            Some(&r) => r,
                            None => continue,
                        },
                        None => {
                            if a.ready_idx >= ready.len() {
                                continue;
                            }
                            a.ready_idx
                        }
                    };
                    if dispatched.contains(&ridx) {
                        continue;
                    }
                    let t = &ready[ridx];
                    let plan = &self.plans[t.session];
                    if !plan.partition.units[t.unit].supports(a.proc) {
                        continue;
                    }
                    let Some(exec_full) = plan.exec_ms[t.unit][a.proc] else {
                        continue;
                    };
                    let xfer: f64 = t
                        .dep_procs
                        .iter()
                        .map(|&(du, dp)| {
                            let bytes = plan.xfer_bytes[t.unit]
                                .iter()
                                .find(|(d, _)| *d == du)
                                .map(|(_, b)| *b)
                                .unwrap_or(0);
                            self.scheduler.transfer_cost_ms(&soc, dp, a.proc, bytes)
                        })
                        .sum();
                    let mgmt = self.scheduler.decision_overhead_ms(plan);
                    let token = run_seq + 1;
                    let accepted = self.backend.try_dispatch(DispatchCmd {
                        token,
                        req: t.req,
                        session: t.session,
                        unit: t.unit,
                        proc: a.proc,
                        exec_full_ms: exec_full,
                        xfer_ms: xfer,
                        mgmt_ms: mgmt,
                    });
                    if !accepted {
                        continue;
                    }
                    run_seq = token;
                    inflight.insert(
                        token,
                        Inflight { req: t.req, session: t.session, unit: t.unit, proc: a.proc },
                    );
                    assignments_trace.push(AssignRecord {
                        req: t.req,
                        session: t.session,
                        unit: t.unit,
                        proc: a.proc,
                    });
                    dispatched.push(ridx);
                }
                if dispatched.is_empty() {
                    break;
                }
                dispatched.sort_unstable_by(|a, b| b.cmp(a));
                for i in dispatched {
                    ready.swap_remove(i);
                }
            }

            // Finite workloads end once every session's quota has retired.
            if self.cfg.max_requests.is_some()
                && reqs.is_empty()
                && ready.is_empty()
                && issued.iter().all(|&n| n >= quota)
            {
                break;
            }
        }

        // Assemble the report. Quota-bounded runs usually end well before
        // the nominal horizon: normalizing throughput/utilization by the
        // unused horizon would deflate every rate metric, so use the
        // actual elapsed time instead. Unbounded runs keep the horizon
        // (the historical simulator semantics).
        let duration = if self.cfg.max_requests.is_some() {
            last_now.min(self.cfg.duration_ms).max(1e-9)
        } else {
            self.cfg.duration_ms
        };
        let sessions: Vec<SessionStats> = (0..napps)
            .map(|s| SessionStats {
                model: self.apps[s].model.clone(),
                completed: completed[s],
                failed: failed[s],
                latency: lat[s].clone(),
                fps: completed[s] as f64 / (duration / 1e3),
                slo_satisfaction: if slo_n[s] > 0 {
                    Some(slo_ok[s] as f64 / slo_n[s] as f64)
                } else {
                    None
                },
            })
            .collect();
        let be = self.backend.finish(duration);
        SimReport {
            scheduler: self.scheduler.name().to_string(),
            backend: be.backend.to_string(),
            duration_ms: duration,
            sessions,
            procs: be.procs,
            power: be.power,
            energy_j: be.energy_j,
            timeline: be.timeline,
            monitor_refreshes: monitor.refresh_count(),
            exec_errors: be.exec_errors,
            assignments: assignments_trace,
        }
    }
}
