//! Backend-agnostic execution core.
//!
//! The paper's contribution is a *scheduling* loop — processor-state-aware
//! placement of unit subgraphs from concurrent DNN sessions — and that loop
//! must not care whether "execution" means advancing a calibrated
//! discrete-event SoC model or running real stage payloads on a wall-clock
//! worker pool. This module factors the loop out of the simulator so both
//! substrates share it:
//!
//! * [`ExecutionBackend`] — the substrate contract: a clock, per-processor
//!   views for the [`HardwareMonitor`](crate::monitor::HardwareMonitor),
//!   task dispatch, and completion/timer/tick event delivery;
//! * [`Driver`](driver::Driver) — the shared request lifecycle: arrivals,
//!   dependency tracking, ready-queue exposure, scheduler invocation,
//!   SLO/latency accounting, failure sweeps;
//! * [`SimBackend`](sim_backend::SimBackend) — the calibrated SoC model
//!   (DVFS, thermal RC dynamics, contention, power) on a virtual clock;
//! * [`ThreadPoolBackend`](threadpool::ThreadPoolBackend) — wall-clock
//!   serving on a worker pool standing in for the heterogeneous
//!   processors, executing PJRT stage payloads where available and
//!   cost-model-paced synthetic payloads otherwise;
//! * [`Server`](server::Server) — the builder API over all of it.
//!
//! Every scheduler ([`VanillaTflite`](crate::sched::VanillaTflite),
//! [`Band`](crate::sched::Band), [`Adms`](crate::sched::Adms), …) runs
//! unmodified on either backend; a scheduling improvement lands in the
//! evaluation harness and the serving path at once.

pub mod driver;
pub mod server;
pub mod sim_backend;
pub mod threadpool;

pub use driver::Driver;
pub use server::{scheduler_by_name, Server, SCHEDULER_NAMES};
pub use sim_backend::SimBackend;
pub use threadpool::ThreadPoolBackend;

use crate::monitor::ProcView;
use crate::sched::{ReqId, SessId};
use crate::sim::report::{ProcStats, TimelineEvent};
use crate::soc::{ProcId, ProcessorSpec, SocSpec};
use crate::util::stats::TimeSeries;
use crate::TimeMs;

/// Execution slots of a processor (helper shared by schedulers and
/// backends).
pub fn proc_slots(spec: &ProcessorSpec) -> usize {
    spec.parallel_slots.max(1)
}

/// How a session issues requests.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalMode {
    /// Re-request as soon as the previous inference finishes (continuous
    /// video processing — the paper's FPS workloads).
    ClosedLoop,
    /// Fixed inter-arrival period, ms.
    Periodic(f64),
    /// Poisson arrivals with the given rate (requests/second).
    Poisson(f64),
}

/// One concurrently-running application.
#[derive(Debug, Clone)]
pub struct App {
    pub model: String,
    pub slo_ms: Option<f64>,
    pub mode: ArrivalMode,
}

impl App {
    pub fn closed_loop(model: &str) -> Self {
        App { model: model.into(), slo_ms: None, mode: ArrivalMode::ClosedLoop }
    }
    pub fn with_slo(model: &str, slo_ms: f64) -> Self {
        App { model: model.into(), slo_ms: Some(slo_ms), mode: ArrivalMode::ClosedLoop }
    }
}

/// Execution configuration, shared by both backends. (Historically the
/// simulator's config; the thread pool interprets `duration_ms` and
/// `tick_ms` as wall-clock milliseconds.)
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub duration_ms: TimeMs,
    /// Governor/thermal/power tick, ms (also the failure-sweep cadence).
    pub tick_ms: f64,
    /// Monitor cache interval (staleness bound of the scheduler's view).
    pub monitor_cache_ms: f64,
    pub seed: u64,
    /// A request fails (is aborted) once its age exceeds
    /// `fail_mult × SLO` (or `fail_mult × 3 × est` without an SLO).
    pub fail_mult: f64,
    /// Ambient temperature override (35 °C for the thermal stress test).
    pub ambient_c: Option<f64>,
    /// Cap on recorded timeline events (Gantt data for Fig 10).
    pub timeline_cap: usize,
    /// Per-session request quota: each session issues at most this many
    /// requests and the run ends once all of them retire (`None` =
    /// unbounded, run to `duration_ms`). This is how finite serving
    /// workloads ("serve 64 requests") are expressed.
    pub max_requests: Option<u64>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            duration_ms: 60_000.0,
            tick_ms: 100.0,
            monitor_cache_ms: 50.0,
            seed: 42,
            fail_mult: 10.0,
            ambient_c: None,
            timeline_cap: 20_000,
            max_requests: None,
        }
    }
}

/// Totally-ordered f64 for event queues (NaN times are a bug).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct OrdF64(pub f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN event time")
    }
}

/// Opaque identifier for one dispatched task instance, allocated by the
/// driver and echoed back in the backend's completion event.
pub type RunToken = u64;

/// Everything a backend needs to execute one scheduled task. The driver
/// pre-prices the policy-dependent costs (transfer, management) so the
/// backend never sees plans or schedulers.
#[derive(Debug, Clone)]
pub struct DispatchCmd {
    pub token: RunToken,
    pub req: ReqId,
    pub session: SessId,
    pub unit: usize,
    pub proc: ProcId,
    /// Unit latency on `proc` at max frequency from the cost model. The
    /// sim scales it by DVFS state and contention; the thread pool paces
    /// synthetic payloads with it.
    pub exec_full_ms: TimeMs,
    /// Inter-processor tensor transfer cost (priced by the scheduler's
    /// runtime model — NNAPI round-trips vs zero-copy DMA).
    pub xfer_ms: TimeMs,
    /// Scheduler decision/management overhead per dispatch.
    pub mgmt_ms: TimeMs,
}

/// One event delivered by [`ExecutionBackend::next_event`].
#[derive(Debug)]
pub enum ExecEvent {
    /// A driver-armed timer (request arrival) is due.
    Timer { at: TimeMs, key: u64 },
    /// A dispatched task finished. `error` is set when the payload
    /// execution failed (thread-pool stage error) — the driver aborts the
    /// request rather than crediting it as completed.
    Completed { at: TimeMs, token: RunToken, error: bool },
    /// Housekeeping tick (thermal/governor in the sim; wall-clock cadence
    /// in the thread pool). The driver runs its failure sweep on it.
    Tick { at: TimeMs },
    /// No pending events remain: the workload has drained.
    Drained { at: TimeMs },
}

impl ExecEvent {
    pub fn at(&self) -> TimeMs {
        match *self {
            ExecEvent::Timer { at, .. }
            | ExecEvent::Completed { at, .. }
            | ExecEvent::Tick { at }
            | ExecEvent::Drained { at } => at,
        }
    }
}

/// Backend-side results folded into the final
/// [`SimReport`](crate::sim::SimReport): processor statistics,
/// power/energy, and the execution timeline.
#[derive(Debug)]
pub struct BackendReport {
    pub backend: &'static str,
    pub procs: Vec<ProcStats>,
    pub power: TimeSeries,
    pub energy_j: f64,
    pub timeline: Vec<TimelineEvent>,
    /// Payload execution errors (thread pool: failed stage executions).
    pub exec_errors: u64,
}

/// One scheduling decision as applied, in dispatch order — the trace that
/// must be identical across backends for a deterministic policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AssignRecord {
    pub req: ReqId,
    pub session: SessId,
    pub unit: usize,
    pub proc: ProcId,
}

/// An execution substrate the shared [`Driver`] can run a workload on.
///
/// The contract mirrors what the discrete-event engine used to do inline:
/// the backend owns the clock, the processors, and the completion/tick
/// event stream; the driver owns requests, the ready queue, and the
/// scheduler. Timers let the driver schedule future arrivals on the
/// backend's clock without knowing whether time is simulated or real.
pub trait ExecutionBackend: Send {
    fn name(&self) -> &'static str;

    fn soc(&self) -> &SocSpec;

    /// Current time on the backend clock, ms.
    fn now(&self) -> TimeMs;

    /// Arm a timer that will surface as [`ExecEvent::Timer`] at `at`.
    fn arm_timer(&mut self, at: TimeMs, key: u64);

    /// Fresh per-processor state views (the monitor layer caches these —
    /// backends should report current truth).
    fn proc_views(&mut self) -> Vec<ProcView>;

    /// Try to place a task. Returns `false` (rejecting the assignment)
    /// when the processor is offline or has no free slot; on success the
    /// completion will arrive as [`ExecEvent::Completed`] with the
    /// command's token.
    fn try_dispatch(&mut self, cmd: DispatchCmd) -> bool;

    /// Number of units of `req` currently resident on processors (used by
    /// the failure sweep to retire aborted requests).
    fn running_units(&self, req: ReqId) -> usize;

    /// Block (wall clock) or advance (virtual clock) until the next
    /// event.
    fn next_event(&mut self) -> ExecEvent;

    /// Tear down and report backend-side statistics over `duration_ms`.
    fn finish(self: Box<Self>, duration_ms: TimeMs) -> BackendReport;
}
