//! Indexed ready queue for the dispatch loop's hot path.
//!
//! The driver used to keep ready tasks in a flat `Vec<PendingTask>` and
//! cancel with `retain(|t| ...)` full-queue scans — O(queue × aborted)
//! on every failure sweep and session stop. This queue keeps the same
//! dense task array (schedulers still see a plain `&[PendingTask]` in
//! the *exact* order the flat vector would have had) but maintains
//! per-request and per-session position indices on the side, so
//! cancellation starts from the victims' known positions instead of
//! scanning, and dispatch removal stays `swap_remove`-cheap.
//!
//! Order contract (the determinism referee — dispatch traces must be
//! bit-identical to the pre-index driver):
//!
//! * `push` appends, exactly like `Vec::push`;
//! * `swap_remove(i)` reorders exactly like `Vec::swap_remove(i)` (the
//!   driver applies dispatched indices in descending order, as before);
//! * the `cancel_*` operations compact survivors in place, preserving
//!   their relative order exactly like `Vec::retain` — but the pass
//!   starts at the first victim's position rather than index 0.
//!
//! The queue also recycles the `dep_procs` buffers of retired tasks
//! (`take_deps_buf`), so steady-state pushes perform no allocation.
//!
//! **Coalescing index (ISSUE 5).** When constructed
//! [`ReadyQueue::with_kinds`], the queue additionally indexes tasks by
//! their *coalescing key* — (per-session model kind, unit), folded by
//! [`coalesce_key`] — so the driver can surface *batchable sets* (tasks
//! fusable into one group dispatch) alongside single tasks without
//! scanning the queue. The index is pure bookkeeping: it never affects
//! task order, and a queue built with [`ReadyQueue::new`] maintains no
//! kind index at all, keeping the batching-off hot path byte-identical
//! to the pre-batching queue.

use crate::sched::{PendingTask, ReqId, SessId};
use crate::soc::ProcId;
use crate::util::rng::splitmix64;
use std::collections::HashMap;

/// Fold a session's model-kind key and a unit index into the coalescing
/// key batchable tasks share: tasks with equal keys run the same unit of
/// structurally-identical models and may fuse into one group dispatch.
/// (SplitMix64 over the XOR keeps distinct `(kind, unit)` pairs from
/// colliding in practice; the kind side is a graph fingerprint already.)
pub fn coalesce_key(kind: u64, unit: usize) -> u64 {
    splitmix64(kind ^ (unit as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
}

/// Back-pointers from a task to its slots inside the index lists,
/// so removing/moving a task never scans a list (a busy session's list
/// can hold its whole ready backlog — a linear scan there would put an
/// O(backlog) factor back on the dispatch path).
#[derive(Debug, Clone, Copy)]
struct Slots {
    req_slot: u32,
    sess_slot: u32,
    /// Slot inside the task's `by_kind` list (unused when the queue has
    /// no coalescing index).
    kind_slot: u32,
}

#[derive(Default)]
pub struct ReadyQueue {
    tasks: Vec<PendingTask>,
    /// Parallel to `tasks`: where each task's position is recorded in
    /// `by_req`/`by_sess`/`by_kind` (kept in lock-step through
    /// swaps/truncations).
    slots: Vec<Slots>,
    /// Positions (into `tasks`) of each open request's ready units.
    by_req: HashMap<ReqId, Vec<u32>>,
    /// Positions of each session's ready units (sessions are dense ids).
    by_sess: Vec<Vec<u32>>,
    /// Per-session model-kind keys (`None` = no coalescing index).
    sess_kinds: Option<Vec<u64>>,
    /// Coalescing index: [`coalesce_key`] → positions of the batchable
    /// set (unsorted — cancellation swaps entries; consumers wanting
    /// queue order sort a scratch copy).
    by_kind: HashMap<u64, Vec<u32>>,
    /// Recycled `dep_procs` buffers from retired tasks.
    spare_deps: Vec<Vec<(usize, ProcId)>>,
    /// Recycled position lists from fully-drained requests/kinds.
    spare_pos: Vec<Vec<u32>>,
    /// Scratch for cancellation position lists (reused across calls).
    scratch: Vec<u32>,
}

impl ReadyQueue {
    pub fn new(sessions: usize) -> Self {
        ReadyQueue {
            tasks: Vec::new(),
            slots: Vec::new(),
            by_req: HashMap::new(),
            by_sess: (0..sessions).map(|_| Vec::new()).collect(),
            sess_kinds: None,
            by_kind: HashMap::new(),
            spare_deps: Vec::new(),
            spare_pos: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// A queue that additionally maintains the coalescing index:
    /// `kinds[s]` is session `s`'s model-kind key (typically the plan
    /// graph's structural fingerprint) — sessions with equal keys are
    /// candidates for cross-session batching.
    pub fn with_kinds(kinds: Vec<u64>) -> Self {
        let mut q = ReadyQueue::new(kinds.len());
        q.sess_kinds = Some(kinds);
        q
    }

    /// Rebind one session's model-kind key (adaptive re-partitioning:
    /// the session's active plan variant changed, and with it its
    /// batching identity — unit indices shift across granularities).
    /// Only valid at a safe switch boundary: the session must have no
    /// queued tasks, so no `by_kind` entries need rekeying
    /// (debug-asserted). No-op on queues without a coalescing index.
    pub fn set_kind(&mut self, sess: SessId, kind: u64) {
        if let Some(kinds) = self.sess_kinds.as_mut() {
            debug_assert!(
                self.by_sess[sess].is_empty(),
                "kind switch for session {sess} with queued tasks"
            );
            kinds[sess] = kind;
        }
    }

    /// The coalescing key of the task at `pos` (meaningless — 0 — when
    /// the queue maintains no kind index).
    pub fn kind_key_at(&self, pos: usize) -> u64 {
        match &self.sess_kinds {
            Some(kinds) => {
                let t = &self.tasks[pos];
                coalesce_key(kinds[t.session], t.unit)
            }
            None => 0,
        }
    }

    /// Positions (unsorted) of every ready task batchable with the task
    /// at `pos`, *including* `pos` itself. Empty when the queue has no
    /// coalescing index.
    pub fn peers(&self, pos: usize) -> &[u32] {
        if self.sess_kinds.is_none() {
            return &[];
        }
        self.by_kind
            .get(&self.kind_key_at(pos))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Size of the batchable set containing the task at `pos` (1 when no
    /// index is maintained — a task is always batchable with itself).
    pub fn group_len(&self, pos: usize) -> usize {
        if self.sess_kinds.is_none() {
            1
        } else {
            self.peers(pos).len()
        }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// The dense task array, in the order the scheduler must see.
    pub fn as_slice(&self) -> &[PendingTask] {
        &self.tasks
    }

    /// A cleared, possibly pre-allocated buffer for a new task's
    /// `dep_procs` (recycled from retired tasks when available).
    pub fn take_deps_buf(&mut self) -> Vec<(usize, ProcId)> {
        self.spare_deps.pop().unwrap_or_default()
    }

    pub fn push(&mut self, task: PendingTask) {
        let pos = self.tasks.len() as u32;
        let spare = &mut self.spare_pos;
        let rlist = self
            .by_req
            .entry(task.req)
            .or_insert_with(|| spare.pop().unwrap_or_default());
        let req_slot = rlist.len() as u32;
        rlist.push(pos);
        let slist = &mut self.by_sess[task.session];
        let sess_slot = slist.len() as u32;
        slist.push(pos);
        let kind_slot = match &self.sess_kinds {
            Some(kinds) => {
                let key = coalesce_key(kinds[task.session], task.unit);
                let klist = self
                    .by_kind
                    .entry(key)
                    .or_insert_with(|| spare.pop().unwrap_or_default());
                let slot = klist.len() as u32;
                klist.push(pos);
                slot
            }
            None => 0,
        };
        self.slots.push(Slots { req_slot, sess_slot, kind_slot });
        self.tasks.push(task);
    }

    /// Drop the task at `pos` from every index list — O(1) via its
    /// recorded slots; the list entries swapped into the freed slots get
    /// their owners' back-pointers fixed up.
    fn unindex(&mut self, pos: usize) {
        let s = self.slots[pos];
        let req = self.tasks[pos].req;
        let sess = self.tasks[pos].session;
        let mut drained = false;
        if let Some(list) = self.by_req.get_mut(&req) {
            list.swap_remove(s.req_slot as usize);
            if let Some(&moved) = list.get(s.req_slot as usize) {
                self.slots[moved as usize].req_slot = s.req_slot;
            }
            drained = list.is_empty();
        }
        if drained {
            if let Some(buf) = self.by_req.remove(&req) {
                self.spare_pos.push(buf);
            }
        }
        let list = &mut self.by_sess[sess];
        list.swap_remove(s.sess_slot as usize);
        if let Some(&moved) = list.get(s.sess_slot as usize) {
            self.slots[moved as usize].sess_slot = s.sess_slot;
        }
        if self.sess_kinds.is_some() {
            let key = self.kind_key_at(pos);
            let mut kind_drained = false;
            if let Some(list) = self.by_kind.get_mut(&key) {
                list.swap_remove(s.kind_slot as usize);
                if let Some(&moved) = list.get(s.kind_slot as usize) {
                    self.slots[moved as usize].kind_slot = s.kind_slot;
                }
                kind_drained = list.is_empty();
            }
            if kind_drained {
                if let Some(buf) = self.by_kind.remove(&key) {
                    self.spare_pos.push(buf);
                }
            }
        }
    }

    /// The task at `old` is about to move to `new`: point its list
    /// entries (found O(1) through its back-pointers) at the new
    /// position. Its own slots don't change.
    fn reindex(&mut self, old: usize, new: usize) {
        let s = self.slots[old];
        let req = self.tasks[old].req;
        let sess = self.tasks[old].session;
        if let Some(list) = self.by_req.get_mut(&req) {
            list[s.req_slot as usize] = new as u32;
        }
        self.by_sess[sess][s.sess_slot as usize] = new as u32;
        if self.sess_kinds.is_some() {
            let key = self.kind_key_at(old);
            if let Some(list) = self.by_kind.get_mut(&key) {
                list[s.kind_slot as usize] = new as u32;
            }
        }
    }

    /// Remove the task at `pos` with `Vec::swap_remove` order semantics
    /// (the last task takes its place). Its `dep_procs` buffer is
    /// recycled.
    pub fn swap_remove(&mut self, pos: usize) {
        let last = self.tasks.len() - 1;
        self.unindex(pos);
        if pos != last {
            self.reindex(last, pos);
        }
        let mut t = self.tasks.swap_remove(pos);
        self.slots.swap_remove(pos);
        let mut deps = std::mem::take(&mut t.dep_procs);
        deps.clear();
        self.spare_deps.push(deps);
    }

    /// Remove every ready task of `req`, preserving survivor order.
    pub fn cancel_request(&mut self, req: ReqId) -> usize {
        let mut positions = std::mem::take(&mut self.scratch);
        positions.clear();
        if let Some(list) = self.by_req.get(&req) {
            positions.extend_from_slice(list);
        }
        let n = self.remove_positions(&mut positions);
        self.scratch = positions;
        n
    }

    /// Remove every ready task of session `sess`, preserving survivor
    /// order (exactly `retain(|t| t.session != sess)`).
    pub fn cancel_session(&mut self, sess: SessId) -> usize {
        let mut positions = std::mem::take(&mut self.scratch);
        positions.clear();
        positions.extend_from_slice(&self.by_sess[sess]);
        let n = self.remove_positions(&mut positions);
        self.scratch = positions;
        n
    }

    /// Remove every ready task of any request in `reqs`, preserving
    /// survivor order (exactly `retain(|t| !reqs.contains(&t.req))`).
    pub fn cancel_requests(&mut self, reqs: &[ReqId]) -> usize {
        let mut positions = std::mem::take(&mut self.scratch);
        positions.clear();
        for r in reqs {
            if let Some(list) = self.by_req.get(r) {
                positions.extend_from_slice(list);
            }
        }
        let n = self.remove_positions(&mut positions);
        self.scratch = positions;
        n
    }

    /// Compact out the tasks at `positions` (unsorted, duplicate-free),
    /// shifting survivors left from the first victim onwards — the same
    /// final order `Vec::retain` would produce, without scanning the
    /// prefix before the first victim.
    fn remove_positions(&mut self, positions: &mut Vec<u32>) -> usize {
        if positions.is_empty() {
            return 0;
        }
        positions.sort_unstable();
        positions.dedup();
        let mut w = positions[0] as usize;
        let mut vi = 0usize;
        for r in w..self.tasks.len() {
            if vi < positions.len() && positions[vi] as usize == r {
                // Victim: unlink, recycle its deps buffer, leave a shell
                // to be truncated (or swapped rightwards) below.
                vi += 1;
                self.unindex(r);
                let mut deps = std::mem::take(&mut self.tasks[r].dep_procs);
                deps.clear();
                self.spare_deps.push(deps);
            } else {
                // Survivor: shift into the first hole, order preserved.
                if r != w {
                    self.reindex(r, w);
                    self.tasks.swap(r, w);
                    self.slots.swap(r, w);
                }
                w += 1;
            }
        }
        self.tasks.truncate(w);
        self.slots.truncate(w);
        positions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(req: ReqId, sess: SessId, unit: usize) -> PendingTask {
        PendingTask {
            req,
            session: sess,
            unit,
            ready_at: 0.0,
            req_arrival: 0.0,
            slo_ms: None,
            remaining_ms: 0.0,
            dep_procs: Vec::new(),
        }
    }

    fn keys(q: &ReadyQueue) -> Vec<(ReqId, SessId, usize)> {
        q.as_slice().iter().map(|t| (t.req, t.session, t.unit)).collect()
    }

    #[test]
    fn swap_remove_matches_vec_semantics() {
        let mut q = ReadyQueue::new(2);
        for (r, s, u) in [(0, 0, 0), (1, 1, 0), (2, 0, 1), (3, 1, 1)] {
            q.push(task(r, s, u));
        }
        q.swap_remove(1); // last (3,1,1) moves into slot 1
        assert_eq!(keys(&q), vec![(0, 0, 0), (3, 1, 1), (2, 0, 1)]);
        q.swap_remove(2);
        assert_eq!(keys(&q), vec![(0, 0, 0), (3, 1, 1)]);
    }

    #[test]
    fn cancel_session_preserves_survivor_order() {
        let mut q = ReadyQueue::new(3);
        for (r, s) in [(0, 0), (1, 1), (2, 2), (3, 1), (4, 0), (5, 1)] {
            q.push(task(r, s, 0));
        }
        assert_eq!(q.cancel_session(1), 3);
        assert_eq!(keys(&q), vec![(0, 0, 0), (2, 2, 0), (4, 0, 0)]);
        assert_eq!(q.cancel_session(1), 0);
    }

    #[test]
    fn cancel_requests_matches_retain() {
        let mut q = ReadyQueue::new(1);
        for r in 0..6u64 {
            q.push(task(r, 0, 0));
        }
        assert_eq!(q.cancel_requests(&[1, 4]), 2);
        assert_eq!(
            keys(&q),
            vec![(0, 0, 0), (2, 0, 0), (3, 0, 0), (5, 0, 0)]
        );
    }

    #[test]
    fn indices_survive_interleaved_ops() {
        let mut q = ReadyQueue::new(2);
        for i in 0..8u64 {
            q.push(task(i, (i % 2) as usize, i as usize));
        }
        q.swap_remove(0); // 7 moves to front
        q.cancel_session(1); // drops 1,3,5 (7 moved; still session 1)… and 7
        // session-1 reqs were 1,3,5,7 — all gone
        assert!(keys(&q).iter().all(|&(_, s, _)| s == 0));
        assert_eq!(q.cancel_request(2), 1);
        assert_eq!(q.cancel_request(2), 0);
        // survivors: 4, 6 in original relative order
        assert_eq!(keys(&q), vec![(4, 0, 4), (6, 0, 6)]);
    }

    /// The coalescing index surfaces batchable sets — same (session
    /// kind, unit) — and stays exact through pushes, dispatch removals,
    /// and cancellations.
    #[test]
    fn coalescing_index_tracks_batchable_sets() {
        // Sessions 0 and 1 run the same model (kind 7); session 2 a
        // different one.
        let mut q = ReadyQueue::with_kinds(vec![7, 7, 99]);
        q.push(task(0, 0, 0)); // pos 0: kind (7, 0)
        q.push(task(1, 1, 0)); // pos 1: kind (7, 0) — peer of pos 0
        q.push(task(2, 2, 0)); // pos 2: kind (99, 0)
        q.push(task(3, 0, 1)); // pos 3: kind (7, 1) — different unit
        assert_eq!(q.group_len(0), 2);
        assert_eq!(q.group_len(1), 2);
        assert_eq!(q.group_len(2), 1);
        assert_eq!(q.group_len(3), 1);
        let mut p: Vec<u32> = q.peers(0).to_vec();
        p.sort_unstable();
        assert_eq!(p, vec![0, 1]);
        assert_eq!(q.kind_key_at(0), q.kind_key_at(1));
        assert_ne!(q.kind_key_at(0), q.kind_key_at(2));
        assert_ne!(q.kind_key_at(0), q.kind_key_at(3));
        // Dispatch removal keeps the index exact (pos 3 moves into 0).
        q.swap_remove(0);
        assert_eq!(keys(&q), vec![(3, 0, 1), (1, 1, 0), (2, 2, 0)]);
        assert_eq!(q.group_len(0), 1); // the moved (7,1) task
        assert_eq!(q.group_len(1), 1); // (7,0) lost its peer
        // Cancellation unlinks from the kind index too.
        q.push(task(4, 1, 1)); // pos 3: (7,1) — peer of pos 0
        assert_eq!(q.group_len(0), 2);
        q.cancel_session(1);
        assert_eq!(q.group_len(0), 1);
        // Un-indexed queues report singleton groups and no peers.
        let mut plain = ReadyQueue::new(2);
        plain.push(task(0, 0, 0));
        plain.push(task(1, 1, 0));
        assert_eq!(plain.group_len(0), 1);
        assert!(plain.peers(0).is_empty());
    }

    /// Rebinding a session's kind at an empty-queue boundary changes its
    /// future batchability without disturbing other sessions' sets.
    #[test]
    fn set_kind_rebinds_batching_identity() {
        let mut q = ReadyQueue::with_kinds(vec![7, 7]);
        q.push(task(0, 0, 0));
        q.push(task(1, 1, 0));
        assert_eq!(q.group_len(0), 2);
        q.swap_remove(1); // session 1 drains
        q.set_kind(1, 42); // its plan variant switched
        q.push(task(2, 1, 0));
        // Same unit, same model — but different granularity: no fusion.
        assert_eq!(q.group_len(0), 1);
        assert_eq!(q.group_len(1), 1);
        // Switching back restores batchability.
        q.swap_remove(1);
        q.set_kind(1, 7);
        q.push(task(3, 1, 0));
        assert_eq!(q.group_len(0), 2);
        // No-op on un-indexed queues.
        let mut plain = ReadyQueue::new(2);
        plain.set_kind(0, 5);
        plain.push(task(0, 0, 0));
        assert_eq!(plain.group_len(0), 1);
    }

    #[test]
    fn deps_buffers_are_recycled() {
        let mut q = ReadyQueue::new(1);
        let mut t = task(0, 0, 0);
        t.dep_procs = vec![(0, 1), (1, 2)];
        q.push(t);
        q.swap_remove(0);
        let buf = q.take_deps_buf();
        assert!(buf.is_empty());
        assert!(buf.capacity() >= 2, "buffer was not recycled");
    }
}
