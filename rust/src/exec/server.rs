//! The `Server` builder: one front door for scheduler-driven execution of
//! multi-model workloads on either backend.
//!
//! ```no_run
//! use adms::exec::{ArrivalMode, Server};
//! use adms::sched::Adms;
//! use adms::soc::dimensity9000;
//!
//! let report = Server::new(dimensity9000())
//!     .scheduler(Adms::default())
//!     .session("retinaface", ArrivalMode::ClosedLoop, None)
//!     .session("arcface_mobile", ArrivalMode::Periodic(33.0), Some(30.0))
//!     .duration_ms(10_000.0)
//!     .run_sim()
//!     .unwrap();
//! println!("p95 {:.2} ms", report.sessions[0].latency.p95());
//! ```
//!
//! `run_sim()` evaluates the workload on the calibrated SoC model;
//! `run_threadpool()` serves it wall-clock on a worker pool. Both return
//! the same [`SimReport`] shape (per-session latency percentiles, SLO
//! attainment, processor stats, assignment trace).

use super::{
    App, ArrivalMode, Driver, EventKind, ExecutionBackend, SessionEvent, SimBackend,
    SimConfig, ThreadPoolBackend,
};
use crate::analyzer::tuner;
use crate::exec::threadpool::SessionWork;
use crate::sched::{
    Adms, Band, BasePolicy, Lookahead, ModelPlan, Pinned, PlanSet, RolloutParams, Scheduler,
    VanillaTflite,
};
use crate::sim::SimReport;
use crate::soc::SocSpec;
use crate::zoo;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Scheduler names accepted by [`scheduler_by_name`] and `--sched`.
pub const SCHEDULER_NAMES: [&str; 5] = ["vanilla", "band", "adms", "pinned", "lookahead"];

/// Construct a scheduler from its CLI name. `vanilla` (alias `tflite`)
/// is the TFLite baseline, `band` the unit-subgraph greedy, `adms` the
/// paper's processor-state-aware policy, `pinned` the best accelerator
/// with CPU fallback, and `lookahead` a base policy (`cfg.lookahead_base`)
/// refined by forked what-if rollouts on the sim backend.
///
/// `lookahead` with `cfg.lookahead_horizon == 0` or
/// `cfg.lookahead_beam <= 1` returns the BARE base policy — the wrapper
/// is never constructed, so `--horizon 0` degenerates to the base
/// byte-exactly by construction (mirroring how `batch_max = 1` never
/// builds the batching machinery). The report's `scheduler` field then
/// names the base policy, which is the honest description of what ran.
pub fn scheduler_by_name(
    name: &str,
    soc: &SocSpec,
    sessions: usize,
    cfg: &SimConfig,
) -> Result<Box<dyn Scheduler>> {
    Ok(match name {
        "vanilla" | "tflite" => Box::new(VanillaTflite::default_for(soc, sessions)),
        "band" => Box::new(Band::new()),
        "adms" => Box::new(Adms::default()),
        "pinned" => {
            let target = soc.best_accelerator().unwrap_or_else(|| soc.cpu_id());
            Box::new(Pinned::new(target, soc.cpu_id()))
        }
        "lookahead" => {
            let base = cfg.lookahead_base.build(soc, sessions);
            if cfg.lookahead_horizon == 0 || cfg.lookahead_beam <= 1 {
                base
            } else {
                Box::new(Lookahead::new(
                    base,
                    RolloutParams {
                        horizon: cfg.lookahead_horizon,
                        beam: cfg.lookahead_beam,
                    },
                ))
            }
        }
        other => bail!(
            "unknown scheduler '{other}' (expected one of: {})",
            SCHEDULER_NAMES.join(", ")
        ),
    })
}

enum SchedChoice {
    Default,
    Named(String),
    Custom(Box<dyn Scheduler>),
}

/// A cloneable, replayable description of one serving run — everything a
/// [`Server`] resolves at build time, minus live state. Schedulers are
/// referenced *by name* (each replay constructs a fresh instance), which
/// is what makes the spec `Clone + Send`: the fleet layer hands one spec
/// per arm to its worker shards, and each shard stamps a per-device seed
/// into `cfg.seed` and calls [`RunSpec::run_sim`] independently. Plans
/// and window tuning are memoized process-wide, so replaying a spec on N
/// shards computes them once.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub soc: SocSpec,
    /// Scheduler name (see [`SCHEDULER_NAMES`]).
    pub scheduler: String,
    pub apps: Vec<App>,
    pub events: Vec<SessionEvent>,
    pub cfg: SimConfig,
    /// Fixed partitioning window (`None` = per-policy default).
    pub window_size: Option<usize>,
}

impl RunSpec {
    /// Materialize a [`Server`] for this spec (validation — unknown
    /// models, schedulers, session references — happens at run time,
    /// exactly as with a hand-built server).
    pub fn server(&self) -> Server {
        let mut s = Server::new(self.soc.clone())
            .scheduler_name(&self.scheduler)
            .apps(self.apps.clone())
            .events(self.events.clone())
            .config(self.cfg.clone());
        if let Some(ws) = self.window_size {
            s = s.window_size(ws);
        }
        s
    }

    /// Replay the spec on the discrete-event SoC backend.
    pub fn run_sim(&self) -> Result<SimReport> {
        self.server().run_sim()
    }

    /// Resolve the spec once without running it: validates every name
    /// (models, scheduler, session references) and *actually builds* the
    /// plans and window tuning, populating the process-wide memo tables.
    /// The fleet layer calls this per arm before spawning shards so
    /// workers start from shared cached partitionings instead of racing
    /// to compute them (`Memo` runs compute outside its lock, so a cold
    /// N-way race would do the most expensive setup work N times).
    pub fn warm_caches(&self) -> Result<()> {
        self.server().build().map(|_| ())
    }
}

/// Builder for a scheduler-driven multi-DNN server. See the module docs
/// for an end-to-end example.
pub struct Server {
    soc: SocSpec,
    sched: SchedChoice,
    apps: Vec<App>,
    work: Vec<Option<SessionWork>>,
    events: Vec<SessionEvent>,
    cfg: SimConfig,
    window_size: Option<usize>,
    pace: f64,
    err: Option<String>,
}

impl Server {
    pub fn new(soc: SocSpec) -> Self {
        Server {
            soc,
            sched: SchedChoice::Default,
            apps: Vec::new(),
            work: Vec::new(),
            events: Vec::new(),
            cfg: SimConfig::default(),
            window_size: None,
            pace: 1.0,
            err: None,
        }
    }

    /// Use a concrete scheduler instance (default: [`Adms`]).
    pub fn scheduler(mut self, s: impl Scheduler + 'static) -> Self {
        self.sched = SchedChoice::Custom(Box::new(s));
        self
    }

    /// Select the scheduler by CLI name (`vanilla` | `band` | `adms` |
    /// `pinned` | `lookahead`); an unknown name surfaces as an error at
    /// run time.
    pub fn scheduler_name(mut self, name: &str) -> Self {
        self.sched = SchedChoice::Named(name.to_string());
        self
    }

    /// Lookahead rollout depth (`--horizon`): completions each forked
    /// what-if rollout observes before scoring. `0` (the default) makes
    /// `lookahead` degenerate to its base policy byte-exactly.
    pub fn lookahead_horizon(mut self, k: u32) -> Self {
        self.cfg.lookahead_horizon = k;
        self
    }

    /// Candidate processors per lookahead decision (`--beam`); `<= 1`
    /// degenerates to the base policy.
    pub fn lookahead_beam(mut self, beam: u32) -> Self {
        self.cfg.lookahead_beam = beam;
        self
    }

    /// Base policy the `lookahead` scheduler refines (`--base`).
    pub fn lookahead_base(mut self, base: BasePolicy) -> Self {
        self.cfg.lookahead_base = base;
        self
    }

    /// Add one session: a zoo model with an arrival process and an
    /// optional SLO. An unknown model surfaces as an error at run time.
    pub fn session(mut self, model: &str, mode: ArrivalMode, slo_ms: Option<f64>) -> Self {
        if zoo::by_name(model).is_none() && self.err.is_none() {
            self.err = Some(format!("unknown model '{model}'"));
        }
        self.apps.push(App { model: model.into(), slo_ms, mode });
        self.work.push(None);
        self
    }

    /// Add one session with real stage payloads for the thread-pool
    /// backend: `stages[u]` executes unit `u` on `input` (unit 0) or its
    /// predecessor's output. Ignored by the sim backend.
    pub fn session_with_stages(
        mut self,
        model: &str,
        mode: ArrivalMode,
        slo_ms: Option<f64>,
        stages: Vec<Arc<dyn crate::runtime::StageExec>>,
        input: Vec<f32>,
    ) -> Self {
        self = self.session(model, mode, slo_ms);
        if let Some(last) = self.work.last_mut() {
            *last = Some(SessionWork { stages, input });
        }
        self
    }

    /// Append pre-built [`App`]s (e.g. a [`crate::workload`] scenario).
    pub fn apps(mut self, apps: Vec<App>) -> Self {
        for a in apps {
            if zoo::by_name(&a.model).is_none() && self.err.is_none() {
                self.err = Some(format!("unknown model '{}'", a.model));
            }
            self.apps.push(a);
            self.work.push(None);
        }
        self
    }

    /// Attach session-lifecycle events (mid-run admission/retirement and
    /// rate changes). Session ids refer to the sessions added so far plus
    /// any added later; `build()` rejects events referencing a session
    /// that was never declared.
    pub fn events(mut self, events: Vec<SessionEvent>) -> Self {
        self.events.extend(events);
        self
    }

    /// Load a dynamic [`Scenario`](crate::scenario::Scenario): its
    /// sessions and lifecycle events replace nothing — they are appended,
    /// so a scenario can run on top of statically-declared sessions.
    pub fn scenario(mut self, sc: &crate::scenario::Scenario) -> Self {
        let base = self.apps.len();
        match sc.compile_with_base(base) {
            Ok((apps, events)) => {
                self = self.apps(apps);
                self.events.extend(events);
            }
            Err(e) => {
                if self.err.is_none() {
                    self.err = Some(format!("scenario '{}': {e}", sc.name));
                }
            }
        }
        self
    }

    /// Run horizon in ms (simulated or wall-clock).
    pub fn duration_ms(mut self, ms: f64) -> Self {
        self.cfg.duration_ms = ms;
        self
    }

    /// Per-session request quota: serve exactly `n` requests per session
    /// and stop once all of them retire.
    pub fn requests(mut self, n: u64) -> Self {
        self.cfg.max_requests = Some(n);
        self
    }

    /// Largest task group one dispatch may fuse (`--batch-max`): ready
    /// tasks of the same (model structure, unit) across sessions coalesce
    /// into one slot-occupying group priced by the per-processor batch
    /// curve. `1` (the default) disables batching bit-exactly.
    pub fn batch_max(mut self, n: usize) -> Self {
        self.cfg.batch_max = n.max(1);
        self
    }

    /// Coalescing window in ms (`--batch-window`): how long a batchable
    /// task may be held past its ready time waiting for peers when its
    /// group is still below `batch_max`. Only meaningful with
    /// `batch_max > 1`.
    pub fn batch_window_ms(mut self, ms: f64) -> Self {
        self.cfg.batch_window_ms = ms.max(0.0);
        self
    }

    /// Per-processor weight-residency budget in bytes (`--mem-budget`):
    /// `0` (the default) disables residency modeling bit-exactly;
    /// [`SPEC_BUDGET`](crate::weights::SPEC_BUDGET) budgets each
    /// processor at its preset `weight_mem_bytes`.
    pub fn mem_budget_bytes(mut self, bytes: u64) -> Self {
        self.cfg.mem_budget_bytes = bytes;
        self
    }

    /// Eviction policy for full residency domains (`--mem-policy`).
    /// Only meaningful with a non-zero memory budget.
    pub fn mem_policy(mut self, policy: crate::weights::MemPolicy) -> Self {
        self.cfg.mem_policy = policy;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Dispatch-timeout multiplier (`--dispatch-timeout`): a dispatched
    /// group is declared lost once it has been in flight longer than this
    /// multiple of its predicted latency. `0` (the default) disables the
    /// sweep bit-exactly.
    pub fn dispatch_timeout(mut self, mult: f64) -> Self {
        self.cfg.dispatch_timeout_mult = mult.max(0.0);
        self
    }

    /// Per-request retry budget for fault-aborted work (`--retry-limit`).
    pub fn retry_limit(mut self, n: u32) -> Self {
        self.cfg.retry_limit = n;
        self
    }

    /// Base retry backoff in ms (`--retry-backoff`), doubled per attempt.
    pub fn retry_backoff_ms(mut self, ms: f64) -> Self {
        self.cfg.retry_backoff_ms = ms.max(0.0);
        self
    }

    /// Quarantine window after a recovery (`--quarantine`): the processor
    /// re-enters scheduling as `Degraded` (re-priced by cost-aware
    /// policies) for this long before being trusted as `Up` again.
    pub fn fault_quarantine_ms(mut self, ms: f64) -> Self {
        self.cfg.fault_quarantine_ms = ms.max(0.0);
        self
    }

    /// Generative fault profile (`--fault-profile`): seeded crash/hang/
    /// transient injection planned over the run horizon. `None` or an
    /// all-zero profile injects nothing.
    pub fn fault_profile(mut self, p: Option<crate::faults::FaultProfile>) -> Self {
        self.cfg.fault_profile = p;
        self
    }

    /// Dedicated fault-plan seed (`--fault-seed`; default: the run seed),
    /// so fault timing can vary while arrivals stay fixed.
    pub fn fault_seed(mut self, seed: Option<u64>) -> Self {
        self.cfg.fault_seed = seed;
        self
    }

    /// Fault-blind mode (`--fault-blind`): faults still happen, but the
    /// driver neither marks health nor retries — the ablation baseline.
    pub fn fault_blind(mut self, blind: bool) -> Self {
        self.cfg.fault_blind = blind;
        self
    }

    /// Runtime plan-granularity adaptation (`--adaptive-plan`). `Off`
    /// (the default) never builds a `PlanSet` or the re-partition
    /// controller — the run is bit-exactly the single-plan one.
    pub fn adaptive_plan(mut self, mode: super::AdaptivePlan) -> Self {
        self.cfg.adaptive_plan = mode;
        self
    }

    /// Per-session cooldown between plan switches (`--replan-cooldown`).
    pub fn replan_cooldown_ms(mut self, ms: f64) -> Self {
        self.cfg.replan_cooldown_ms = ms.max(0.0);
        self
    }

    /// Pressure threshold for stepping finer (`--replan-threshold`);
    /// half of it is the coarser threshold.
    pub fn replan_threshold(mut self, t: f64) -> Self {
        self.cfg.replan_threshold = t.clamp(0.0, 1.0);
        self
    }

    /// Replace the whole execution config (advanced).
    pub fn config(mut self, cfg: SimConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Fix the partitioning window size for every session (default:
    /// tuned per model for ADMS, 1 for the baseline policies — matching
    /// the paper's evaluation arms).
    pub fn window_size(mut self, ws: usize) -> Self {
        self.window_size = Some(ws);
        self
    }

    /// Multiplier on synthetic payload pacing in the thread pool
    /// (`0 < pace ≤ 1` compresses wall time; tests use small values).
    pub fn pace(mut self, pace: f64) -> Self {
        self.pace = pace;
        self
    }

    fn build(self) -> Result<Built> {
        if let Some(e) = self.err {
            bail!("{e}");
        }
        if self.apps.is_empty() {
            bail!("server has no sessions: add at least one with .session(model, mode, slo)");
        }
        for ev in &self.events {
            let s = match ev.kind {
                EventKind::Start { session }
                | EventKind::Stop { session }
                | EventKind::Rate { session, .. } => session,
                // Processor fault events carry no session reference, and
                // the processor id is validated at runtime by the driver
                // (out-of-range = no-op) so scenarios stay SoC-portable.
                EventKind::ProcFail { .. }
                | EventKind::ProcRecover { .. }
                | EventKind::ProcTransient { .. } => continue,
            };
            if s >= self.apps.len() {
                bail!(
                    "lifecycle event at {} ms references unknown session {s} \
                     ({} sessions declared)",
                    ev.at_ms,
                    self.apps.len()
                );
            }
        }
        let scheduler: Box<dyn Scheduler> = match self.sched {
            SchedChoice::Custom(s) => s,
            SchedChoice::Named(n) => {
                scheduler_by_name(&n, &self.soc, self.apps.len(), &self.cfg)?
            }
            SchedChoice::Default => Box::new(Adms::default()),
        };
        // Keyed on `tuning_name`, not `name`: lookahead-over-adms must
        // partition with the same tuned windows bare adms gets.
        let tuned = scheduler.tuning_name() == "adms";
        let mut plans = Vec::new();
        let mut plan_sets = if self.cfg.adaptive_configured() {
            Some((Vec::new(), Vec::new()))
        } else {
            None
        };
        for app in &self.apps {
            let g = zoo::by_name(&app.model)
                .ok_or_else(|| anyhow!("unknown model '{}'", app.model))?;
            let ws = match self.window_size {
                Some(ws) => ws,
                None if tuned => tuner::tuned_window_size(&g, &self.soc, 12),
                None => 1,
            };
            let g = Arc::new(g);
            plans.push(ModelPlan::build_cached(Arc::clone(&g), &self.soc, ws));
            if let Some((sets, active)) = plan_sets.as_mut() {
                // The ladder always contains the statically-chosen window,
                // so the controller starts from exactly the plan a static
                // run would use and only ever *moves away* on evidence.
                let mut ladder = tuner::tune_plan_set(&g, &self.soc, 12);
                if !ladder.contains(&ws) {
                    ladder.push(ws);
                }
                let set = PlanSet::build_cached(g, &self.soc, &ladder);
                active.push(set.position(ws).expect("chosen ws in its own ladder"));
                sets.push(set);
            }
        }
        Ok(Built {
            cfg: self.cfg,
            apps: self.apps,
            plans,
            plan_sets,
            scheduler,
            soc: self.soc,
            work: self.work,
            events: self.events,
            pace: self.pace,
        })
    }

    /// Evaluate the workload on the calibrated discrete-event SoC model.
    pub fn run_sim(self) -> Result<SimReport> {
        let b = self.build()?;
        let backend = Box::new(SimBackend::new(b.soc, b.cfg.clone()));
        Ok(Driver::new(b.cfg, b.apps, b.plans, b.scheduler, backend)
            .events(b.events)
            .plan_sets(b.plan_sets)
            .run())
    }

    /// Serve the workload wall-clock on the worker-pool backend.
    pub fn run_threadpool(self) -> Result<SimReport> {
        let b = self.build()?;
        let work: Vec<SessionWork> = b
            .work
            .into_iter()
            .map(|w| w.unwrap_or_else(|| SessionWork { stages: Vec::new(), input: Vec::new() }))
            .collect();
        let backend = Box::new(ThreadPoolBackend::new(b.soc, b.cfg.clone(), work, b.pace));
        Ok(Driver::new(b.cfg, b.apps, b.plans, b.scheduler, backend)
            .events(b.events)
            .plan_sets(b.plan_sets)
            .run())
    }

    /// Run on a caller-supplied backend (extension point).
    pub fn run_backend(self, backend: Box<dyn ExecutionBackend>) -> Result<SimReport> {
        let b = self.build()?;
        Ok(Driver::new(b.cfg, b.apps, b.plans, b.scheduler, backend)
            .events(b.events)
            .plan_sets(b.plan_sets)
            .run())
    }
}

/// A fully-resolved server, ready to bind to a backend.
struct Built {
    cfg: SimConfig,
    apps: Vec<App>,
    plans: Vec<ModelPlan>,
    /// Granularity ladders + initial active rungs, present only on
    /// adaptive runs (`cfg.adaptive_configured()`).
    plan_sets: Option<(Vec<PlanSet>, Vec<usize>)>,
    scheduler: Box<dyn Scheduler>,
    soc: SocSpec,
    work: Vec<Option<SessionWork>>,
    events: Vec<SessionEvent>,
    pace: f64,
}
