//! The calibrated discrete-event SoC substrate, extracted from the old
//! monolithic engine. Owns the virtual clock, the event heap, thermal/DVFS
//! dynamics, power accounting, and the contention-aware service-time
//! model; the request lifecycle lives in [`Driver`](super::Driver).
//!
//! Hot-path discipline (DESIGN.md §3b): per-event work is O(changed
//! state), not O(processors × slots). Busy/slot time is integrated
//! lazily per processor at occupancy-change points instead of scanning
//! every processor on every heap event; `running_units` is an O(1)
//! counter lookup; and the contention model's distinct-session census is
//! maintained incrementally instead of allocating + sorting + deduping a
//! session vector on every dispatch and view refresh.

use super::{
    proc_slots, BackendReport, DispatchCmd, ExecEvent, ExecutionBackend, OrdF64, RunToken,
    SimConfig,
};
use crate::monitor::{Health, ProcView};
use crate::power::{processor_power_w, EnergyMeter, BOARD_BASELINE_W};
use crate::sched::{ReqId, SessId};
use crate::sim::report::{ProcStats, TimelineEvent};
use crate::soc::SocSpec;
use crate::thermal::ThermalState;
use crate::util::stats::TimeSeries;
use crate::TimeMs;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Sessions touching a processor within this window still count as
/// resident for the contention model.
const SESSION_WINDOW_MS: f64 = 100.0;

#[derive(Debug, Clone)]
enum Ev {
    Timer(u64),
    Complete { proc: usize, token: RunToken },
    Tick,
}

/// Heap entry ordered by (time, class, sequence). Timers sort *after*
/// completions and ticks at the same instant: a driver-armed timer always
/// observes the state changes of same-time events, exactly as when a
/// closed loop arms it while handling the triggering completion. This is
/// what makes a recorded run and its replay (which arms the same timers
/// much earlier, from the replay schedule) process equal-time events in
/// the same order — the foundation of trace record/replay
/// (`scenario::trace`).
#[derive(Debug, Clone)]
struct QEv {
    t: OrdF64,
    seq: u64,
    ev: Ev,
}
impl QEv {
    /// Same-instant ordering class: non-timers first.
    fn class(&self) -> u8 {
        match self.ev {
            Ev::Timer(_) => 1,
            _ => 0,
        }
    }
}
impl PartialEq for QEv {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for QEv {}
impl PartialOrd for QEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .cmp(&other.t)
            .then(self.class().cmp(&other.class()))
            .then(self.seq.cmp(&other.seq))
    }
}

/// A task group currently resident on a processor slot. A fused group
/// occupies ONE slot for its whole batched duration, counts as ONE
/// resident execution for the contention census (the lead's session),
/// and is metered once — but every member request's unit is tracked in
/// `req_units` so the driver's abort bookkeeping sees it as resident.
#[derive(Debug)]
struct Running {
    token: RunToken,
    req: ReqId,
    session: SessId,
    unit: usize,
    start: TimeMs,
    end: TimeMs,
    /// Non-lead group members (empty for single-task dispatches).
    extra: Vec<(ReqId, SessId)>,
}

impl Clone for Running {
    fn clone(&self) -> Self {
        Running {
            token: self.token,
            req: self.req,
            session: self.session,
            unit: self.unit,
            start: self.start,
            end: self.end,
            extra: self.extra.clone(),
        }
    }
    fn clone_from(&mut self, src: &Self) {
        self.token = src.token;
        self.req = src.req;
        self.session = src.session;
        self.unit = src.unit;
        self.start = src.start;
        self.end = src.end;
        self.extra.clone_from(&src.extra);
    }
}

/// Dynamic per-processor state.
struct ProcState {
    thermal: ThermalState,
    running: Vec<Running>,
    /// Failed by the fault layer: refuses all dispatches until recovered
    /// (distinct from thermal `offline`, which the SoC imposes on
    /// itself). Never set on faults-off runs.
    down: bool,
    /// Estimated ms of work resident (running remainder + committed).
    backlog_ms: f64,
    /// Distinct sessions currently running here, with residency counts
    /// (≤ slots entries — maintained on dispatch/complete so the
    /// contention census never rebuilds a sorted session set).
    run_sessions: Vec<(SessId, u32)>,
    /// Sessions that recently touched this processor: (session, time),
    /// at most one entry per session.
    recent_sessions: Vec<(SessId, TimeMs)>,
    /// Clock of the last busy/slot-time integration for this processor.
    /// Occupancy is constant between integration points, so flushing at
    /// every occupancy change (and at ticks/views/finish) accumulates
    /// exactly what the old per-event full scan did.
    last_acct: TimeMs,
    busy_ms: f64,      // wall time with ≥1 task, total
    slot_ms: f64,      // Σ per-slot occupied time, total
    tick_busy_ms: f64, // within current tick (for power/util)
    tick_slot_ms: f64,
    dispatches: u64,
    /// Dispatches that paid a weight cold-load (`cmd.load_ms > 0`).
    cold_loads: u64,
    temp_series: TimeSeries,
    freq_series: TimeSeries,
}

impl ProcState {
    /// Integrate busy/slot time up to `to` at the current occupancy.
    fn account(&mut self, to: TimeMs) {
        let n = self.running.len();
        if n > 0 {
            let dt = to - self.last_acct;
            if dt > 0.0 {
                self.busy_ms += dt;
                self.tick_busy_ms += dt;
                self.slot_ms += dt * n as f64;
                self.tick_slot_ms += dt * n as f64;
            }
        }
        self.last_acct = to;
    }

    fn run_add(&mut self, s: SessId) {
        match self.run_sessions.iter_mut().find(|(rs, _)| *rs == s) {
            Some(e) => e.1 += 1,
            None => self.run_sessions.push((s, 1)),
        }
    }

    fn run_sub(&mut self, s: SessId) {
        if let Some(i) = self.run_sessions.iter().position(|&(rs, _)| rs == s) {
            self.run_sessions[i].1 -= 1;
            if self.run_sessions[i].1 == 0 {
                self.run_sessions.swap_remove(i);
            }
        }
    }
}

impl Clone for ProcState {
    fn clone(&self) -> Self {
        ProcState {
            thermal: self.thermal.clone(),
            running: self.running.clone(),
            down: self.down,
            backlog_ms: self.backlog_ms,
            run_sessions: self.run_sessions.clone(),
            recent_sessions: self.recent_sessions.clone(),
            last_acct: self.last_acct,
            busy_ms: self.busy_ms,
            slot_ms: self.slot_ms,
            tick_busy_ms: self.tick_busy_ms,
            tick_slot_ms: self.tick_slot_ms,
            dispatches: self.dispatches,
            cold_loads: self.cold_loads,
            temp_series: self.temp_series.clone(),
            freq_series: self.freq_series.clone(),
        }
    }
    /// Field-wise `clone_from`: `Vec`/`TimeSeries` buffers are recycled,
    /// which is what makes [`SimBackend::restore`] (and the lookahead
    /// scratch fork) allocation-recycling instead of a fresh deep copy.
    fn clone_from(&mut self, src: &Self) {
        self.thermal = src.thermal.clone();
        self.running.clone_from(&src.running);
        self.down = src.down;
        self.backlog_ms = src.backlog_ms;
        self.run_sessions.clone_from(&src.run_sessions);
        self.recent_sessions.clone_from(&src.recent_sessions);
        self.last_acct = src.last_acct;
        self.busy_ms = src.busy_ms;
        self.slot_ms = src.slot_ms;
        self.tick_busy_ms = src.tick_busy_ms;
        self.tick_slot_ms = src.tick_slot_ms;
        self.dispatches = src.dispatches;
        self.cold_loads = src.cold_loads;
        self.temp_series.clone_from(&src.temp_series);
        self.freq_series.clone_from(&src.freq_series);
    }
}

/// Discrete-event SoC backend on a virtual clock.
///
/// The whole backend is `Clone`: every field is plain owned data (the
/// event heap, per-processor state, meters, series), so [`fork`]
/// (`SimBackend::fork`) is a deep copy whose future evolution is
/// byte-identical to the original's — the fidelity contract behind the
/// lookahead scheduler's what-if rollouts, pinned by
/// `prop_fork_is_byte_identical`.
pub struct SimBackend {
    soc: SocSpec,
    cfg: SimConfig,
    ambient: f64,
    procs: Vec<ProcState>,
    heap: BinaryHeap<Reverse<QEv>>,
    seq: u64,
    now: TimeMs,
    /// Start of the current governor-tick window (time of the last
    /// processed `Ev::Tick`, 0 before the first). Mid-tick utilization is
    /// `tick_busy_ms` over the elapsed part of this window — dividing by
    /// the *full* `tick_ms` (the old bug) understated the reported
    /// `ProcView::util` between ticks — and `finish` integrates energy
    /// over the partial window `[last_tick, duration]` the tick loop
    /// never covers.
    last_tick: TimeMs,
    /// Units of each request currently resident on processors — the O(1)
    /// backing for [`ExecutionBackend::running_units`] (the driver asks
    /// on every abort; scanning every slot of every processor was
    /// O(procs × slots) per query).
    req_units: HashMap<ReqId, u32>,
    energy: EnergyMeter,
    power_series: TimeSeries,
    timeline: Vec<TimelineEvent>,
}

impl Clone for SimBackend {
    fn clone(&self) -> Self {
        SimBackend {
            soc: self.soc.clone(),
            cfg: self.cfg.clone(),
            ambient: self.ambient,
            procs: self.procs.clone(),
            heap: self.heap.clone(),
            seq: self.seq,
            now: self.now,
            last_tick: self.last_tick,
            req_units: self.req_units.clone(),
            energy: self.energy.clone(),
            power_series: self.power_series.clone(),
            timeline: self.timeline.clone(),
        }
    }
    /// Field-wise `clone_from` so restoring into an existing backend
    /// recycles its allocations (`Vec::clone_from` reuses element slots
    /// and calls the elements' own `clone_from`; `BinaryHeap`/`HashMap`
    /// likewise keep their buffers). A `#[derive(Clone)]` would fall back
    /// to `*self = src.clone()` here — a full fresh deep copy — which is
    /// exactly the per-candidate rollout cost this impl removes.
    fn clone_from(&mut self, src: &Self) {
        self.soc = src.soc.clone();
        self.cfg = src.cfg.clone();
        self.ambient = src.ambient;
        self.procs.clone_from(&src.procs);
        self.heap.clone_from(&src.heap);
        self.seq = src.seq;
        self.now = src.now;
        self.last_tick = src.last_tick;
        self.req_units.clone_from(&src.req_units);
        self.energy = src.energy.clone();
        self.power_series.clone_from(&src.power_series);
        self.timeline.clone_from(&src.timeline);
    }
}

impl SimBackend {
    pub fn new(soc: SocSpec, cfg: SimConfig) -> Self {
        let ambient = cfg.ambient_c.unwrap_or(soc.ambient_c);
        let procs = (0..soc.num_processors())
            .map(|_| ProcState {
                thermal: ThermalState::new(ambient),
                running: Vec::new(),
                down: false,
                backlog_ms: 0.0,
                run_sessions: Vec::new(),
                recent_sessions: Vec::new(),
                last_acct: 0.0,
                busy_ms: 0.0,
                slot_ms: 0.0,
                tick_busy_ms: 0.0,
                tick_slot_ms: 0.0,
                dispatches: 0,
                cold_loads: 0,
                temp_series: TimeSeries::default(),
                freq_series: TimeSeries::default(),
            })
            .collect();
        let mut be = SimBackend {
            soc,
            ambient,
            procs,
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            last_tick: 0.0,
            req_units: HashMap::new(),
            energy: EnergyMeter::new(),
            power_series: TimeSeries::default(),
            timeline: Vec::new(),
            cfg,
        };
        let first_tick = be.cfg.tick_ms;
        be.push(first_tick, Ev::Tick);
        be
    }

    fn push(&mut self, t: TimeMs, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse(QEv { t: OrdF64(t), seq: self.seq, ev }));
    }

    /// Governor tick: thermal integration, DVFS governing, power sample.
    fn tick(&mut self, now: TimeMs) {
        let mut total_w = BOARD_BASELINE_W;
        for (i, p) in self.procs.iter_mut().enumerate() {
            let spec = &self.soc.processors[i];
            p.account(now);
            let util_power = (p.tick_busy_ms / self.cfg.tick_ms).clamp(0.0, 1.0);
            let fs = p.thermal.freq_scale(spec);
            let w =
                processor_power_w(spec, util_power, if p.thermal.offline { 0.2 } else { fs });
            p.thermal.integrate(spec, self.ambient, w, self.cfg.tick_ms);
            p.thermal.govern(spec, now);
            total_w += w;
            p.temp_series.push(now, p.thermal.temp_c);
            p.freq_series.push(now, p.thermal.freq_mhz(spec));
            p.tick_busy_ms = 0.0;
            p.tick_slot_ms = 0.0;
        }
        self.energy.accumulate(total_w, self.cfg.tick_ms);
        self.power_series.push(now, total_w);
        self.last_tick = now;
        let next = now + self.cfg.tick_ms;
        self.push(next, Ev::Tick);
    }

    /// Snapshot the full simulation state — heap, clocks, occupancy,
    /// thermal/DVFS, energy meters, series, timeline. The fork and the
    /// original evolve independently and identically from this instant
    /// (`req_units` is keyed-access-only, so `HashMap` iteration order
    /// cannot leak into either timeline).
    pub fn fork(&self) -> SimBackend {
        self.clone()
    }

    /// Rewind to a previously taken [`fork`](SimBackend::fork) snapshot.
    /// This is the allocation-recycling path: the manual
    /// [`Clone::clone_from`] above copies field-wise, so the event heap,
    /// per-processor vectors, series buffers, and the request census all
    /// reuse this backend's existing storage — restoring a scratch fork
    /// across lookahead candidates costs copies, not allocations. The
    /// resulting state is byte-identical to a fresh `snap.clone()`
    /// (`prop_fork_is_byte_identical` drives this through dirty reuse).
    pub fn restore(&mut self, snap: &SimBackend) {
        self.clone_from(snap);
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn soc(&self) -> &SocSpec {
        &self.soc
    }

    fn now(&self) -> TimeMs {
        self.now
    }

    fn arm_timer(&mut self, at: TimeMs, key: u64) {
        self.push(at, Ev::Timer(key));
    }

    fn proc_views(&mut self) -> Vec<ProcView> {
        let mut out = Vec::new();
        self.fill_proc_views(&mut out);
        out
    }

    fn fill_proc_views(&mut self, out: &mut Vec<ProcView>) {
        let now = self.now;
        let soc = &self.soc;
        // Utilization is busy time over the *elapsed* part of the current
        // tick window, not over the full tick: a snapshot 10 ms into a
        // 100 ms tick with the processor saturated must read 1.0, not 0.1
        // (dividing by `tick_ms` was the bug). Scope note: `ProcView::
        // util` is monitor-surface truth for any policy or telemetry
        // reading it — no in-tree scheduler consumes it today (they read
        // load/backlog/headroom), so the fix corrects the reported
        // metric, not historical scheduling decisions. Exactly at a tick
        // boundary nothing of the window has elapsed yet, so fall back
        // to the instantaneous occupancy.
        let elapsed = now - self.last_tick;
        out.extend(self.procs.iter_mut().enumerate().map(|(i, p)| {
            let spec = &soc.processors[i];
            // Bring tick-window utilization current (occupancy since the
            // last change point hasn't been integrated yet).
            p.account(now);
            let util = if elapsed > 0.0 {
                (p.tick_busy_ms / elapsed).min(1.0)
            } else if p.running.is_empty() {
                0.0
            } else {
                1.0
            };
            ProcView {
                id: i,
                kind: spec.kind,
                temp_c: p.thermal.temp_c,
                freq_mhz: p.thermal.freq_mhz(spec),
                freq_scale: p.thermal.freq_scale(spec),
                offline: p.thermal.offline,
                load: p.running.len() as f64 / proc_slots(spec) as f64,
                backlog_ms: p.backlog_ms,
                active_sessions: active_sessions(p, now),
                util,
                headroom_c: p.thermal.headroom_c(spec),
                // Hardware truth carries no beliefs: the driver overlays
                // its health state onto the monitor cache when the fault
                // layer is active.
                health: Health::Up,
            }
        }));
    }

    fn try_dispatch(&mut self, cmd: DispatchCmd) -> bool {
        let now = self.now;
        let spec = &self.soc.processors[cmd.proc];
        let pstate = &self.procs[cmd.proc];
        if pstate.down || pstate.thermal.offline || pstate.running.len() >= proc_slots(spec) {
            return false;
        }
        // Service time: exec at current frequency × contention
        // + transfers + per-dispatch management overhead.
        let fs = pstate.thermal.freq_scale(spec).max(crate::sched::ModelPlan::FREQ_FLOOR);
        let exec = cmd.exec_full_ms / fs;
        // Distinct sessions resident on this processor, counting the
        // dispatching task's session exactly once.
        let nsess =
            active_sessions_with(pstate, now, cmd.session).max(pstate.running.len() + 1);
        let mult = spec.contention_mult(nsess);
        // Background device load (population heterogeneity): unmodeled
        // co-resident work steals a fraction of the processor, stretching
        // execution by 1/(1−bg). Guarded so bg_load = 0 leaves the
        // computation untouched — byte-identical to the pre-knob sim.
        let mut exec_c = exec * mult;
        if self.cfg.bg_load > 0.0 {
            exec_c /= 1.0 - self.cfg.bg_load.clamp(0.0, 0.95);
        }
        // Weight cold-load latency is flash streaming — serialized
        // before execution, unscaled by DVFS or contention (0.0 on
        // unbudgeted runs, keeping this line bit-exact with the
        // pre-residency service time).
        let service = exec_c + cmd.load_ms + cmd.xfer_ms + cmd.mgmt_ms;
        let run = Running {
            token: cmd.token,
            req: cmd.req,
            session: cmd.session,
            unit: cmd.unit,
            start: now,
            end: now + service,
            extra: cmd.extra,
        };
        let end = run.end;
        self.push(end, Ev::Complete { proc: cmd.proc, token: cmd.token });
        *self.req_units.entry(cmd.req).or_insert(0) += 1;
        for &(r, _) in &run.extra {
            *self.req_units.entry(r).or_insert(0) += 1;
        }
        let p = &mut self.procs[cmd.proc];
        // Occupancy changes here: settle the interval at the old count.
        p.account(now);
        p.backlog_ms += service;
        p.dispatches += 1;
        if cmd.load_ms > 0.0 {
            p.cold_loads += 1;
        }
        touch_session(p, cmd.session, now);
        p.run_add(cmd.session);
        p.running.push(run);
        true
    }

    fn running_units(&self, req: ReqId) -> usize {
        self.req_units.get(&req).copied().unwrap_or(0) as usize
    }

    fn set_proc_down(&mut self, proc: usize, down: bool) {
        if let Some(p) = self.procs.get_mut(proc) {
            p.down = down;
        }
    }

    /// Abort a resident group: free its slot, drop every member's unit
    /// from the running census, and leave its heaped `Ev::Complete` as a
    /// stale no-op (`next_event` already skips completions whose token no
    /// longer matches a resident run — the same tolerance that lets a
    /// cancelled request's completion pass silently). Aborted work leaves
    /// no timeline entry: it never finished.
    fn abort(&mut self, token: RunToken) -> bool {
        let now = self.now;
        for proc in 0..self.procs.len() {
            let Some(pos) = self.procs[proc].running.iter().position(|r| r.token == token)
            else {
                continue;
            };
            // Occupancy changes: settle the interval at the old count.
            self.procs[proc].account(now);
            let dead = self.procs[proc].running.remove(pos);
            self.procs[proc].run_sub(dead.session);
            drop_unit(dead.req, &mut self.req_units);
            for &(r, _) in &dead.extra {
                drop_unit(r, &mut self.req_units);
            }
            // Same decrement a completion would apply: backlog was charged
            // the full service time at dispatch.
            self.procs[proc].backlog_ms =
                (self.procs[proc].backlog_ms - (dead.end - dead.start)).max(0.0);
            return true;
        }
        false
    }

    fn fork(&self) -> Option<Box<dyn ExecutionBackend>> {
        Some(Box::new(self.clone()))
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    /// Recycling fork: when the scratch slot already holds a `SimBackend`
    /// (the usual case — the driver keeps one slot across every rollout
    /// candidate of a run), overwrite it in place via
    /// [`restore`](SimBackend::restore) instead of deep-cloning.
    fn fork_into(&self, scratch: &mut Option<Box<dyn ExecutionBackend>>) -> bool {
        if let Some(b) = scratch.as_mut() {
            if let Some(sb) = b.as_any_mut().and_then(|a| a.downcast_mut::<SimBackend>()) {
                sb.restore(self);
                return true;
            }
        }
        *scratch = Some(Box::new(self.clone()));
        true
    }

    fn next_event(&mut self) -> ExecEvent {
        loop {
            let Some(Reverse(QEv { t: OrdF64(now), ev, .. })) = self.heap.pop() else {
                return ExecEvent::Drained { at: self.now };
            };
            // Past the horizon: surface the event untouched so the driver
            // can stop; crucially, do NOT advance the clock or account
            // busy time beyond the duration (preserves the old engine's
            // busy_frac semantics — the lazy accounting below only ever
            // integrates up to the last in-horizon event).
            if now > self.cfg.duration_ms {
                return match ev {
                    Ev::Timer(key) => ExecEvent::Timer { at: now, key },
                    Ev::Tick => ExecEvent::Tick { at: now },
                    Ev::Complete { token, .. } => {
                        ExecEvent::Completed { at: now, token, error: false }
                    }
                };
            }
            self.now = now;

            match ev {
                Ev::Timer(key) => return ExecEvent::Timer { at: now, key },
                Ev::Tick => {
                    self.tick(now);
                    return ExecEvent::Tick { at: now };
                }
                Ev::Complete { proc, token } => {
                    let Some(pos) =
                        self.procs[proc].running.iter().position(|r| r.token == token)
                    else {
                        continue;
                    };
                    // Occupancy changes: settle the interval first.
                    self.procs[proc].account(now);
                    let done = self.procs[proc].running.remove(pos);
                    self.procs[proc].run_sub(done.session);
                    drop_unit(done.req, &mut self.req_units);
                    for &(r, _) in &done.extra {
                        drop_unit(r, &mut self.req_units);
                    }
                    self.procs[proc].backlog_ms =
                        (self.procs[proc].backlog_ms - (done.end - done.start)).max(0.0);
                    if self.timeline.len() < self.cfg.timeline_cap {
                        self.timeline.push(TimelineEvent {
                            proc,
                            session: done.session,
                            req: done.req,
                            unit: done.unit,
                            start: done.start,
                            end: done.end,
                        });
                    }
                    return ExecEvent::Completed { at: now, token, error: false };
                }
            }
        }
    }

    fn finish(self: Box<Self>, duration_ms: TimeMs) -> BackendReport {
        let mut this = *self;
        // Close the books: integrate still-running occupancy up to the
        // last in-horizon event (the old per-event scan had already done
        // this by the time the driver stopped).
        let now = this.now;
        for p in this.procs.iter_mut() {
            p.account(now);
        }
        // Tail window: the governor loop accumulates energy only at tick
        // boundaries, so the partial tick between the last `Ev::Tick` and
        // the end of the run was silently dropped — with `tick_ms = 700`
        // and a 1000 ms horizon, 30 % of the run drew no energy at all.
        // Integrate thermal state and the meter over `[last_tick,
        // duration_ms]` at the post-last-tick processor state so
        // `energy_j`/`avg_watts` cover the full run regardless of how
        // `duration_ms` aligns with the tick cadence. Busy time within
        // the tail is whatever `tick_busy_ms` accumulated up to the last
        // in-horizon event (exact for idle and drained runs, a lower
        // bound when work was still resident at the horizon).
        let tail = duration_ms - this.last_tick;
        if tail > 0.0 {
            let mut total_w = BOARD_BASELINE_W;
            for (i, p) in this.procs.iter_mut().enumerate() {
                let spec = &this.soc.processors[i];
                let util = (p.tick_busy_ms / tail).clamp(0.0, 1.0);
                let fs = p.thermal.freq_scale(spec);
                let w =
                    processor_power_w(spec, util, if p.thermal.offline { 0.2 } else { fs });
                // Complete the window exactly like `tick` does —
                // integrate, govern, sample — so tail-window heating can
                // still trip the throttle counters and the temp/freq
                // series close at the horizon rather than the last tick.
                p.thermal.integrate(spec, this.ambient, w, tail);
                p.thermal.govern(spec, duration_ms);
                total_w += w;
                p.temp_series.push(duration_ms, p.thermal.temp_c);
                p.freq_series.push(duration_ms, p.thermal.freq_mhz(spec));
            }
            this.energy.accumulate(total_w, tail);
            this.power_series.push(duration_ms, total_w);
        }
        let soc = this.soc;
        let procs = this
            .procs
            .into_iter()
            .enumerate()
            .map(|(i, p)| ProcStats {
                name: soc.processors[i].name.clone(),
                busy_frac: p.busy_ms / duration_ms,
                avg_load: p.slot_ms / (duration_ms * proc_slots(&soc.processors[i]) as f64),
                temp: p.temp_series,
                freq: p.freq_series,
                throttle_events: p.thermal.throttle_events,
                first_throttle_ms: p.thermal.first_throttle_ms,
                dispatches: p.dispatches,
                cold_loads: p.cold_loads,
            })
            .collect();
        BackendReport {
            backend: "sim",
            procs,
            power: this.power_series,
            energy_j: this.energy.joules(),
            timeline: this.timeline,
            exec_errors: 0,
        }
    }
}

/// Decrement a request's resident-unit count, removing the entry at 0.
fn drop_unit(req: ReqId, units: &mut HashMap<ReqId, u32>) {
    if let Some(n) = units.get_mut(&req) {
        *n -= 1;
        if *n == 0 {
            units.remove(&req);
        }
    }
}

/// Distinct sessions resident on `p` at `now`: currently running ones
/// (`run_sessions` — incrementally maintained, no duplicates) plus
/// recently-touching ones still inside the window and not already
/// counted. Equal to the old sort+dedup over the concatenated multiset,
/// without building it.
fn active_sessions(p: &ProcState, now: TimeMs) -> usize {
    let mut n = p.run_sessions.len();
    for &(s, t) in &p.recent_sessions {
        if now - t <= SESSION_WINDOW_MS && !p.run_sessions.iter().any(|&(rs, _)| rs == s) {
            n += 1;
        }
    }
    n
}

/// `active_sessions` with `extra` included exactly once (the session of a
/// task being dispatched must not double-count against its own recent
/// residency).
fn active_sessions_with(p: &ProcState, now: TimeMs, extra: SessId) -> usize {
    let mut n = active_sessions(p, now);
    let counted = p.run_sessions.iter().any(|&(rs, _)| rs == extra)
        || p.recent_sessions
            .iter()
            .any(|&(s, t)| s == extra && now - t <= SESSION_WINDOW_MS);
    if !counted {
        n += 1;
    }
    n
}

fn touch_session(p: &mut ProcState, s: SessId, now: TimeMs) {
    p.recent_sessions.retain(|&(ss, t)| ss != s && now - t <= SESSION_WINDOW_MS);
    p.recent_sessions.push((s, now));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::dimensity9000;

    /// Drive the backend the way the driver does: pop events until one
    /// lands past the horizon (or the heap drains), without processing it.
    fn drive_to_end(be: &mut SimBackend, dur: TimeMs) {
        loop {
            match be.next_event() {
                ExecEvent::Drained { .. } => break,
                ev if ev.at() > dur => break,
                _ => {}
            }
        }
    }

    /// Regression for the dropped-tail-window energy bug: an idle run's
    /// energy must equal (board + Σ processor idle) power × duration for
    /// *any* tick size, including tick sizes that do not divide the
    /// horizon (the old accounting stopped at the last full tick, so
    /// `tick_ms = 700` lost 30 % of a 1000 ms run's energy).
    #[test]
    fn idle_energy_covers_full_duration_regardless_of_tick() {
        let soc = dimensity9000();
        let idle_w: f64 =
            BOARD_BASELINE_W + soc.processors.iter().map(|p| p.idle_w).sum::<f64>();
        let dur = 1_000.0;
        for tick_ms in [100.0, 333.0, 700.0] {
            let cfg = SimConfig { duration_ms: dur, tick_ms, ..SimConfig::default() };
            let mut be = Box::new(SimBackend::new(soc.clone(), cfg));
            drive_to_end(&mut be, dur);
            let report = be.finish(dur);
            let want_j = idle_w * dur / 1e3;
            assert!(
                (report.energy_j - want_j).abs() < 1e-9,
                "tick {tick_ms}: energy {} J, want {want_j} J",
                report.energy_j
            );
        }
    }

    /// The tail also closes the power time series and keeps average
    /// power honest: energy over the horizon is exactly idle power even
    /// when the horizon is not a multiple of the tick (900 ms, 400 ms
    /// ticks → the old meter covered only 800 ms).
    #[test]
    fn idle_average_power_is_idle_power() {
        let soc = dimensity9000();
        let idle_w: f64 =
            BOARD_BASELINE_W + soc.processors.iter().map(|p| p.idle_w).sum::<f64>();
        let cfg = SimConfig { duration_ms: 900.0, tick_ms: 400.0, ..SimConfig::default() };
        let mut be = Box::new(SimBackend::new(soc, cfg));
        drive_to_end(&mut be, 900.0);
        let report = be.finish(900.0);
        assert!((report.energy_j / 0.9 - idle_w).abs() < 1e-9);
        // The final power sample sits at the horizon, not the last tick.
        assert_eq!(report.power.times.last().copied(), Some(900.0));
    }

    /// A fused group occupies ONE slot but tracks every member request's
    /// unit as resident, and its single completion drains all of them —
    /// the backend side of the group-dispatch contract (ISSUE 5).
    #[test]
    fn group_dispatch_occupies_one_slot_and_tracks_member_units() {
        let soc = dimensity9000();
        let slots0 = proc_slots(&soc.processors[0]);
        let cfg = SimConfig { duration_ms: 10_000.0, ..SimConfig::default() };
        let mut be = SimBackend::new(soc, cfg);
        let ok = be.try_dispatch(DispatchCmd {
            token: 1,
            req: 0,
            session: 0,
            unit: 0,
            proc: 0,
            exec_full_ms: 5.0,
            xfer_ms: 0.0,
            mgmt_ms: 0.0,
            load_ms: 0.0,
            extra: vec![(1, 1), (2, 2)],
        });
        assert!(ok);
        // One slot occupied by the whole group…
        let views = be.proc_views();
        assert!((views[0].load - 1.0 / slots0 as f64).abs() < 1e-12);
        // …and one resident session for the contention census (the fused
        // execution is a single kernel).
        assert_eq!(views[0].active_sessions, 1);
        // …but every member request's unit is resident.
        for r in 0..3u64 {
            assert_eq!(be.running_units(r), 1, "req {r} not resident");
        }
        // The single group completion drains all members at once.
        loop {
            match be.next_event() {
                ExecEvent::Completed { token, .. } => {
                    assert_eq!(token, 1);
                    break;
                }
                ExecEvent::Drained { .. } => panic!("drained before completion"),
                _ => {}
            }
        }
        for r in 0..3u64 {
            assert_eq!(be.running_units(r), 0, "req {r} leaked a resident unit");
        }
    }

    /// Fault surface: a down processor refuses dispatches; aborting a
    /// resident group frees the slot, drains every member's unit, and the
    /// orphaned completion event never surfaces.
    #[test]
    fn down_proc_refuses_and_abort_suppresses_completion() {
        let soc = dimensity9000();
        let cfg = SimConfig { duration_ms: 10_000.0, ..SimConfig::default() };
        let mut be = SimBackend::new(soc, cfg);
        let cmd = |token: u64| DispatchCmd {
            token,
            req: token,
            session: 0,
            unit: 0,
            proc: 2,
            exec_full_ms: 5.0,
            xfer_ms: 0.0,
            mgmt_ms: 0.0,
            load_ms: 0.0,
            extra: if token == 1 { vec![(10, 1)] } else { Vec::new() },
        };
        assert!(be.try_dispatch(cmd(1)));
        be.set_proc_down(2, true);
        assert!(!be.try_dispatch(cmd(2)), "down processor accepted a dispatch");
        assert_eq!(be.running_units(1), 1);
        assert_eq!(be.running_units(10), 1);
        assert!(be.abort(1), "abort must find the resident group");
        assert!(!be.abort(1), "double abort must be a no-op");
        assert_eq!(be.running_units(1), 0);
        assert_eq!(be.running_units(10), 0);
        // The heaped completion for token 1 must never surface; the run
        // drains (ticks keep firing until past-horizon, so stop there).
        loop {
            match be.next_event() {
                ExecEvent::Completed { token, .. } => panic!("orphan completion {token}"),
                ExecEvent::Drained { .. } => break,
                ev if ev.at() > 10_000.0 => break,
                _ => {}
            }
        }
        // Recovery restores dispatchability.
        be.set_proc_down(2, false);
        assert!(be.try_dispatch(cmd(3)));
    }

    /// Regression for the mid-tick utilization bug: a processor saturated
    /// since the start of the tick window must report util ≈ 1.0 on a
    /// snapshot taken mid-window (the old code divided the busy time by
    /// the full `tick_ms`, reporting 0.5 at the 50 ms point of a 100 ms
    /// tick — wrong monitor-surface truth for anything reading
    /// `ProcView::util`, though no in-tree scheduler does today).
    #[test]
    fn mid_tick_view_reports_elapsed_window_utilization() {
        let soc = dimensity9000();
        let cfg = SimConfig { duration_ms: 10_000.0, tick_ms: 100.0, ..SimConfig::default() };
        let mut be = SimBackend::new(soc, cfg);
        // Fresh backend at t = 0: nothing elapsed, nothing running.
        assert_eq!(be.proc_views()[0].util, 0.0);
        let ok = be.try_dispatch(DispatchCmd {
            token: 1,
            req: 0,
            session: 0,
            unit: 0,
            proc: 0,
            exec_full_ms: 5_000.0,
            xfer_ms: 0.0,
            mgmt_ms: 0.0,
            load_ms: 0.0,
            extra: Vec::new(),
        });
        assert!(ok);
        // Advance mid-tick via a timer at t = 50 (the tick is at 100).
        be.arm_timer(50.0, 7);
        let ev = be.next_event();
        assert_eq!(ev.at(), 50.0);
        let views = be.proc_views();
        assert!(
            views[0].util > 0.99,
            "busy since t=0 but util reads {}",
            views[0].util
        );
        // An idle processor on the same snapshot still reads 0.
        assert_eq!(views[1].util, 0.0);
    }
}
