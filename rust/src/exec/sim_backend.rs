//! The calibrated discrete-event SoC substrate, extracted from the old
//! monolithic engine. Owns the virtual clock, the event heap, thermal/DVFS
//! dynamics, power accounting, and the contention-aware service-time
//! model; the request lifecycle lives in [`Driver`](super::Driver).

use super::{
    proc_slots, BackendReport, DispatchCmd, ExecEvent, ExecutionBackend, OrdF64, RunToken,
    SimConfig,
};
use crate::monitor::ProcView;
use crate::power::{processor_power_w, EnergyMeter, BOARD_BASELINE_W};
use crate::sched::{ReqId, SessId};
use crate::sim::report::{ProcStats, TimelineEvent};
use crate::soc::SocSpec;
use crate::thermal::ThermalState;
use crate::util::stats::TimeSeries;
use crate::TimeMs;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Sessions touching a processor within this window still count as
/// resident for the contention model.
const SESSION_WINDOW_MS: f64 = 100.0;

#[derive(Debug)]
enum Ev {
    Timer(u64),
    Complete { proc: usize, token: RunToken },
    Tick,
}

/// Heap entry ordered by (time, class, sequence). Timers sort *after*
/// completions and ticks at the same instant: a driver-armed timer always
/// observes the state changes of same-time events, exactly as when a
/// closed loop arms it while handling the triggering completion. This is
/// what makes a recorded run and its replay (which arms the same timers
/// much earlier, from the replay schedule) process equal-time events in
/// the same order — the foundation of trace record/replay
/// (`scenario::trace`).
#[derive(Debug)]
struct QEv {
    t: OrdF64,
    seq: u64,
    ev: Ev,
}
impl QEv {
    /// Same-instant ordering class: non-timers first.
    fn class(&self) -> u8 {
        match self.ev {
            Ev::Timer(_) => 1,
            _ => 0,
        }
    }
}
impl PartialEq for QEv {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for QEv {}
impl PartialOrd for QEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .cmp(&other.t)
            .then(self.class().cmp(&other.class()))
            .then(self.seq.cmp(&other.seq))
    }
}

/// A task currently resident on a processor slot.
#[derive(Debug, Clone)]
struct Running {
    token: RunToken,
    req: ReqId,
    session: SessId,
    unit: usize,
    start: TimeMs,
    end: TimeMs,
}

/// Dynamic per-processor state.
struct ProcState {
    thermal: ThermalState,
    running: Vec<Running>,
    /// Estimated ms of work resident (running remainder + committed).
    backlog_ms: f64,
    /// Sessions that recently touched this processor: (session, time).
    recent_sessions: Vec<(SessId, TimeMs)>,
    busy_ms: f64,      // wall time with ≥1 task, total
    slot_ms: f64,      // Σ per-slot occupied time, total
    tick_busy_ms: f64, // within current tick (for power/util)
    tick_slot_ms: f64,
    dispatches: u64,
    temp_series: TimeSeries,
    freq_series: TimeSeries,
}

/// Discrete-event SoC backend on a virtual clock.
pub struct SimBackend {
    soc: SocSpec,
    cfg: SimConfig,
    ambient: f64,
    procs: Vec<ProcState>,
    heap: BinaryHeap<Reverse<QEv>>,
    seq: u64,
    now: TimeMs,
    energy: EnergyMeter,
    power_series: TimeSeries,
    timeline: Vec<TimelineEvent>,
}

impl SimBackend {
    pub fn new(soc: SocSpec, cfg: SimConfig) -> Self {
        let ambient = cfg.ambient_c.unwrap_or(soc.ambient_c);
        let procs = (0..soc.num_processors())
            .map(|_| ProcState {
                thermal: ThermalState::new(ambient),
                running: Vec::new(),
                backlog_ms: 0.0,
                recent_sessions: Vec::new(),
                busy_ms: 0.0,
                slot_ms: 0.0,
                tick_busy_ms: 0.0,
                tick_slot_ms: 0.0,
                dispatches: 0,
                temp_series: TimeSeries::default(),
                freq_series: TimeSeries::default(),
            })
            .collect();
        let mut be = SimBackend {
            soc,
            ambient,
            procs,
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            energy: EnergyMeter::new(),
            power_series: TimeSeries::default(),
            timeline: Vec::new(),
            cfg,
        };
        let first_tick = be.cfg.tick_ms;
        be.push(first_tick, Ev::Tick);
        be
    }

    fn push(&mut self, t: TimeMs, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse(QEv { t: OrdF64(t), seq: self.seq, ev }));
    }

    /// Governor tick: thermal integration, DVFS governing, power sample.
    fn tick(&mut self, now: TimeMs) {
        let mut total_w = BOARD_BASELINE_W;
        for (i, p) in self.procs.iter_mut().enumerate() {
            let spec = &self.soc.processors[i];
            let util_power = (p.tick_busy_ms / self.cfg.tick_ms).clamp(0.0, 1.0);
            let fs = p.thermal.freq_scale(spec);
            let w =
                processor_power_w(spec, util_power, if p.thermal.offline { 0.2 } else { fs });
            p.thermal.integrate(spec, self.ambient, w, self.cfg.tick_ms);
            p.thermal.govern(spec, now);
            total_w += w;
            p.temp_series.push(now, p.thermal.temp_c);
            p.freq_series.push(now, p.thermal.freq_mhz(spec));
            p.tick_busy_ms = 0.0;
            p.tick_slot_ms = 0.0;
        }
        self.energy.accumulate(total_w, self.cfg.tick_ms);
        self.power_series.push(now, total_w);
        let next = now + self.cfg.tick_ms;
        self.push(next, Ev::Tick);
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn soc(&self) -> &SocSpec {
        &self.soc
    }

    fn now(&self) -> TimeMs {
        self.now
    }

    fn arm_timer(&mut self, at: TimeMs, key: u64) {
        self.push(at, Ev::Timer(key));
    }

    fn proc_views(&mut self) -> Vec<ProcView> {
        let now = self.now;
        let soc = &self.soc;
        let tick = self.cfg.tick_ms;
        self.procs
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let spec = &soc.processors[i];
                ProcView {
                    id: i,
                    kind: spec.kind,
                    temp_c: p.thermal.temp_c,
                    freq_mhz: p.thermal.freq_mhz(spec),
                    freq_scale: p.thermal.freq_scale(spec),
                    offline: p.thermal.offline,
                    load: p.running.len() as f64 / proc_slots(spec) as f64,
                    backlog_ms: p.backlog_ms,
                    active_sessions: active_sessions(p, now),
                    util: (p.tick_busy_ms / tick).min(1.0),
                    headroom_c: p.thermal.headroom_c(spec),
                }
            })
            .collect()
    }

    fn try_dispatch(&mut self, cmd: DispatchCmd) -> bool {
        let now = self.now;
        let spec = &self.soc.processors[cmd.proc];
        let pstate = &self.procs[cmd.proc];
        if pstate.thermal.offline || pstate.running.len() >= proc_slots(spec) {
            return false;
        }
        // Service time: exec at current frequency × contention
        // + transfers + per-dispatch management overhead.
        let fs = pstate.thermal.freq_scale(spec).max(0.05);
        let exec = cmd.exec_full_ms / fs;
        // Distinct sessions resident on this processor, counting the
        // dispatching task's session exactly once.
        let nsess =
            active_sessions_with(pstate, now, cmd.session).max(pstate.running.len() + 1);
        let mult = spec.contention_mult(nsess);
        let service = exec * mult + cmd.xfer_ms + cmd.mgmt_ms;
        let run = Running {
            token: cmd.token,
            req: cmd.req,
            session: cmd.session,
            unit: cmd.unit,
            start: now,
            end: now + service,
        };
        let end = run.end;
        self.push(end, Ev::Complete { proc: cmd.proc, token: cmd.token });
        let p = &mut self.procs[cmd.proc];
        p.backlog_ms += service;
        p.dispatches += 1;
        touch_session(p, cmd.session, now);
        p.running.push(run);
        true
    }

    fn running_units(&self, req: ReqId) -> usize {
        self.procs
            .iter()
            .map(|p| p.running.iter().filter(|r| r.req == req).count())
            .sum()
    }

    fn next_event(&mut self) -> ExecEvent {
        loop {
            let Some(Reverse(QEv { t: OrdF64(now), ev, .. })) = self.heap.pop() else {
                return ExecEvent::Drained { at: self.now };
            };
            // Past the horizon: surface the event untouched so the driver
            // can stop; crucially, do NOT account busy time beyond the
            // duration (preserves the old engine's busy_frac semantics).
            if now > self.cfg.duration_ms {
                return match ev {
                    Ev::Timer(key) => ExecEvent::Timer { at: now, key },
                    Ev::Tick => ExecEvent::Tick { at: now },
                    Ev::Complete { token, .. } => {
                        ExecEvent::Completed { at: now, token, error: false }
                    }
                };
            }
            // Accumulate busy time since the previous event.
            let dt = now - self.now;
            if dt > 0.0 {
                for p in self.procs.iter_mut() {
                    if !p.running.is_empty() {
                        p.busy_ms += dt;
                        p.tick_busy_ms += dt;
                        let n = p.running.len() as f64;
                        p.slot_ms += dt * n;
                        p.tick_slot_ms += dt * n;
                    }
                }
            }
            self.now = now;

            match ev {
                Ev::Timer(key) => return ExecEvent::Timer { at: now, key },
                Ev::Tick => {
                    self.tick(now);
                    return ExecEvent::Tick { at: now };
                }
                Ev::Complete { proc, token } => {
                    let Some(pos) =
                        self.procs[proc].running.iter().position(|r| r.token == token)
                    else {
                        continue;
                    };
                    let done = self.procs[proc].running.remove(pos);
                    self.procs[proc].backlog_ms =
                        (self.procs[proc].backlog_ms - (done.end - done.start)).max(0.0);
                    if self.timeline.len() < self.cfg.timeline_cap {
                        self.timeline.push(TimelineEvent {
                            proc,
                            session: done.session,
                            req: done.req,
                            unit: done.unit,
                            start: done.start,
                            end: done.end,
                        });
                    }
                    return ExecEvent::Completed { at: now, token, error: false };
                }
            }
        }
    }

    fn finish(self: Box<Self>, duration_ms: TimeMs) -> BackendReport {
        let this = *self;
        let soc = this.soc;
        let procs = this
            .procs
            .into_iter()
            .enumerate()
            .map(|(i, p)| ProcStats {
                name: soc.processors[i].name.clone(),
                busy_frac: p.busy_ms / duration_ms,
                avg_load: p.slot_ms / (duration_ms * proc_slots(&soc.processors[i]) as f64),
                temp: p.temp_series,
                freq: p.freq_series,
                throttle_events: p.thermal.throttle_events,
                first_throttle_ms: p.thermal.first_throttle_ms,
                dispatches: p.dispatches,
            })
            .collect();
        BackendReport {
            backend: "sim",
            procs,
            power: this.power_series,
            energy_j: this.energy.joules(),
            timeline: this.timeline,
            exec_errors: 0,
        }
    }
}

fn active_sessions(p: &ProcState, now: TimeMs) -> usize {
    let mut sessions: Vec<SessId> = p.running.iter().map(|r| r.session).collect();
    for &(s, t) in &p.recent_sessions {
        if now - t <= SESSION_WINDOW_MS {
            sessions.push(s);
        }
    }
    sessions.sort_unstable();
    sessions.dedup();
    sessions.len()
}

/// `active_sessions` with `extra` included exactly once (the session of a
/// task being dispatched must not double-count against its own recent
/// residency).
fn active_sessions_with(p: &ProcState, now: TimeMs, extra: SessId) -> usize {
    let mut sessions: Vec<SessId> = p.running.iter().map(|r| r.session).collect();
    for &(s, t) in &p.recent_sessions {
        if now - t <= SESSION_WINDOW_MS {
            sessions.push(s);
        }
    }
    sessions.push(extra);
    sessions.sort_unstable();
    sessions.dedup();
    sessions.len()
}

fn touch_session(p: &mut ProcState, s: SessId, now: TimeMs) {
    p.recent_sessions.retain(|&(ss, t)| ss != s && now - t <= SESSION_WINDOW_MS);
    p.recent_sessions.push((s, now));
}
