//! Wall-clock execution on a worker-thread pool.
//!
//! Each SoC processor is stood in for by a group of worker threads (one
//! per execution slot), so the scheduler's placement decisions map onto
//! real OS-level parallelism. A dispatched unit either executes a real
//! PJRT stage payload ([`StageExec`]) or a synthetic payload paced by the
//! cost model's full-frequency estimate — the same estimate the simulator
//! scales, which keeps the two substrates comparable.
//!
//! The clock is `Instant`-based milliseconds since backend start, so the
//! driver's arrival processes, SLOs, and failure budgets all read as
//! wall-clock quantities.

use super::{
    proc_slots, BackendReport, DispatchCmd, ExecEvent, ExecutionBackend, OrdF64, RunToken,
    SimConfig,
};
use crate::monitor::{Health, ProcView};
use crate::runtime::StageExec;
use crate::sched::{ReqId, SessId};
use crate::sim::report::{ProcStats, TimelineEvent};
use crate::soc::SocSpec;
use crate::util::stats::TimeSeries;
use crate::TimeMs;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Stage payloads for one session: `stages[u]` executes unit `u`. When a
/// unit has no stage (or no input buffer yet), the backend falls back to
/// synthetic pacing.
pub struct SessionWork {
    pub stages: Vec<Arc<dyn StageExec>>,
    /// Input fed to unit 0 of every request (the manifest probe input).
    pub input: Vec<f32>,
}

enum Payload {
    /// Sleep for the cost-model estimate (scaled by `pace`).
    Synthetic { ms: f64 },
    /// Execute a real stage on the given input.
    Stage { stage: Arc<dyn StageExec>, input: Vec<f32> },
}

struct Job {
    token: RunToken,
    payload: Payload,
}

struct WorkerMsg {
    token: RunToken,
    output: Option<Vec<f32>>,
    error: Option<String>,
}

struct Inflight {
    req: ReqId,
    session: SessId,
    unit: usize,
    proc: usize,
    start_ms: TimeMs,
    est_ms: f64,
    /// Non-lead group members (empty for single-task dispatches): the
    /// fused group holds one worker slot for its whole batched duration,
    /// but each member request's unit counts as resident.
    extra: Vec<(ReqId, SessId)>,
}

struct ProcPool {
    tx: Sender<Job>,
    handles: Vec<JoinHandle<()>>,
    inflight: usize,
    /// Σ per-slot occupied time (for avg_load).
    slot_ms: f64,
    /// Wall time with ≥ 1 resident task (for busy_frac — same semantics
    /// as the sim backend).
    busy_ms: f64,
    /// Start of the current ≥ 1-task interval, if one is open.
    busy_since: Option<TimeMs>,
    dispatches: u64,
    /// Dispatches that paid a weight cold-load (`cmd.load_ms > 0`).
    cold_loads: u64,
    /// Failed by the fault layer: refuses all dispatches until recovered.
    /// The worker threads stay alive (a real wedged driver keeps its
    /// process too); only admission is cut.
    down: bool,
}

/// Wall-clock serving backend.
pub struct ThreadPoolBackend {
    soc: SocSpec,
    cfg: SimConfig,
    start: Instant,
    pools: Vec<ProcPool>,
    done_rx: Receiver<WorkerMsg>,
    /// Timers armed by the driver: (due, seq, key).
    timers: BinaryHeap<Reverse<(OrdF64, u64, u64)>>,
    timer_seq: u64,
    next_tick: TimeMs,
    inflight: HashMap<RunToken, Inflight>,
    /// Intermediate stage outputs, keyed by request (linear pipelines).
    buffers: HashMap<ReqId, Vec<f32>>,
    work: Vec<SessionWork>,
    /// Multiplier on synthetic sleep times (< 1 compresses wall time in
    /// tests; 1.0 = cost-model pace).
    pace: f64,
    timeline: Vec<TimelineEvent>,
    exec_errors: u64,
}

impl ThreadPoolBackend {
    /// `work` may be empty (all-synthetic) or hold one entry per session.
    pub fn new(soc: SocSpec, cfg: SimConfig, work: Vec<SessionWork>, pace: f64) -> Self {
        let (done_tx, done_rx) = channel::<WorkerMsg>();
        let pools = soc
            .processors
            .iter()
            .map(|spec| {
                let (tx, rx) = channel::<Job>();
                let rx = Arc::new(std::sync::Mutex::new(rx));
                let handles = (0..proc_slots(spec))
                    .map(|_| {
                        let rx = Arc::clone(&rx);
                        let done = done_tx.clone();
                        std::thread::spawn(move || loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            let Ok(job) = job else { break };
                            let msg = match job.payload {
                                Payload::Synthetic { ms } => {
                                    if ms > 0.0 {
                                        std::thread::sleep(Duration::from_secs_f64(ms * 1e-3));
                                    }
                                    WorkerMsg { token: job.token, output: None, error: None }
                                }
                                Payload::Stage { stage, input } => {
                                    match stage.execute_f32(&input) {
                                        Ok(out) => WorkerMsg {
                                            token: job.token,
                                            output: Some(out),
                                            error: None,
                                        },
                                        Err(e) => WorkerMsg {
                                            token: job.token,
                                            output: None,
                                            error: Some(format!("{e:#}")),
                                        },
                                    }
                                }
                            };
                            if done.send(msg).is_err() {
                                break;
                            }
                        })
                    })
                    .collect();
                ProcPool {
                    tx,
                    handles,
                    inflight: 0,
                    slot_ms: 0.0,
                    busy_ms: 0.0,
                    busy_since: None,
                    dispatches: 0,
                    cold_loads: 0,
                    down: false,
                }
            })
            .collect();
        ThreadPoolBackend {
            soc,
            cfg,
            start: Instant::now(),
            pools,
            done_rx,
            timers: BinaryHeap::new(),
            timer_seq: 0,
            next_tick: 0.0,
            inflight: HashMap::new(),
            buffers: HashMap::new(),
            work,
            pace: if pace > 0.0 { pace } else { 1.0 },
            timeline: Vec::new(),
            exec_errors: 0,
        }
    }

    fn wall_ms(&self) -> TimeMs {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// Next due (time, kind): timers vs the housekeeping tick.
    fn next_deadline(&self) -> (TimeMs, bool) {
        let tick_at = self.next_tick + self.cfg.tick_ms;
        match self.timers.peek() {
            Some(Reverse((OrdF64(t), _, _))) if *t <= tick_at => (*t, false),
            _ => (tick_at, true),
        }
    }

    fn handle_done(&mut self, msg: WorkerMsg, at: TimeMs) -> ExecEvent {
        let errored = msg.error.is_some();
        if let Some(e) = msg.error {
            self.exec_errors += 1;
            log::warn!("stage execution failed: {e}");
        }
        if let Some(info) = self.inflight.remove(&msg.token) {
            // Keep the output only when a later stage of this session will
            // consume it — the final stage's output would otherwise leak
            // one buffer per request.
            let has_consumer = self
                .work
                .get(info.session)
                .is_some_and(|w| info.unit + 1 < w.stages.len());
            if has_consumer {
                if let Some(out) = msg.output {
                    self.buffers.insert(info.req, out);
                }
            } else {
                // Final stage (or synthetic unit): drop any lingering
                // intermediate so requests don't leak buffers.
                self.buffers.remove(&info.req);
            }
            let pool = &mut self.pools[info.proc];
            pool.inflight = pool.inflight.saturating_sub(1);
            pool.slot_ms += at - info.start_ms;
            if pool.inflight == 0 {
                if let Some(t0) = pool.busy_since.take() {
                    pool.busy_ms += at - t0;
                }
            }
            if self.timeline.len() < self.cfg.timeline_cap {
                self.timeline.push(TimelineEvent {
                    proc: info.proc,
                    session: info.session,
                    req: info.req,
                    unit: info.unit,
                    start: info.start_ms,
                    end: at,
                });
            }
        }
        ExecEvent::Completed { at, token: msg.token, error: errored }
    }
}

impl ExecutionBackend for ThreadPoolBackend {
    fn name(&self) -> &'static str {
        "threadpool"
    }

    fn soc(&self) -> &SocSpec {
        &self.soc
    }

    fn now(&self) -> TimeMs {
        self.wall_ms()
    }

    fn arm_timer(&mut self, at: TimeMs, key: u64) {
        self.timer_seq += 1;
        self.timers.push(Reverse((OrdF64(at), self.timer_seq, key)));
    }

    fn proc_views(&mut self) -> Vec<ProcView> {
        let ambient = self.cfg.ambient_c.unwrap_or(self.soc.ambient_c);
        let now = self.wall_ms();
        self.soc
            .processors
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let pool = &self.pools[i];
                let slots = proc_slots(spec) as f64;
                let backlog: f64 = self
                    .inflight
                    .values()
                    .filter(|f| f.proc == i)
                    .map(|f| (f.est_ms - (now - f.start_ms)).max(0.0))
                    .sum();
                let mut sessions: Vec<SessId> = self
                    .inflight
                    .values()
                    .filter(|f| f.proc == i)
                    .map(|f| f.session)
                    .collect();
                sessions.sort_unstable();
                sessions.dedup();
                ProcView {
                    id: i,
                    kind: spec.kind,
                    temp_c: ambient,
                    freq_mhz: spec.max_freq(),
                    freq_scale: 1.0,
                    offline: false,
                    load: pool.inflight as f64 / slots,
                    backlog_ms: backlog,
                    active_sessions: sessions.len(),
                    util: (pool.inflight as f64 / slots).min(1.0),
                    headroom_c: spec.throttle_temp_c - ambient,
                    // Beliefs are the driver's: it overlays health onto
                    // the monitor cache when the fault layer is active.
                    health: Health::Up,
                }
            })
            .collect()
    }

    fn try_dispatch(&mut self, cmd: DispatchCmd) -> bool {
        let slots = proc_slots(&self.soc.processors[cmd.proc]);
        if self.pools[cmd.proc].down || self.pools[cmd.proc].inflight >= slots {
            return false;
        }
        // Cold weight loads pace the synthetic payload too: the thread
        // pool stands in for a device whose first touch of a model on a
        // processor streams the weights from flash (0.0 unbudgeted).
        let est_ms = cmd.exec_full_ms + cmd.load_ms + cmd.xfer_ms + cmd.mgmt_ms;
        // Real stage payload when the session provides one for this unit
        // (unit 0 eats the session input; later units the predecessor's
        // output), synthetic cost-model pacing otherwise.
        let payload = match self.work.get(cmd.session) {
            Some(w) if cmd.unit < w.stages.len() => {
                let input = if cmd.unit == 0 {
                    Some(w.input.clone())
                } else {
                    self.buffers.remove(&cmd.req)
                };
                match input {
                    Some(input) => {
                        Payload::Stage { stage: Arc::clone(&w.stages[cmd.unit]), input }
                    }
                    None => Payload::Synthetic { ms: est_ms * self.pace },
                }
            }
            _ => Payload::Synthetic { ms: est_ms * self.pace },
        };
        let now = self.wall_ms();
        let pool = &mut self.pools[cmd.proc];
        if pool.tx.send(Job { token: cmd.token, payload }).is_err() {
            return false;
        }
        if pool.inflight == 0 {
            pool.busy_since = Some(now);
        }
        pool.inflight += 1;
        pool.dispatches += 1;
        if cmd.load_ms > 0.0 {
            pool.cold_loads += 1;
        }
        self.inflight.insert(
            cmd.token,
            Inflight {
                req: cmd.req,
                session: cmd.session,
                unit: cmd.unit,
                proc: cmd.proc,
                start_ms: now,
                est_ms,
                extra: cmd.extra,
            },
        );
        true
    }

    fn running_units(&self, req: ReqId) -> usize {
        self.inflight
            .values()
            .filter(|f| f.req == req || f.extra.iter().any(|&(r, _)| r == req))
            .count()
    }

    fn set_proc_down(&mut self, proc: usize, down: bool) {
        if let Some(p) = self.pools.get_mut(proc) {
            p.down = down;
        }
    }

    /// Abort an inflight group: drop the backend's bookkeeping and close
    /// the pool accounting exactly where `handle_done` would. The worker
    /// thread cannot be interrupted mid-payload — its eventual
    /// `WorkerMsg` finds no `Inflight` entry and surfaces as a completion
    /// for a token the driver no longer tracks, which the driver ignores
    /// (the same tolerance the sim backend's stale-completion skip
    /// provides on the virtual clock). Aborted work leaves no timeline
    /// entry.
    fn abort(&mut self, token: RunToken) -> bool {
        let Some(info) = self.inflight.remove(&token) else {
            return false;
        };
        let at = self.wall_ms();
        self.buffers.remove(&info.req);
        let pool = &mut self.pools[info.proc];
        pool.inflight = pool.inflight.saturating_sub(1);
        pool.slot_ms += at - info.start_ms;
        if pool.inflight == 0 {
            if let Some(t0) = pool.busy_since.take() {
                pool.busy_ms += at - t0;
            }
        }
        true
    }

    fn next_event(&mut self) -> ExecEvent {
        loop {
            // Completions first: they free capacity and unlock work.
            if let Ok(msg) = self.done_rx.try_recv() {
                let at = self.wall_ms();
                return self.handle_done(msg, at);
            }
            let now = self.wall_ms();
            let (deadline, is_tick) = self.next_deadline();
            if deadline <= now {
                if is_tick {
                    self.next_tick += self.cfg.tick_ms;
                    return ExecEvent::Tick { at: now };
                }
                let Reverse((OrdF64(at), _, key)) = self.timers.pop().expect("timer peeked");
                // Report the wall time the timer actually fired at.
                return ExecEvent::Timer { at: now.max(at), key };
            }
            let wait = Duration::from_secs_f64(((deadline - now) * 1e-3).max(1e-4));
            match self.done_rx.recv_timeout(wait) {
                Ok(msg) => {
                    let at = self.wall_ms();
                    return self.handle_done(msg, at);
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    return ExecEvent::Drained { at: self.wall_ms() }
                }
            }
        }
    }

    fn finish(mut self: Box<Self>, duration_ms: TimeMs) -> BackendReport {
        let end = self.wall_ms();
        // Drop the job senders so workers drain and exit, then join.
        let pools = std::mem::take(&mut self.pools);
        let mut procs = Vec::new();
        for (i, pool) in pools.into_iter().enumerate() {
            let ProcPool {
                tx, handles, slot_ms, mut busy_ms, busy_since, dispatches, cold_loads, ..
            } = pool;
            drop(tx);
            for h in handles {
                let _ = h.join();
            }
            if let Some(t0) = busy_since {
                busy_ms += end - t0;
            }
            let spec = &self.soc.processors[i];
            procs.push(ProcStats {
                name: spec.name.clone(),
                busy_frac: (busy_ms / duration_ms).min(1.0),
                avg_load: slot_ms / (duration_ms * proc_slots(spec) as f64),
                temp: TimeSeries::default(),
                freq: TimeSeries::default(),
                throttle_events: 0,
                first_throttle_ms: None,
                dispatches,
                cold_loads,
            });
        }
        BackendReport {
            backend: "threadpool",
            procs,
            power: TimeSeries::default(),
            energy_j: 0.0,
            timeline: self.timeline,
            exec_errors: self.exec_errors,
        }
    }
}
