//! Shared helpers for the experiment modules: framework construction
//! (TFLite / Band / ADMS arms) and simulation wrappers.

use crate::analyzer::tuner;
use crate::graph::Graph;
use crate::sched::{Adms, Band, Scheduler, VanillaTflite};
use crate::sim::{App, Engine, SimConfig, SimReport};
use crate::soc::SocSpec;

/// The paper's three evaluation arms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    Tflite,
    Band,
    Adms,
}

impl Framework {
    pub const ALL: [Framework; 3] = [Framework::Tflite, Framework::Band, Framework::Adms];

    pub fn label(self) -> &'static str {
        match self {
            Framework::Tflite => "TFLite",
            Framework::Band => "Band",
            Framework::Adms => "ADMS",
        }
    }

    /// Partitioning granularity: TFLite/Band use raw (ws = 1) partitions;
    /// ADMS tunes the window per model-SoC pair (paper §3.2).
    pub fn window_size(self, g: &Graph, soc: &SocSpec) -> usize {
        match self {
            Framework::Tflite | Framework::Band => 1,
            Framework::Adms => tuner::tuned_window_size(g, soc, 12),
        }
    }

    pub fn scheduler(self, soc: &SocSpec, sessions: usize) -> Box<dyn Scheduler> {
        match self {
            Framework::Tflite => Box::new(VanillaTflite::default_for(soc, sessions)),
            Framework::Band => Box::new(Band::new()),
            Framework::Adms => Box::new(Adms::default()),
        }
    }
}

/// Run one framework arm over a workload.
pub fn run_framework(
    soc: &SocSpec,
    fw: Framework,
    apps: Vec<App>,
    cfg: SimConfig,
) -> SimReport {
    let sched = fw.scheduler(soc, apps.len());
    let soc2 = soc.clone();
    let mut report = Engine::new(
        soc.clone(),
        cfg,
        apps,
        sched,
        &|g| fw.window_size(g, &soc2),
    )
    .expect("engine build")
    .run();
    report.scheduler = fw.label().to_string();
    report
}

/// Duration helper: full seconds in recorded runs, compressed for CI.
pub fn duration_ms(quick: bool, full_ms: f64) -> f64 {
    if quick {
        (full_ms / 20.0).max(400.0)
    } else {
        full_ms
    }
}

/// Solo closed-loop mean latency of one model under one framework.
pub fn solo_latency_ms(soc: &SocSpec, fw: Framework, model: &str, dur_ms: f64) -> f64 {
    let cfg = SimConfig { duration_ms: dur_ms, ..Default::default() };
    let r = run_framework(soc, fw, vec![App::closed_loop(model)], cfg);
    r.sessions[0].latency.mean()
}
