//! Paper Fig 10: model-level vs subgraph-level scheduling for two
//! concurrent ArcFace-ResNet50 instances on the Huawei P20, shown as a
//! per-processor execution Gantt.
//!
//! Expected shape: model-level (TFLite) leaves the CPU/NPU idle and is
//! bound by the slowest processor; subgraph-level (ADMS) interleaves both
//! models across all processors, lifting utilization (paper: ~50 % →
//! ~95 % on the active processors) and cutting makespan ~24 %.

use crate::sched::{Adms, VanillaTflite};
use crate::sim::{App, Engine, SimConfig, SimReport};
use crate::soc::{kirin970, ProcKind};
use crate::util::table::fnum;

fn gantt(r: &SimReport, soc: &crate::soc::SocSpec, t_end: f64) -> String {
    const COLS: usize = 72;
    let mut out = String::new();
    for (pid, proc_spec) in soc.processors.iter().enumerate() {
        let mut row = vec!['.'; COLS];
        for ev in r.timeline.iter().filter(|e| e.proc == pid && e.start < t_end) {
            let a = ((ev.start / t_end) * COLS as f64) as usize;
            let b = (((ev.end.min(t_end)) / t_end) * COLS as f64).ceil() as usize;
            let mark = char::from_digit(1 + ev.session as u32, 10).unwrap_or('#');
            for c in row.iter_mut().take(b.min(COLS)).skip(a) {
                *c = mark;
            }
        }
        out.push_str(&format!("{:>14} |", proc_spec.kind.label()));
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!(
        "                0 ms {} {} ms   (1/2 = ArcfaceResnet session)\n",
        " ".repeat(COLS.saturating_sub(18)),
        fnum(t_end, 1)
    ));
    out
}

/// First time both sessions have completed ≥ 1 request (the makespan of
/// the first inference round, the quantity Fig 10 visualizes).
fn first_round_end(r: &SimReport) -> f64 {
    let mut done = [f64::INFINITY; 2];
    let mut remaining = [usize::MAX; 2];
    // Requests 0 and 1 are the first arrivals of sessions 0 and 1.
    for s in 0..2 {
        let units: Vec<&crate::sim::TimelineEvent> =
            r.timeline.iter().filter(|e| e.session == s && e.req < 2).collect();
        remaining[s] = units.len();
        done[s] = units.iter().map(|e| e.end).fold(0.0, f64::max);
    }
    done.iter().copied().fold(0.0, f64::max)
}

pub fn run() -> String {
    let soc = kirin970();
    let apps = vec![
        App::closed_loop("arcface_resnet50"),
        App::closed_loop("arcface_resnet50"),
    ];
    let cfg = SimConfig { duration_ms: 2_000.0, ..Default::default() };

    // Model-level: TFLite pins one instance to the GPU, the other to the
    // DSP (the paper's observed placement).
    let gpu = soc.proc_by_kind(ProcKind::Gpu).unwrap();
    let dsp = soc.proc_by_kind(ProcKind::Dsp).unwrap();
    let vanilla = Box::new(VanillaTflite::round_robin(&[gpu, dsp], 2, soc.cpu_id()));
    let r_model = Engine::new(soc.clone(), cfg.clone(), apps.clone(), vanilla, &|_| 1)
        .unwrap()
        .run();

    // Subgraph-level: ADMS with tuned partitioning.
    let r_sub = Engine::new(soc.clone(), cfg, apps, Box::new(Adms::default()), &|g| {
        crate::analyzer::tuner::tuned_window_size(g, &kirin970(), 12)
    })
    .unwrap()
    .run();

    let t_model = first_round_end(&r_model);
    let t_sub = first_round_end(&r_sub);
    let window = t_model.max(t_sub) * 1.05;

    let mut out = String::new();
    out.push_str("### Fig 10 — Model-level vs subgraph-level scheduling (Huawei P20)\n\n");
    out.push_str("Model-level (TFLite):\n");
    out.push_str(&gantt(&r_model, &soc, window));
    out.push_str(&format!(
        "first-round makespan: {} ms; mean latency {} ms; busy processors {}\n\n",
        fnum(t_model, 2),
        fnum(r_model.mean_latency_ms(), 2),
        fnum(100.0 * r_model.avg_busy_frac(), 1)
    ));
    out.push_str("Subgraph-level (ADMS):\n");
    out.push_str(&gantt(&r_sub, &soc, window));
    out.push_str(&format!(
        "first-round makespan: {} ms; mean latency {} ms; busy processors {}\n",
        fnum(t_sub, 2),
        fnum(r_sub.mean_latency_ms(), 2),
        fnum(100.0 * r_sub.avg_busy_frac(), 1)
    ));
    out.push_str(&format!(
        "\nmakespan improvement: {}% (paper reports 23.8%)\n",
        fnum(100.0 * (t_model - t_sub) / t_model, 1)
    ));
    out
}
