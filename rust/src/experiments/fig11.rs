//! Paper Fig 11: power-consumption trend during 60 s of continuous FRS
//! inference on the Redmi K50 Pro.
//!
//! Expected shape: Band shows the highest peaks and largest swings,
//! TFLite the lowest average but deep dips (idle stalls), ADMS a tight
//! band (paper: 7.7–8.1 W) — the stability metric is the trace's
//! standard deviation.

use super::common::{duration_ms, run_framework, Framework};
use crate::sim::{SimConfig, SimReport};
use crate::soc::dimensity9000;
use crate::util::table::{ascii_chart, fnum, Table};
use crate::workload::frs;

pub fn run(quick: bool) -> String {
    let soc = dimensity9000();
    let dur = duration_ms(quick, 60_000.0);
    let cfg = SimConfig { duration_ms: dur, ..Default::default() };
    let reports: Vec<SimReport> = Framework::ALL
        .iter()
        .map(|&fw| run_framework(&soc, fw, frs(), cfg.clone()))
        .collect();
    let mut t = Table::new(
        "Fig 11 — Power trace statistics, 60 s FRS on Redmi K50 Pro",
        &["Framework", "Mean (W)", "Min (W)", "Max (W)", "Std (W)"],
    );
    let mut series = Vec::new();
    for r in &reports {
        t.row(&[
            r.scheduler.clone(),
            fnum(r.power.mean(), 2),
            fnum(r.power.min(), 2),
            fnum(r.power.max(), 2),
            fnum(r.power.std(), 3),
        ]);
        series.push((r.scheduler.clone(), r.power.downsample(70)));
    }
    let mut out = t.render();
    out.push('\n');
    let chart_series: Vec<(&str, &[f64])> = series
        .iter()
        .map(|(n, s)| (n.as_str(), s.values.as_slice()))
        .collect();
    out.push_str(&ascii_chart("device power (W) over time", &chart_series, 10));
    out
}
