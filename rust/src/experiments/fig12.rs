//! Paper Fig 12: temperature and processor-frequency dynamics during a
//! 10-minute thermal stress test on the Redmi K50 Pro.
//!
//! Expected shape: under TFLite the CPU/GPU hit the 68 °C throttle
//! threshold within ~2-3 minutes — CPU frequency collapsing toward 1 GHz
//! and the GPU periodically cutting out; ADMS spreads load and stays
//! below the threshold through most of the window.

use super::common::{duration_ms, run_framework, Framework};
use crate::sim::{SimConfig, SimReport};
use crate::soc::{dimensity9000, ProcKind};
use crate::util::table::{ascii_chart, fnum, Table};
use crate::workload::stress_mix;

pub fn run(quick: bool) -> String {
    let soc = dimensity9000();
    let dur = duration_ms(quick, 600_000.0);
    let cfg = SimConfig { duration_ms: dur, ..Default::default() };
    let mut out = String::new();
    let mut t = Table::new(
        "Fig 12 — Thermal stress summary (10 min, Redmi K50 Pro)",
        &[
            "Framework",
            "CPU max °C",
            "GPU max °C",
            "CPU min MHz",
            "GPU min MHz",
            "Throttle events",
            "First throttle (min)",
        ],
    );
    let mut traces: Vec<(String, SimReport)> = Vec::new();
    for fw in [Framework::Tflite, Framework::Adms] {
        let r = run_framework(&soc, fw, stress_mix(6), cfg.clone());
        let cpu = soc.proc_by_kind(ProcKind::Cpu).unwrap();
        let gpu = soc.proc_by_kind(ProcKind::Gpu).unwrap();
        t.row(&[
            r.scheduler.clone(),
            fnum(r.procs[cpu].temp.max(), 1),
            fnum(r.procs[gpu].temp.max(), 1),
            fnum(r.procs[cpu].freq.min(), 0),
            fnum(r.procs[gpu].freq.min(), 0),
            r.procs.iter().map(|p| p.throttle_events).sum::<u64>().to_string(),
            r.first_throttle_ms()
                .map(|t| fnum(t / 60_000.0, 2))
                .unwrap_or_else(|| "never".into()),
        ]);
        traces.push((r.scheduler.clone(), r));
    }
    out.push_str(&t.render());
    out.push('\n');
    for (name, r) in &traces {
        let cpu = soc.proc_by_kind(ProcKind::Cpu).unwrap();
        let gpu = soc.proc_by_kind(ProcKind::Gpu).unwrap();
        let ct = r.procs[cpu].temp.downsample(70);
        let gt = r.procs[gpu].temp.downsample(70);
        out.push_str(&ascii_chart(
            &format!("{name}: temperature (°C)"),
            &[("cpu", &ct.values), ("gpu", &gt.values)],
            8,
        ));
        let cf = r.procs[cpu].freq.downsample(70);
        let gf = r.procs[gpu].freq.downsample(70);
        out.push_str(&ascii_chart(
            &format!("{name}: frequency (MHz)"),
            &[("cpu", &cf.values), ("gpu", &gf.values)],
            8,
        ));
    }
    out
}
