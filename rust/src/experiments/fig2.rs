//! Paper Fig 2: support for different operation types by the processors
//! of the Redmi K50 Pro (Dimensity 9000).

use crate::graph::OpKind;
use crate::soc::dimensity9000;
use crate::util::table::Table;

pub fn run() -> String {
    let soc = dimensity9000();
    let mut header = vec!["Op type"];
    let names: Vec<String> = soc.processors.iter().map(|p| p.kind.label().to_string()).collect();
    for n in &names {
        header.push(n);
    }
    let mut t = Table::new(
        &format!("Fig 2 — Op support by processor ({})", soc.device),
        &header,
    );
    for k in OpKind::ALL {
        if k == OpKind::Input {
            continue;
        }
        let mut cells = vec![k.label().to_string()];
        for p in &soc.processors {
            cells.push(if p.support.supports(k) { "yes".into() } else { "-".into() });
        }
        t.row(&cells);
    }
    let mut out = t.render();
    out.push('\n');
    for p in &soc.processors {
        out.push_str(&format!(
            "{}: {} / {} op types supported\n",
            p.name,
            p.support.num_supported(),
            OpKind::ALL.len() - 1
        ));
    }
    out
}
