//! Paper Fig 3: average DNN inference latency on single processors vs
//! multi-processor execution, MobileNet and EfficientDet on Kirin 970 and
//! Dimensity 9000.
//!
//! Expected shape: on the Dimensity 9000 accelerators dominate the CPU
//! (NPU up to ~23× on MobileNet); on the Kirin 970, fallback-heavy
//! multi-processor execution can be *slower* than the CPU alone
//! (EfficientDet), reproducing the paper's "multi-processor inference is
//! not always ideal" insight.

use super::common::duration_ms;
use crate::sim::{App, Engine, SimConfig};
use crate::sched::{Pinned, VanillaTflite};
use crate::soc::{soc_by_name, ProcKind};
use crate::util::table::{fnum, Table};

pub fn run(quick: bool) -> String {
    let dur = duration_ms(quick, 10_000.0);
    let mut out = String::new();
    for soc_name in ["kirin970", "dimensity9000"] {
        let soc = soc_by_name(soc_name).unwrap();
        let mut t = Table::new(
            &format!("Fig 3 — Avg latency (ms), {}", soc.device),
            &["Model", "CPU", "GPU", "DSP", "NPU", "Multi-proc (TFLite)"],
        );
        for model in ["mobilenet_v1_quant", "efficientdet"] {
            let mut cells = vec![crate::zoo::display_name(model).to_string()];
            for kind in ProcKind::ALL {
                let cell = match soc.proc_by_kind(kind) {
                    None => "-".to_string(),
                    Some(pid) => {
                        let cfg = SimConfig { duration_ms: dur, fail_mult: 1e12, ..Default::default() };
                        let r = Engine::new(
                            soc.clone(),
                            cfg,
                            vec![App::closed_loop(model)],
                            Box::new(Pinned::new(pid, soc.cpu_id())),
                            &|_| 1,
                        )
                        .unwrap()
                        .run();
                        fnum(r.sessions[0].latency.mean(), 2)
                    }
                };
                cells.push(cell);
            }
            // Multi-processor arm: TFLite with the NNAPI delegate enabled
            // (the paper's §2.2 measurement-study configuration).
            let cfg = SimConfig { duration_ms: dur, fail_mult: 1e12, ..Default::default() };
            let r = Engine::new(
                soc.clone(),
                cfg,
                vec![App::closed_loop(model)],
                Box::new(VanillaTflite::best_accelerator(&soc, 1)),
                &|_| 1,
            )
            .unwrap()
            .run();
            cells.push(fnum(r.sessions[0].latency.mean(), 2));
            t.row(&cells);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}
