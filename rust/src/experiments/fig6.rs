//! Paper Fig 6: relationship between window size, inference latency, and
//! subgraph count for DeepLabV3 on the Redmi K50 Pro.
//!
//! Expected shape: subgraph count collapses as ws grows; latency improves
//! to an optimum (paper: ws = 5), then degrades as large windows push
//! accelerator-viable work back onto the CPU.

use super::common::duration_ms;
use crate::analyzer::tuner::sweep_window_sizes;
use crate::sched::Adms;
use crate::sim::{App, Engine, SimConfig};
use crate::soc::dimensity9000;
use crate::util::table::{ascii_chart, fnum, Table};
use crate::zoo;

pub fn run(quick: bool) -> String {
    let soc = dimensity9000();
    let g = zoo::deeplab_v3();
    let dur = duration_ms(quick, 8_000.0);
    let max_ws = if quick { 8 } else { 12 };
    let sweep = sweep_window_sizes(&g, &soc, max_ws);
    let mut t = Table::new(
        "Fig 6 — Window size vs latency and subgraph count (DeepLabV3, Redmi K50 Pro)",
        &["ws", "Units", "Merged", "Total", "Est latency (ms)", "Measured (ms)", "FPS"],
    );
    let mut lat_series = Vec::new();
    let mut cnt_series = Vec::new();
    let mut best: Option<(usize, f64)> = None;
    for p in &sweep {
        let cfg = SimConfig { duration_ms: dur, ..Default::default() };
        let ws = p.window_size;
        let r = Engine::new(
            soc.clone(),
            cfg,
            vec![App::closed_loop("deeplab_v3")],
            Box::new(Adms::default()),
            &|_| ws,
        )
        .unwrap()
        .run();
        let measured = r.sessions[0].latency.mean();
        let fps = r.sessions[0].fps;
        if best.map(|(_, b)| measured < b).unwrap_or(true) {
            best = Some((ws, measured));
        }
        lat_series.push(measured);
        cnt_series.push(p.total as f64);
        t.row(&[
            ws.to_string(),
            p.units.to_string(),
            p.merged.to_string(),
            p.total.to_string(),
            fnum(p.est_latency_ms, 2),
            fnum(measured, 2),
            fnum(fps, 2),
        ]);
    }
    let mut out = t.render();
    out.push('\n');
    out.push_str(&ascii_chart(
        "measured latency (ms) over window size",
        &[("latency", &lat_series)],
        8,
    ));
    out.push_str(&ascii_chart(
        "total subgraph candidates over window size",
        &[("candidates", &cnt_series)],
        8,
    ));
    if let Some((ws, ms)) = best {
        out.push_str(&format!(
            "\noptimal window size: {ws} ({} ms; paper reports the optimum at ws=5)\n",
            fnum(ms, 2)
        ));
    }
    out
}
