//! Paper Fig 8: processing frame rates (FPS) for the FRS and ROS
//! parallel-inference workloads on the Redmi K50 Pro and Huawei P20,
//! TFLite vs Band vs ADMS. Includes the paper's §4.4 ablation: ADMS with
//! subgraph partitioning disabled (model-level scheduling only).
//!
//! Expected shape: ADMS > Band > TFLite everywhere; ADMS-without-
//! partitioning lands *below* Band.

use super::common::{duration_ms, run_framework, Framework};
use crate::metrics::fps_table;
use crate::sched::Adms;
use crate::sim::{Engine, SimConfig, SimReport};
use crate::soc::soc_by_name;
use crate::util::table::fnum;
use crate::workload::{frs, ros};

/// ADMS with partitioning disabled: whole-model units (huge ws) but the
/// same multi-factor scheduler — the §4.4 ablation arm.
fn adms_no_partition(soc: &crate::soc::SocSpec, apps: Vec<crate::sim::App>, cfg: SimConfig) -> SimReport {
    let mut r = Engine::new(
        soc.clone(),
        cfg,
        apps,
        Box::new(Adms::default()),
        &|g| g.num_real_ops() + 1, // window larger than any run → 1-2 units
    )
    .unwrap()
    .run();
    r.scheduler = "ADMS w/o part.".into();
    r
}

pub fn run(quick: bool) -> String {
    let dur = duration_ms(quick, 60_000.0);
    let mut out = String::new();
    for (scen_name, apps_fn) in [("FRS", frs as fn() -> _), ("ROS", ros as fn() -> _)] {
        for soc_name in ["dimensity9000", "kirin970"] {
            let soc = soc_by_name(soc_name).unwrap();
            let cfg = SimConfig { duration_ms: dur, ..Default::default() };
            let reports: Vec<SimReport> = Framework::ALL
                .iter()
                .map(|&fw| run_framework(&soc, fw, apps_fn(), cfg.clone()))
                .collect();
            let ablation = adms_no_partition(&soc, apps_fn(), cfg);
            let mut all: Vec<&SimReport> = reports.iter().collect();
            all.push(&ablation);
            out.push_str(
                &fps_table(
                    &format!("Fig 8 — {scen_name} FPS on {}", soc.device),
                    &all,
                )
                .render(),
            );
            let tfl = reports[0].pipeline_fps();
            let adms = reports[2].pipeline_fps();
            if tfl > 0.0 {
                out.push_str(&format!(
                    "pipeline-FPS gains — ADMS vs TFLite: {}x   ADMS vs Band: {}x\n\n",
                    fnum(adms / tfl, 2),
                    fnum(adms / reports[1].pipeline_fps().max(1e-9), 2)
                ));
            }
        }
    }
    out
}
