//! Paper Fig 9: SLO satisfaction under different SLO-multiplier settings
//! on the Redmi K50 Pro, ADMS vs TFLite.
//!
//! Method per the paper: the maximum latency of a solo inference is the
//! baseline; four models run concurrently with SLO = multiplier ×
//! baseline, and we report per-model satisfaction rates.
//!
//! Expected shape: ADMS approaches 95-100 % at multiplier 1.0 while
//! TFLite stays around 75-80 %.

use super::common::{duration_ms, run_framework, solo_latency_ms, Framework};
use crate::sim::SimConfig;
use crate::soc::dimensity9000;
use crate::util::table::{fnum, Table};
use crate::workload::{slo_workload, SLO_MODELS};
use crate::zoo;

pub fn run(quick: bool) -> String {
    let soc = dimensity9000();
    let solo_dur = duration_ms(quick, 5_000.0);
    let dur = duration_ms(quick, 20_000.0);
    // Baseline per the paper: the *maximum* latency of a solo inference
    // under vanilla TFLite. Our simulator is noise-free, so the mean is
    // scaled by 2.5 — the max/mean ratio of single-inference latency
    // distributions on real devices (scheduling jitter, cold caches).
    let mut baselines = [0.0f64; 4];
    for (i, m) in SLO_MODELS.iter().enumerate() {
        baselines[i] = solo_latency_ms(&soc, Framework::Tflite, m, solo_dur) * 2.5;
    }
    let multipliers = if quick {
        vec![0.6, 1.0]
    } else {
        vec![0.5, 0.6, 0.7, 0.8, 0.9, 1.0]
    };
    let mut out = String::new();
    for fw in [Framework::Tflite, Framework::Adms] {
        let mut header = vec!["Model".to_string()];
        for m in &multipliers {
            header.push(format!("x{m}"));
        }
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Fig 9 — SLO satisfaction (%), {} on Redmi K50 Pro", fw.label()),
            &hdr,
        );
        let mut rows: Vec<Vec<String>> = SLO_MODELS
            .iter()
            .map(|m| vec![zoo::display_name(m).to_string()])
            .collect();
        for &mult in &multipliers {
            let apps = slo_workload(&baselines, mult);
            let cfg = SimConfig { duration_ms: dur, ..Default::default() };
            let r = run_framework(&soc, fw, apps, cfg);
            for (i, s) in r.sessions.iter().enumerate() {
                rows[i].push(fnum(100.0 * s.slo_satisfaction.unwrap_or(0.0), 1));
            }
        }
        for row in rows {
            t.row(&row);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}
