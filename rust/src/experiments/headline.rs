//! The paper's headline claims (abstract / §6):
//! * up to 4.04× lower multi-DNN inference latency than TFLite
//!   (equivalently, 404 % FPS on the FRS workload);
//! * 24.2 % better energy efficiency (frames/joule) than Band.

use super::common::{duration_ms, run_framework, Framework};
use crate::sim::{SimConfig, SimReport};
use crate::soc::dimensity9000;
use crate::util::table::{fnum, Table};
use crate::workload::frs;

pub fn run(quick: bool) -> String {
    let soc = dimensity9000();
    let dur = duration_ms(quick, 60_000.0);
    let cfg = SimConfig { duration_ms: dur, ..Default::default() };
    let reports: Vec<SimReport> = Framework::ALL
        .iter()
        .map(|&fw| run_framework(&soc, fw, frs(), cfg.clone()))
        .collect();
    let (tfl, band, adms) = (&reports[0], &reports[1], &reports[2]);
    let mut t = Table::new(
        "Headline — ADMS vs baselines (FRS, Redmi K50 Pro)",
        &["Claim", "Paper", "Measured"],
    );
    t.row(&[
        "Latency/FPS gain vs TFLite".into(),
        "4.04x".into(),
        format!("{}x", fnum(adms.pipeline_fps() / tfl.pipeline_fps().max(1e-9), 2)),
    ]);
    t.row(&[
        "FPS gain vs Band".into(),
        "1.21x".into(),
        format!("{}x", fnum(adms.pipeline_fps() / band.pipeline_fps().max(1e-9), 2)),
    ]);
    t.row(&[
        "Energy efficiency vs Band".into(),
        "+24.2%".into(),
        format!(
            "{}%",
            fnum(
                100.0 * (adms.pipeline_frames_per_joule() / band.pipeline_frames_per_joule().max(1e-9) - 1.0),
                1
            )
        ),
    ]);
    t.row(&[
        "Energy efficiency vs TFLite".into(),
        "3.68x".into(),
        format!(
            "{}x",
            fnum(adms.pipeline_frames_per_joule() / tfl.pipeline_frames_per_joule().max(1e-9), 2)
        ),
    ]);
    t.render()
}
