//! Regenerators for every table and figure in the paper's evaluation
//! (DESIGN.md §5 maps each to its module). All output flows through
//! [`crate::util::table`] so results are uniform and diffable; absolute
//! numbers come from the calibrated SoC simulation, and EXPERIMENTS.md
//! records paper-vs-measured for each.

pub mod common;
pub mod table1;
pub mod fig2;
pub mod fig3;
pub mod table2;
pub mod table3;
pub mod fig6;
pub mod table5;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod table6;
pub mod fig11;
pub mod fig12;
pub mod table7;
pub mod headline;

/// All experiment ids in paper order.
pub const EXPERIMENTS: [&str; 15] = [
    "table1", "fig2", "fig3", "table2", "table3", "fig6", "table5", "fig8",
    "fig9", "fig10", "table6", "fig11", "fig12", "table7", "headline",
];

/// Run one experiment by id. `quick` shrinks simulated durations for CI;
/// the recorded EXPERIMENTS.md numbers use `quick = false`.
pub fn run(id: &str, quick: bool) -> anyhow::Result<String> {
    Ok(match id {
        "table1" => table1::run(),
        "fig2" => fig2::run(),
        "fig3" => fig3::run(quick),
        "table2" => table2::run(quick),
        "table3" => table3::run(),
        "fig6" => fig6::run(quick),
        "table5" => table5::run(quick),
        "fig8" => fig8::run(quick),
        "fig9" => fig9::run(quick),
        "fig10" => fig10::run(),
        "table6" => table6::run(quick),
        "fig11" => fig11::run(quick),
        "fig12" => fig12::run(quick),
        "table7" => table7::run(quick),
        "headline" => headline::run(quick),
        _ => anyhow::bail!(
            "unknown experiment '{id}' (known: {})",
            EXPERIMENTS.join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every experiment must run end-to-end in quick mode and produce a
    /// non-trivial report. (Slow by design; still < 1 min in total.)
    #[test]
    fn all_experiments_run_in_quick_mode() {
        for id in EXPERIMENTS {
            let out = run(id, true).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(out.len() > 100, "{id}: output too short:\n{out}");
            assert!(out.contains('|') || out.contains(':'), "{id}: no table");
        }
    }

    #[test]
    fn unknown_id_is_an_error() {
        assert!(run("table99", true).is_err());
    }
}
