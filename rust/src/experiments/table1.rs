//! Paper Table 1: proportional distribution of operation types in the
//! evaluation models (ADD / C2D / DLG / DW / Others percentages).

use crate::graph::OpCategory;
use crate::util::table::{fnum, Table};
use crate::zoo;

const MODELS: [&str; 8] = [
    "arcface_mobile",
    "deeplab_v3",
    "east",
    "efficientnet4",
    "handlmk",
    "icn_quant",
    "inception_v4",
    "mobilenet_v2",
];

pub fn run() -> String {
    let mut t = Table::new(
        "Table 1 — Proportional distribution of operation types (%)",
        &["Model", "ADD", "C2D", "DLG", "DW", "Others", "Ops"],
    );
    for name in MODELS {
        let g = zoo::by_name(name).unwrap();
        let pct = g.category_percentages();
        let get = |c: OpCategory| {
            pct.iter().find(|(k, _)| *k == c).map(|(_, p)| *p).unwrap_or(0.0)
        };
        t.row(&[
            zoo::display_name(name).to_string(),
            fnum(get(OpCategory::Add), 2),
            fnum(get(OpCategory::Conv2d), 2),
            fnum(get(OpCategory::Dlg), 2),
            fnum(get(OpCategory::DepthwiseConv), 2),
            fnum(get(OpCategory::Others), 2),
            g.num_real_ops().to_string(),
        ]);
    }
    t.render()
}
