//! Paper Table 2: impact of parallel inference on latency — average
//! latency (ms) for MobileNetV1 at 1 / 2 / 4 concurrent models on each
//! accelerator of the three devices.
//!
//! Expected shape: near-flat scaling on the Adreno 540 and MediaTek NPU,
//! dramatic collapse on the Hexagon 682 DSP (paper: 46.77 → 609.44 ms)
//! and the Kirin 970 NPU.

use super::common::duration_ms;
use crate::sched::Pinned;
use crate::sim::{Engine, SimConfig};
use crate::soc::{soc_by_name, ProcKind, SocSpec};
use crate::util::table::{fnum, Table};
use crate::workload::concurrent_copies;

fn avg_latency(soc: &SocSpec, kind: ProcKind, n: usize, dur: f64) -> Option<f64> {
    let pid = soc.proc_by_kind(kind)?;
    // Measurement study: no deadline semantics, never abort.
    let cfg = SimConfig { duration_ms: dur, fail_mult: 1e12, ..Default::default() };
    let r = Engine::new(
        soc.clone(),
        cfg,
        concurrent_copies("mobilenet_v1_quant", n),
        Box::new(Pinned::new(pid, soc.cpu_id())),
        &|_| 1,
    )
    .ok()?
    .run();
    let means: Vec<f64> = r.sessions.iter().map(|s| s.latency.mean()).collect();
    Some(means.iter().sum::<f64>() / means.len() as f64)
}

pub fn run(quick: bool) -> String {
    let dur = duration_ms(quick, 10_000.0);
    let mut t = Table::new(
        "Table 2 — MobileNetV1(quant) avg latency (ms) under concurrency",
        &["Device", "Accelerator", "1 model", "2 models", "4 models"],
    );
    let cases: [(&str, ProcKind); 7] = [
        ("dimensity9000", ProcKind::Gpu),
        ("dimensity9000", ProcKind::Dsp),
        ("dimensity9000", ProcKind::Npu),
        ("kirin970", ProcKind::Gpu),
        ("kirin970", ProcKind::Npu),
        ("snapdragon835", ProcKind::Gpu),
        ("snapdragon835", ProcKind::Dsp),
    ];
    for (soc_name, kind) in cases {
        let soc = soc_by_name(soc_name).unwrap();
        let pid = soc.proc_by_kind(kind).unwrap();
        let mut cells = vec![soc.device.clone(), soc.processors[pid].name.clone()];
        for n in [1usize, 2, 4] {
            cells.push(
                avg_latency(&soc, kind, n, dur)
                    .map(|v| fnum(v, 2))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&cells);
    }
    t.render()
}
