//! Paper Table 3: subgraph and operation counts for the six evaluation
//! models under Band-style (window size 1) partitioning on the Redmi
//! K50 Pro — the candidate-explosion measurement motivating ADMS.

use crate::analyzer;
use crate::soc::dimensity9000;
use crate::util::table::Table;
use crate::zoo;

const MODELS: [&str; 6] =
    ["east", "yolo_v3", "mobilenet_v1", "mobilenet_v2", "icn_quant", "deeplab_v3"];

pub fn run() -> String {
    let soc = dimensity9000();
    let mut t = Table::new(
        "Table 3 — Subgraph and op counts, Band partitioning (ws=1), Redmi K50 Pro",
        &["Model", "Operations", "Unit", "Merged", "Total"],
    );
    for name in MODELS {
        let g = zoo::by_name(name).unwrap();
        let p = analyzer::partition(&g, &soc, 1);
        t.row(&[
            zoo::display_name(name).to_string(),
            g.num_real_ops().to_string(),
            p.units.len().to_string(),
            p.merged_candidates.to_string(),
            p.total_subgraphs.to_string(),
        ]);
    }
    t.render()
}
