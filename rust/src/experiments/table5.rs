//! Paper Table 5: subgraph partitioning and single-model inference
//! latency, Band vs ADMS, on the Redmi K50 Pro.
//!
//! Expected shape: ADMS produces far fewer unit/merged subgraphs (its
//! window-size filter) and lower latency on every model.

use super::common::{duration_ms, run_framework, Framework};
use crate::analyzer::{self, tuner};
use crate::sim::{App, SimConfig};
use crate::soc::dimensity9000;
use crate::util::table::{fnum, Table};
use crate::zoo;

const MODELS: [&str; 5] =
    ["mobilenet_v1", "icn_quant", "deeplab_v3", "mobilenet_v2", "yolo_v3"];

pub fn run(quick: bool) -> String {
    let soc = dimensity9000();
    let dur = duration_ms(quick, 10_000.0);
    let mut t = Table::new(
        "Table 5 — Band vs ADMS: partitions and single-model latency (Redmi K50 Pro)",
        &[
            "Model",
            "Units B",
            "Units A",
            "Merged B",
            "Merged A",
            "Latency B (ms)",
            "Latency A (ms)",
            "Δ",
        ],
    );
    for name in MODELS {
        let g = zoo::by_name(name).unwrap();
        let band_p = analyzer::partition(&g, &soc, 1);
        let (ws, _) = tuner::tune_window_size(&g, &soc, 12);
        let adms_p = analyzer::partition(&g, &soc, ws);
        let cfg = SimConfig { duration_ms: dur, ..Default::default() };
        let band_r =
            run_framework(&soc, Framework::Band, vec![App::closed_loop(name)], cfg.clone());
        let adms_r = run_framework(&soc, Framework::Adms, vec![App::closed_loop(name)], cfg);
        let lb = band_r.sessions[0].latency.mean();
        let la = adms_r.sessions[0].latency.mean();
        t.row(&[
            zoo::display_name(name).to_string(),
            band_p.units.len().to_string(),
            adms_p.units.len().to_string(),
            band_p.merged_candidates.to_string(),
            adms_p.merged_candidates.to_string(),
            fnum(lb, 2),
            fnum(la, 2),
            format!("{}%", fnum(100.0 * (lb - la) / lb, 1)),
        ]);
    }
    t.render()
}
