//! Paper Table 6: average power consumption and energy efficiency during
//! the FRS workload on the Redmi K50 Pro.
//!
//! Expected shape: TFLite lowest power but dismal FPS; Band highest
//! power; ADMS slightly below Band in power with the highest FPS and the
//! best frames/joule (paper: 5.74 vs 4.62 vs 1.56).

use super::common::{duration_ms, run_framework, Framework};
use crate::metrics::comparison_table;
use crate::sim::{SimConfig, SimReport};
use crate::soc::dimensity9000;
use crate::workload::frs;

pub fn run(quick: bool) -> String {
    let soc = dimensity9000();
    let dur = duration_ms(quick, 60_000.0);
    let cfg = SimConfig { duration_ms: dur, ..Default::default() };
    let reports: Vec<SimReport> = Framework::ALL
        .iter()
        .map(|&fw| run_framework(&soc, fw, frs(), cfg.clone()))
        .collect();
    let refs: Vec<&SimReport> = reports.iter().collect();
    comparison_table(
        "Table 6 — Power and energy efficiency, FRS on Redmi K50 Pro",
        &refs,
    )
    .render()
}
