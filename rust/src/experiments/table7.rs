//! Paper Table 7: system robustness under stress on the Redmi K50 Pro —
//! failure rate over a long run, maximum concurrent models, and time to
//! thermal throttling at 35 °C ambient.
//!
//! Expected shape: ADMS < Band < TFLite on failure rate; ADMS sustains
//! the most concurrent models; TFLite throttles within minutes while
//! ADMS lasts several times longer.

use super::common::{duration_ms, run_framework, Framework};
use crate::sim::SimConfig;
use crate::soc::dimensity9000;
use crate::util::table::{fnum, Table};
use crate::workload::stress_mix;

/// Highest concurrency (4..=limit) sustained with < 5 % failures.
fn max_concurrent(fw: Framework, dur: f64, limit: usize) -> String {
    let soc = dimensity9000();
    let mut best = 0;
    for n in (4..=limit).step_by(2) {
        let cfg = SimConfig { duration_ms: dur, ..Default::default() };
        let r = run_framework(&soc, fw, stress_mix(n), cfg);
        if r.failure_rate() < 0.05 && r.total_completed() > 0 {
            best = n;
        } else {
            break;
        }
    }
    if best >= limit {
        format!("{limit}+")
    } else if best == 0 {
        "<4".into()
    } else {
        best.to_string()
    }
}

pub fn run(quick: bool) -> String {
    let soc = dimensity9000();
    let long_dur = duration_ms(quick, 600_000.0); // stand-in for the 30-min run
    let conc_dur = duration_ms(quick, 8_000.0);
    let therm_dur = duration_ms(quick, 900_000.0);
    let limit = if quick { 6 } else { 12 };
    let mut t = Table::new(
        "Table 7 — Robustness under stress (Redmi K50 Pro)",
        &[
            "Metric",
            Framework::Tflite.label(),
            Framework::Band.label(),
            Framework::Adms.label(),
        ],
    );
    // Long-duration failure rate (tight SLO-free abort budget).
    let mut fail_cells = vec!["Failure rate (long run, %)".to_string()];
    let mut throttle_cells = vec!["Time to thermal throttling (min)".to_string()];
    for fw in Framework::ALL {
        let cfg = SimConfig {
            duration_ms: long_dur,
            fail_mult: 12.0,
            ..Default::default()
        };
        let r = run_framework(&soc, fw, stress_mix(6), cfg);
        fail_cells.push(fnum(100.0 * r.failure_rate(), 2));
        // Thermal: 35 °C ambient per the paper's chamber test.
        let cfg = SimConfig {
            duration_ms: therm_dur,
            ambient_c: Some(35.0),
            ..Default::default()
        };
        let r = run_framework(&soc, fw, stress_mix(6), cfg);
        throttle_cells.push(
            r.first_throttle_ms()
                .map(|t| fnum(t / 60_000.0, 1))
                .unwrap_or_else(|| format!(">{}", fnum(therm_dur / 60_000.0, 0))),
        );
    }
    t.row(&fail_cells);
    let mut conc_cells = vec!["Max concurrent models".to_string()];
    for fw in Framework::ALL {
        conc_cells.push(max_concurrent(fw, conc_dur, limit));
    }
    t.row(&conc_cells);
    t.row(&throttle_cells);
    t.render()
}
