//! Deterministic fault injection: processor failure as a first-class,
//! schedulable event.
//!
//! "Potentials and Pitfalls" (PAPERS.md) documents that mobile
//! accelerator drivers crash, hang, and mis-execute routinely; a fleet of
//! millions of devices makes per-device flakiness a population-level
//! certainty. This module turns that into something the simulator can
//! reproduce bit-exactly: a [`FaultProfile`] describes per-processor
//! crash / hang / transient-error processes, and [`plan`] expands it into
//! plain [`SessionEvent`]s (`ProcFail` / `ProcRecover` / `ProcTransient`)
//! *before* the run starts — SplitMix64-seeded per processor exactly like
//! the fleet's `device_seed`, so the same `(seed, soc, profile, duration)`
//! always yields the same storm, forks and record/replay see ordinary
//! timer events, and a fleet report stays byte-identical across worker
//! counts.
//!
//! The driver consumes the events (see `exec::driver`): `ProcFail` marks
//! the processor down on the backend and aborts (crash) or strands (hang)
//! its resident groups; `ProcRecover` brings it back through a
//! `Degraded` quarantine; `ProcTransient` turns the next completion on
//! that processor into an execution error. Everything downstream —
//! timeout sweep, bounded retries with exponential backoff, health-masked
//! scheduling — is driver/scheduler policy, not part of the fault model.

use crate::exec::{EventKind, SessionEvent};
use crate::soc::{ProcKind, SocSpec};
use crate::util::rng::{splitmix64, Pcg32};
use crate::TimeMs;

/// Named per-processor fault process. All rates are events per second of
/// (sim) time per processor; `mttr_ms` is the mean down time after a
/// crash or hang. The CPU is always spared: it is the one processor with
/// full op support, and a phone whose CPU is gone is not a scheduling
/// problem.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultProfile {
    pub name: String,
    /// Crash rate (events/s): resident work is aborted immediately.
    pub crash_per_s: f64,
    /// Hang rate (events/s): resident work is stranded until the
    /// dispatch-timeout sweep notices (or the run ends).
    pub hang_per_s: f64,
    /// Transient-error rate (events/s): one completion on the processor
    /// fails without taking the processor down.
    pub transient_per_s: f64,
    /// Mean time to recovery, ms (exponentially distributed).
    pub mttr_ms: f64,
}

impl FaultProfile {
    pub fn off() -> Self {
        FaultProfile {
            name: "off".into(),
            crash_per_s: 0.0,
            hang_per_s: 0.0,
            transient_per_s: 0.0,
            mttr_ms: 0.0,
        }
    }

    /// Occasional flakiness: roughly one crash per processor per 10 s.
    pub fn light() -> Self {
        FaultProfile {
            name: "light".into(),
            crash_per_s: 0.1,
            hang_per_s: 0.02,
            transient_per_s: 0.2,
            mttr_ms: 400.0,
        }
    }

    /// A hostile device: sub-second failure inter-arrivals per processor.
    pub fn heavy() -> Self {
        FaultProfile {
            name: "heavy".into(),
            crash_per_s: 0.5,
            hang_per_s: 0.1,
            transient_per_s: 1.0,
            mttr_ms: 300.0,
        }
    }

    pub fn is_off(&self) -> bool {
        self.crash_per_s <= 0.0 && self.hang_per_s <= 0.0 && self.transient_per_s <= 0.0
    }

    /// Parse a CLI/fleet-arm spelling: `off` | `light` | `heavy`, or a
    /// custom `k=v` list (`crash=0.3,hang=0.05,transient=0.5,mttr=300`,
    /// any subset; unset keys default to 0 except `mttr` which defaults
    /// to 300 ms).
    pub fn parse(s: &str) -> Option<FaultProfile> {
        match s {
            "off" | "none" => return Some(FaultProfile::off()),
            "light" => return Some(FaultProfile::light()),
            "heavy" => return Some(FaultProfile::heavy()),
            _ => {}
        }
        let mut p = FaultProfile { name: s.to_string(), mttr_ms: 300.0, ..FaultProfile::off() };
        for kv in s.split(',') {
            let (k, v) = kv.split_once('=')?;
            let v: f64 = v.trim().parse().ok()?;
            if !v.is_finite() || v < 0.0 {
                return None;
            }
            match k.trim() {
                "crash" => p.crash_per_s = v,
                "hang" => p.hang_per_s = v,
                "transient" => p.transient_per_s = v,
                "mttr" => p.mttr_ms = v,
                _ => return None,
            }
        }
        Some(p)
    }
}

/// Expand a profile into a sorted event list over `[0, duration_ms)`.
///
/// Each non-CPU processor gets its own PRNG stream derived from `seed`
/// via SplitMix64 (the `device_seed` construction), so adding or removing
/// a processor never perturbs another processor's storm. Crashes and
/// hangs form one alternating fail→recover renewal process (a processor
/// is never failed twice without recovering in between); transients are
/// an independent Poisson process drawn from the same per-processor
/// stream after it.
pub fn plan(
    profile: &FaultProfile,
    soc: &SocSpec,
    seed: u64,
    duration_ms: TimeMs,
) -> Vec<SessionEvent> {
    let mut evs: Vec<SessionEvent> = Vec::new();
    if profile.is_off() || duration_ms <= 0.0 {
        return evs;
    }
    let base = splitmix64(seed ^ 0xfa17_c0de_5eed_0001);
    for (p, spec) in soc.processors.iter().enumerate() {
        if spec.kind == ProcKind::Cpu {
            continue;
        }
        let stream = splitmix64(base ^ splitmix64(p as u64 ^ 0x9e37_79b9_7f4a_7c15));
        let mut rng = Pcg32::new(stream, p as u64);
        let fail_rate = profile.crash_per_s + profile.hang_per_s;
        if fail_rate > 0.0 {
            let mut t = 0.0;
            loop {
                t += rng.exp(fail_rate) * 1000.0;
                if t >= duration_ms {
                    break;
                }
                let hang = rng.next_f64() * fail_rate < profile.hang_per_s;
                evs.push(SessionEvent { at_ms: t, kind: EventKind::ProcFail { proc: p, hang } });
                if profile.mttr_ms > 0.0 {
                    t += rng.exp(1.0 / profile.mttr_ms);
                }
                if t >= duration_ms {
                    break;
                }
                evs.push(SessionEvent { at_ms: t, kind: EventKind::ProcRecover { proc: p } });
            }
        }
        if profile.transient_per_s > 0.0 {
            let mut t = 0.0;
            loop {
                t += rng.exp(profile.transient_per_s) * 1000.0;
                if t >= duration_ms {
                    break;
                }
                evs.push(SessionEvent { at_ms: t, kind: EventKind::ProcTransient { proc: p } });
            }
        }
    }
    // Stable sort: equal-time events keep generation order (ascending
    // processor id), so the driver's arming order — and therefore the
    // event heap's sequence tiebreak — is deterministic.
    evs.sort_by(|a, b| a.at_ms.partial_cmp(&b.at_ms).expect("NaN fault time"));
    evs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::presets::dimensity9000;

    fn fmt(evs: &[SessionEvent]) -> String {
        format!("{evs:?}")
    }

    #[test]
    fn off_profile_plans_nothing() {
        let soc = dimensity9000();
        assert!(plan(&FaultProfile::off(), &soc, 42, 60_000.0).is_empty());
        assert!(plan(&FaultProfile::heavy(), &soc, 42, 0.0).is_empty());
    }

    #[test]
    fn plan_is_deterministic_in_seed() {
        let soc = dimensity9000();
        let a = plan(&FaultProfile::heavy(), &soc, 7, 10_000.0);
        let b = plan(&FaultProfile::heavy(), &soc, 7, 10_000.0);
        assert!(!a.is_empty());
        assert_eq!(fmt(&a), fmt(&b));
        let c = plan(&FaultProfile::heavy(), &soc, 8, 10_000.0);
        assert_ne!(fmt(&a), fmt(&c), "different seeds should give different storms");
    }

    #[test]
    fn plan_is_sorted_in_window_and_spares_cpu() {
        let soc = dimensity9000();
        let cpu = soc.cpu_id();
        let evs = plan(&FaultProfile::heavy(), &soc, 42, 20_000.0);
        let mut last = 0.0;
        for ev in &evs {
            assert!(ev.at_ms >= last && ev.at_ms < 20_000.0, "out of window: {ev:?}");
            last = ev.at_ms;
            let proc = match ev.kind {
                EventKind::ProcFail { proc, .. }
                | EventKind::ProcRecover { proc }
                | EventKind::ProcTransient { proc } => proc,
                _ => panic!("non-fault event in plan: {ev:?}"),
            };
            assert_ne!(proc, cpu, "the CPU must be spared");
        }
    }

    #[test]
    fn fail_and_recover_alternate_per_proc() {
        let soc = dimensity9000();
        let evs = plan(&FaultProfile::heavy(), &soc, 123, 30_000.0);
        for p in 0..soc.processors.len() {
            let mut down = false;
            for ev in &evs {
                match ev.kind {
                    EventKind::ProcFail { proc, .. } if proc == p => {
                        assert!(!down, "double fail on proc {p}");
                        down = true;
                    }
                    EventKind::ProcRecover { proc } if proc == p => {
                        assert!(down, "recover without fail on proc {p}");
                        down = false;
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn parse_named_and_custom_profiles() {
        assert_eq!(FaultProfile::parse("off").unwrap(), FaultProfile::off());
        assert_eq!(FaultProfile::parse("light").unwrap(), FaultProfile::light());
        assert_eq!(FaultProfile::parse("heavy").unwrap(), FaultProfile::heavy());
        let p = FaultProfile::parse("crash=0.3,mttr=250").unwrap();
        assert_eq!(p.crash_per_s, 0.3);
        assert_eq!(p.hang_per_s, 0.0);
        assert_eq!(p.mttr_ms, 250.0);
        assert!(!p.is_off());
        assert!(FaultProfile::parse("bogus").is_none());
        assert!(FaultProfile::parse("crash=-1").is_none());
    }
}
