//! Fleet-scale sharded simulation: a population of devices, not one
//! phone.
//!
//! The ROADMAP's north star is a system serving heavy traffic from
//! millions of users, but a single `adms serve` run simulates exactly one
//! device. This layer runs **N independent devices** — each one an
//! evaluation *arm* ([`ArmSpec`]: SoC preset × scheduler × workload or
//! scenario) with a per-device seed derived deterministically from the
//! fleet seed — sharded across worker threads, and merges the per-device
//! results into a [`FleetReport`] without ever shipping raw sample
//! vectors between threads (per-device latency populations collapse into
//! the fixed-size [`Digest`] histograms of `util::stats`).
//!
//! ## Determinism
//!
//! `adms fleet --devices N --seed S` is bit-deterministic across worker
//! counts, by construction:
//!
//! 1. device `d` always runs arm `d % arms` with seed
//!    [`device_seed`]`(S, d)` — independent of which worker executes it;
//! 2. each device simulation is seed-deterministic (the PR-2/PR-3
//!    record-replay and rerun-identity properties);
//! 3. per-device digests land in a slot indexed by device id, and the
//!    final merge folds them **in device-id order on one thread** — so
//!    every floating-point accumulation happens in the same order no
//!    matter how the devices were sharded. Worker threads only decide
//!    *when* a digest is produced, never how it is combined.
//!
//! The plan / window-tuning memo tables (`util::memo`) are mutex-guarded
//! and keyed by graph fingerprint, so shards share one cached
//! partitioning per (model, SoC, ws) instead of recomputing it per
//! device.

pub mod tournament;

pub use tournament::{run_tournament, TournamentReport, TournamentRow, TournamentSpec};

use crate::exec::{RunSpec, SimConfig, SCHEDULER_NAMES};
use crate::sim::SimReport;
use crate::soc::soc_by_name;
use crate::util::json::Json;
use crate::util::rng::splitmix64;
use crate::util::stats::Digest;
use anyhow::{anyhow, bail, Result};

/// One evaluation arm of the fleet: which SoC preset the device is, which
/// scheduling policy it runs, and what workload its user drives — plus an
/// optional per-arm batching override, so batched and unbatched arms can
/// ride one fleet (the config is part of the cloneable [`RunSpec`], so
/// batched arms stay worker-count-deterministic like every other arm).
#[derive(Debug, Clone, PartialEq)]
pub struct ArmSpec {
    /// SoC preset name (`soc::SOC_NAMES`).
    pub soc: String,
    /// Scheduler name (`exec::SCHEDULER_NAMES`).
    pub scheduler: String,
    /// Workload in the `workload::resolve` grammar (named workload or
    /// comma-separated zoo models), or `scenario:<name-or-file>` for a
    /// dynamic scenario (`scenario::resolve`).
    pub workload: String,
    /// Per-arm `batch_max` override (`None` = the fleet-wide config's).
    pub batch_max: Option<usize>,
    /// Per-arm `batch_window_ms` override (`None` = the fleet-wide
    /// config's).
    pub batch_window_ms: Option<f64>,
    /// Per-arm fault-profile override in the `faults::FaultProfile::parse`
    /// grammar (`None` = the fleet-wide config's), so faulted and
    /// fault-free arms can ride one fleet.
    pub fault_profile: Option<String>,
    /// Per-arm adaptive re-partitioning mode (`exec::AdaptivePlan::parse`
    /// grammar; `None` = the fleet-wide config's), so static and adaptive
    /// arms can ride one fleet. Cooldown/threshold knobs ride the shared
    /// fleet config.
    pub adaptive: Option<String>,
}

impl ArmSpec {
    /// An arm with no per-arm batching override.
    pub fn new(soc: &str, scheduler: &str, workload: &str) -> Self {
        ArmSpec {
            soc: soc.into(),
            scheduler: scheduler.into(),
            workload: workload.into(),
            batch_max: None,
            batch_window_ms: None,
            fault_profile: None,
            adaptive: None,
        }
    }

    /// Builder: run this arm batched (`batch_max`, coalescing window).
    pub fn batched(mut self, batch_max: usize, window_ms: f64) -> Self {
        self.batch_max = Some(batch_max.max(1));
        self.batch_window_ms = Some(window_ms.max(0.0));
        self
    }

    /// Builder: run this arm under a fault profile (`"light"`, `"heavy"`,
    /// or a `crash=..,hang=..,transient=..,mttr=..` spec).
    pub fn faulty(mut self, profile: &str) -> Self {
        self.fault_profile = Some(profile.to_string());
        self
    }

    /// Builder: run this arm with runtime granularity switching
    /// (`"reactive"`; `"off"` restores the static default).
    pub fn adaptive(mut self, mode: &str) -> Self {
        self.adaptive = Some(mode.to_string());
        self
    }

    pub fn label(&self) -> String {
        let mut l = format!("{}/{}/{}", self.soc, self.scheduler, self.workload);
        if let Some(b) = self.batch_max {
            if b > 1 {
                l.push_str(&format!(" (batch {b})"));
            }
        }
        if let Some(p) = &self.fault_profile {
            l.push_str(&format!(" (faults {p})"));
        }
        if let Some(a) = &self.adaptive {
            if a != "off" {
                l.push_str(&format!(" (adaptive {a})"));
            }
        }
        l
    }

    /// Resolve the arm to a cloneable [`RunSpec`] (validating every
    /// name), with `cfg` as the shared per-device execution config
    /// (per-arm batching overrides applied on top).
    pub fn to_run_spec(&self, cfg: &SimConfig) -> Result<RunSpec> {
        let soc = soc_by_name(&self.soc)
            .ok_or_else(|| anyhow!("arm '{}': unknown soc '{}'", self.label(), self.soc))?;
        if !SCHEDULER_NAMES.contains(&self.scheduler.as_str()) {
            bail!(
                "arm '{}': unknown scheduler '{}' (expected one of: {})",
                self.label(),
                self.scheduler,
                SCHEDULER_NAMES.join(", ")
            );
        }
        let (apps, events) = if let Some(rest) = self.workload.strip_prefix("scenario:") {
            let sc = crate::scenario::resolve(rest)
                .map_err(|e| anyhow!("arm '{}': {e}", self.label()))?;
            sc.compile().map_err(|e| anyhow!("arm '{}': {e}", self.label()))?
        } else {
            let apps = crate::workload::resolve(&self.workload, &soc).map_err(|e| {
                anyhow!("arm '{}': {e} (or scenario:<name-or-file>)", self.label())
            })?;
            (apps, Vec::new())
        };
        let mut cfg = cfg.clone();
        if let Some(b) = self.batch_max {
            cfg.batch_max = b.max(1);
        }
        if let Some(w) = self.batch_window_ms {
            cfg.batch_window_ms = w.max(0.0);
        }
        if let Some(p) = &self.fault_profile {
            cfg.fault_profile = Some(crate::faults::FaultProfile::parse(p).ok_or_else(|| {
                anyhow!("arm '{}': bad fault profile '{p}'", self.label())
            })?);
        }
        if let Some(a) = &self.adaptive {
            cfg.adaptive_plan = crate::exec::AdaptivePlan::parse(a).ok_or_else(|| {
                anyhow!("arm '{}': bad adaptive mode '{a}' (off | reactive)", self.label())
            })?;
        }
        Ok(RunSpec {
            soc,
            scheduler: self.scheduler.clone(),
            apps,
            events,
            cfg,
            window_size: None,
        })
    }
}

/// A fleet: `devices` simulated devices assigned round-robin over `arms`,
/// all sharing one execution config (horizon, tick, quota) and deriving
/// per-device seeds from `seed`.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub arms: Vec<ArmSpec>,
    pub devices: usize,
    pub seed: u64,
    /// Per-device execution config; `cfg.seed` is overwritten per device.
    pub cfg: SimConfig,
}

/// The seed device `d` simulates under in a fleet seeded `fleet_seed`:
/// a SplitMix64 mix of both, so neighbouring devices get decorrelated
/// streams and the mapping never depends on sharding.
pub fn device_seed(fleet_seed: u64, device: usize) -> u64 {
    splitmix64(splitmix64(fleet_seed) ^ splitmix64(device as u64 ^ 0x9e37_79b9_7f4a_7c15))
}

/// Everything the fleet keeps per device: counters and fixed-size
/// digests, never raw samples — a thousand-device fleet ships a thousand
/// of these across threads, not a thousand latency vectors.
#[derive(Debug, Clone)]
pub struct DeviceDigest {
    pub device: usize,
    pub arm: usize,
    pub seed: u64,
    /// Actual simulated span of this device's run, ms.
    pub sim_ms: f64,
    pub issued: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub latency: Digest,
    pub slo_ok: u64,
    pub slo_n: u64,
    pub energy_j: f64,
    pub throttle_events: u64,
    /// Σ busy fraction over processors (with `procs`, an exact average).
    pub busy_frac_sum: f64,
    pub procs: u64,
    pub events: u64,
    /// Weight-cache counters (all zero on unbudgeted runs — the driver
    /// never constructs a cache, so the report carries defaults).
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_bytes_loaded: u64,
    pub cold_load_ms: f64,
    /// Failure-reason split and fault-layer counters (all zero on
    /// fault-free runs — the driver never constructs the fault layer, so
    /// the report carries defaults).
    pub failed_budget: u64,
    pub failed_exec: u64,
    pub faulted: u64,
    pub retries_exhausted: u64,
    pub retries: u64,
    pub proc_fails: u64,
    pub proc_recovers: u64,
    pub timeouts: u64,
    /// Adaptive re-partitioning counters (all zero when `--adaptive-plan
    /// off` — the driver never constructs the controller, so the report
    /// carries no `replans` block).
    pub replans: u64,
    pub replans_finer: u64,
    pub replans_coarser: u64,
}

impl DeviceDigest {
    pub fn from_report(device: usize, arm: usize, seed: u64, r: &SimReport) -> Self {
        let mut latency = Digest::new();
        for s in &r.sessions {
            latency.merge(&Digest::from_summary(&s.latency));
        }
        DeviceDigest {
            device,
            arm,
            seed,
            sim_ms: r.duration_ms,
            issued: r.total_issued(),
            completed: r.total_completed(),
            failed: r.total_failed(),
            cancelled: r.total_cancelled(),
            latency,
            slo_ok: r.sessions.iter().map(|s| s.slo_ok).sum(),
            slo_n: r.sessions.iter().map(|s| s.slo_n).sum(),
            energy_j: r.energy_j,
            throttle_events: r.procs.iter().map(|p| p.throttle_events).sum(),
            busy_frac_sum: r.procs.iter().map(|p| p.busy_frac).sum(),
            procs: r.procs.len() as u64,
            events: r.events,
            cache_hits: r.cache.hits,
            cache_misses: r.cache.misses,
            cache_evictions: r.cache.evictions,
            cache_bytes_loaded: r.cache.bytes_loaded,
            cold_load_ms: r.cache.cold_load_ms,
            failed_budget: r.sessions.iter().map(|s| s.failed_budget).sum(),
            failed_exec: r.sessions.iter().map(|s| s.failed_exec).sum(),
            faulted: r.sessions.iter().map(|s| s.faulted).sum(),
            retries_exhausted: r.sessions.iter().map(|s| s.retries_exhausted).sum(),
            retries: r.sessions.iter().map(|s| s.retries).sum(),
            proc_fails: r.faults.map(|f| f.proc_fails).unwrap_or(0),
            proc_recovers: r.faults.map(|f| f.proc_recovers).unwrap_or(0),
            timeouts: r.faults.map(|f| f.timeouts).unwrap_or(0),
            replans: r.replans.as_ref().map(|p| p.replans).unwrap_or(0),
            replans_finer: r.replans.as_ref().map(|p| p.finer).unwrap_or(0),
            replans_coarser: r.replans.as_ref().map(|p| p.coarser).unwrap_or(0),
        }
    }
}

/// Aggregate over a set of devices (one arm, or the whole fleet).
/// (`Default` is the empty aggregate: zero devices, empty digest.)
#[derive(Debug, Clone, Default)]
pub struct FleetAgg {
    pub devices: u64,
    pub sim_ms: f64,
    pub issued: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub latency: Digest,
    pub slo_ok: u64,
    pub slo_n: u64,
    pub energy_j: f64,
    pub throttle_events: u64,
    pub busy_frac_sum: f64,
    pub procs: u64,
    pub events: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_bytes_loaded: u64,
    pub cold_load_ms: f64,
    pub failed_budget: u64,
    pub failed_exec: u64,
    pub faulted: u64,
    pub retries_exhausted: u64,
    pub retries: u64,
    pub proc_fails: u64,
    pub proc_recovers: u64,
    pub timeouts: u64,
    pub replans: u64,
    pub replans_finer: u64,
    pub replans_coarser: u64,
}

impl FleetAgg {
    fn absorb(&mut self, d: &DeviceDigest) {
        self.devices += 1;
        self.sim_ms += d.sim_ms;
        self.issued += d.issued;
        self.completed += d.completed;
        self.failed += d.failed;
        self.cancelled += d.cancelled;
        self.latency.merge(&d.latency);
        self.slo_ok += d.slo_ok;
        self.slo_n += d.slo_n;
        self.energy_j += d.energy_j;
        self.throttle_events += d.throttle_events;
        self.busy_frac_sum += d.busy_frac_sum;
        self.procs += d.procs;
        self.events += d.events;
        self.cache_hits += d.cache_hits;
        self.cache_misses += d.cache_misses;
        self.cache_evictions += d.cache_evictions;
        self.cache_bytes_loaded += d.cache_bytes_loaded;
        self.cold_load_ms += d.cold_load_ms;
        self.failed_budget += d.failed_budget;
        self.failed_exec += d.failed_exec;
        self.faulted += d.faulted;
        self.retries_exhausted += d.retries_exhausted;
        self.retries += d.retries;
        self.proc_fails += d.proc_fails;
        self.proc_recovers += d.proc_recovers;
        self.timeouts += d.timeouts;
        self.replans += d.replans;
        self.replans_finer += d.replans_finer;
        self.replans_coarser += d.replans_coarser;
    }

    /// Exact SLO attainment over every SLO-scored request in the set.
    pub fn slo_satisfaction(&self) -> Option<f64> {
        if self.slo_n > 0 {
            Some(self.slo_ok as f64 / self.slo_n as f64)
        } else {
            None
        }
    }

    /// Completed requests per simulated device-second.
    pub fn throughput_rps(&self) -> f64 {
        if self.sim_ms > 0.0 {
            self.completed as f64 / (self.sim_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Mean device power over the set, W.
    pub fn avg_watts(&self) -> f64 {
        if self.sim_ms > 0.0 {
            self.energy_j / (self.sim_ms / 1e3)
        } else {
            0.0
        }
    }

    pub fn avg_busy_frac(&self) -> f64 {
        if self.procs > 0 {
            self.busy_frac_sum / self.procs as f64
        } else {
            0.0
        }
    }

    pub(crate) fn to_json(&self) -> Json {
        let num_or_zero = |x: f64| Json::Num(if x.is_finite() { x } else { 0.0 });
        Json::obj(vec![
            ("devices", Json::Num(self.devices as f64)),
            ("sim_ms", Json::Num(self.sim_ms)),
            ("issued", Json::Num(self.issued as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("p50_ms", num_or_zero(self.latency.p50())),
            ("p95_ms", num_or_zero(self.latency.p95())),
            ("p99_ms", num_or_zero(self.latency.p99())),
            ("mean_ms", num_or_zero(self.latency.mean())),
            ("max_ms", num_or_zero(self.latency.max())),
            // True when any folded-in session had engaged its reservoir:
            // the percentiles above are then estimates weighted by
            // reservoir (not true) populations — same disclosure as the
            // '~' marker in serve output.
            ("latency_subsampled", Json::Bool(self.latency.is_subsampled())),
            ("slo_ok", Json::Num(self.slo_ok as f64)),
            ("slo_n", Json::Num(self.slo_n as f64)),
            ("energy_j", Json::Num(self.energy_j)),
            ("avg_watts", Json::Num(self.avg_watts())),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("throttle_events", Json::Num(self.throttle_events as f64)),
            ("avg_busy_frac", Json::Num(self.avg_busy_frac())),
            ("events", Json::Num(self.events as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_misses", Json::Num(self.cache_misses as f64)),
            ("cache_evictions", Json::Num(self.cache_evictions as f64)),
            ("cache_bytes_loaded", Json::Num(self.cache_bytes_loaded as f64)),
            ("cold_load_ms", Json::Num(self.cold_load_ms)),
            ("failed_budget", Json::Num(self.failed_budget as f64)),
            ("failed_exec", Json::Num(self.failed_exec as f64)),
            ("faulted", Json::Num(self.faulted as f64)),
            ("retries_exhausted", Json::Num(self.retries_exhausted as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("proc_fails", Json::Num(self.proc_fails as f64)),
            ("proc_recovers", Json::Num(self.proc_recovers as f64)),
            ("timeouts", Json::Num(self.timeouts as f64)),
            ("replans", Json::Num(self.replans as f64)),
            ("replans_finer", Json::Num(self.replans_finer as f64)),
            ("replans_coarser", Json::Num(self.replans_coarser as f64)),
        ])
    }
}

/// One arm's aggregate inside a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct ArmReport {
    pub spec: ArmSpec,
    pub agg: FleetAgg,
}

/// The merged result of a fleet run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub devices: usize,
    pub seed: u64,
    pub arms: Vec<ArmReport>,
    /// Fleet-wide aggregate — folded over raw device digests in
    /// device-id order (NOT over per-arm aggregates): that fold order is
    /// what the bit-determinism guarantee and `tests/fleet_rt.rs`'s
    /// byte-equality assertions pin down, so don't "simplify" it to an
    /// arm-order fold (it would reorder the f64 accumulations).
    pub total: FleetAgg,
}

impl FleetReport {
    fn merge(spec: &FleetSpec, digests: Vec<DeviceDigest>) -> Self {
        let mut arms: Vec<ArmReport> = spec
            .arms
            .iter()
            .map(|a| ArmReport { spec: a.clone(), agg: FleetAgg::default() })
            .collect();
        let mut total = FleetAgg::default();
        // Device-id order: `digests` is indexed by device id, so both the
        // per-arm and the fleet-wide folds see every device in the same
        // order regardless of worker count.
        for d in &digests {
            arms[d.arm].agg.absorb(d);
            total.absorb(d);
        }
        FleetReport { devices: spec.devices, seed: spec.seed, arms, total }
    }

    pub fn to_json(&self) -> Json {
        let arms = self
            .arms
            .iter()
            .map(|a| {
                let mut obj = match a.agg.to_json() {
                    Json::Obj(o) => o,
                    _ => unreachable!("agg serializes as an object"),
                };
                obj.insert("arm".into(), Json::Str(a.spec.label()));
                Json::Obj(obj)
            })
            .collect();
        Json::obj(vec![
            ("devices", Json::Num(self.devices as f64)),
            // A string, not a number: the report is a reproducibility
            // record, and u64 seeds above 2^53 would round through f64.
            ("seed", Json::Str(self.seed.to_string())),
            ("arms", Json::Arr(arms)),
            ("total", self.total.to_json()),
        ])
    }

    /// Render the per-arm table plus fleet totals for the CLI.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:36} {:>4} {:>9} {:>7} {:>8} {:>8} {:>7} {:>9} {:>8} {:>6}",
            "arm", "dev", "completed", "failed", "p50 ms", "p95 ms", "SLO %", "req/s", "avg W",
            "thrtl"
        );
        let mut any_subsampled = false;
        let mut row = |label: &str, a: &FleetAgg| {
            // '~' marks reservoir-estimated percentiles, as in serve
            // output (sessions past the Summary cap fold in subsampled).
            let approx = if a.latency.is_subsampled() { "~" } else { "" };
            any_subsampled |= a.latency.is_subsampled();
            let _ = writeln!(
                out,
                "{:36} {:>4} {:>9} {:>7} {:>8} {:>8} {:>7} {:>9.2} {:>8.2} {:>6}",
                label,
                a.devices,
                a.completed,
                a.failed,
                format!(
                    "{approx}{:.2}",
                    if a.latency.is_empty() { 0.0 } else { a.latency.p50() }
                ),
                format!(
                    "{approx}{:.2}",
                    if a.latency.is_empty() { 0.0 } else { a.latency.p95() }
                ),
                a.slo_satisfaction()
                    .map(|v| format!("{:.1}", v * 100.0))
                    .unwrap_or_else(|| "-".into()),
                a.throughput_rps(),
                a.avg_watts(),
                a.throttle_events,
            );
        };
        for a in &self.arms {
            row(&a.spec.label(), &a.agg);
        }
        row("fleet total", &self.total);
        if self.total.cache_hits + self.total.cache_misses > 0 {
            let _ = writeln!(
                out,
                "weights: {} hits / {} misses / {} evictions, {:.1} MiB \
                 cold-loaded ({:.0} ms stall)",
                self.total.cache_hits,
                self.total.cache_misses,
                self.total.cache_evictions,
                self.total.cache_bytes_loaded as f64 / (1u64 << 20) as f64,
                self.total.cold_load_ms,
            );
        }
        let t = &self.total;
        if t.proc_fails + t.faulted + t.retries + t.retries_exhausted + t.timeouts > 0 {
            let _ = writeln!(
                out,
                "faults: {} proc fails / {} recovers / {} timeouts; {} retries, \
                 {} faulted, {} retries exhausted",
                t.proc_fails, t.proc_recovers, t.timeouts, t.retries, t.faulted,
                t.retries_exhausted,
            );
        }
        if t.replans > 0 {
            let _ = writeln!(
                out,
                "replans: {} granularity switch(es) ({} finer, {} coarser)",
                t.replans, t.replans_finer, t.replans_coarser,
            );
        }
        if any_subsampled {
            let _ = writeln!(
                out,
                "note: '~' percentiles are reservoir estimates (a session exceeded the \
                 per-device sample cap)"
            );
        }
        out
    }
}

/// What one worker shard returns: (device id, digest) pairs, or the
/// first device error it hit.
type ShardResult = Result<Vec<(usize, DeviceDigest)>>;

/// Run the fleet, sharded over `workers` threads. Device `d` runs arm
/// `d % arms` under seed [`device_seed`]`(spec.seed, d)`; results merge
/// in device-id order (see the module docs for the determinism argument).
pub fn run_fleet(spec: &FleetSpec, workers: usize) -> Result<FleetReport> {
    if spec.arms.is_empty() {
        bail!("fleet has no arms: give at least one (soc, scheduler, workload) triple");
    }
    if spec.devices == 0 {
        bail!("fleet has no devices (--devices must be ≥ 1)");
    }
    // Resolve and validate every arm up front, on one thread, and warm
    // the plan/tuning memo tables (`RunSpec::warm_caches` really builds
    // the plans) so the shards start from shared cached partitionings
    // instead of racing to compute them N ways on a cold process.
    let run_specs: Vec<RunSpec> =
        spec.arms.iter().map(|a| a.to_run_spec(&spec.cfg)).collect::<Result<_>>()?;
    for (rs, arm) in run_specs.iter().zip(&spec.arms) {
        rs.warm_caches().map_err(|e| anyhow!("arm '{}': {e}", arm.label()))?;
    }
    let workers = workers.clamp(1, spec.devices);

    let results: Vec<ShardResult> = std::thread::scope(|scope| {
        let run_specs = &run_specs;
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut d = w;
                    while d < spec.devices {
                        let arm = d % run_specs.len();
                        let mut rs = run_specs[arm].clone();
                        rs.cfg.seed = device_seed(spec.seed, d);
                        let report = rs.run_sim().map_err(|e| {
                            anyhow!("device {d} (arm '{}'): {e}", spec.arms[arm].label())
                        })?;
                        out.push((d, DeviceDigest::from_report(d, arm, rs.cfg.seed, &report)));
                        d += workers;
                    }
                    Ok(out)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("fleet worker panicked")).collect()
    });

    let mut digests: Vec<Option<DeviceDigest>> = vec![None; spec.devices];
    for r in results {
        for (d, dig) in r? {
            digests[d] = Some(dig);
        }
    }
    let digests: Vec<DeviceDigest> =
        digests.into_iter().map(|d| d.expect("every device simulated")).collect();
    Ok(FleetReport::merge(spec, digests))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_seeds_are_distinct_and_stable() {
        let seen: std::collections::HashSet<u64> =
            (0..256).map(|d| device_seed(42, d)).collect();
        assert_eq!(seen.len(), 256, "device seeds collided");
        // Stable across calls (a pure function of (fleet seed, device)).
        assert_eq!(device_seed(42, 7), device_seed(42, 7));
        assert_ne!(device_seed(42, 7), device_seed(43, 7));
    }

    #[test]
    fn arm_validation_rejects_unknown_names() {
        let cfg = SimConfig::default();
        let bad_soc = ArmSpec::new("nope", "adms", "frs");
        assert!(bad_soc.to_run_spec(&cfg).is_err());
        let bad_sched = ArmSpec::new("dimensity9000", "nope", "frs");
        assert!(bad_sched.to_run_spec(&cfg).is_err());
        let bad_wl = ArmSpec::new("dimensity9000", "adms", "not_a_workload");
        assert!(bad_wl.to_run_spec(&cfg).is_err());
        let ok = ArmSpec::new("dimensity9000", "band", "mobilenet_v1,east");
        let rs = ok.to_run_spec(&cfg).unwrap();
        assert_eq!(rs.apps.len(), 2);
        let sc = ArmSpec::new("dimensity9000", "adms", "scenario:churn_mix");
        let rs = sc.to_run_spec(&cfg).unwrap();
        assert!(!rs.events.is_empty(), "scenario arm lost its lifecycle events");
        // Per-arm batching overrides land in the run spec's config.
        let batched = ArmSpec::new("dimensity9000", "adms", "frs").batched(4, 5.0);
        let rs = batched.to_run_spec(&cfg).unwrap();
        assert_eq!(rs.cfg.batch_max, 4);
        assert_eq!(rs.cfg.batch_window_ms, 5.0);
        assert!(batched.label().contains("batch 4"));
        // Per-arm fault profiles parse into the run spec's config.
        let faulty = ArmSpec::new("dimensity9000", "adms", "frs").faulty("light");
        let rs = faulty.to_run_spec(&cfg).unwrap();
        assert_eq!(rs.cfg.fault_profile.as_ref().unwrap().name, "light");
        assert!(faulty.label().contains("faults light"));
        let bad_profile = ArmSpec::new("dimensity9000", "adms", "frs").faulty("wat");
        assert!(bad_profile.to_run_spec(&cfg).is_err());
        // Per-arm adaptive modes parse into the run spec's config.
        let adaptive = ArmSpec::new("dimensity9000", "adms", "frs").adaptive("reactive");
        let rs = adaptive.to_run_spec(&cfg).unwrap();
        assert!(rs.cfg.adaptive_configured());
        assert!(adaptive.label().contains("adaptive reactive"));
        let bad_mode = ArmSpec::new("dimensity9000", "adms", "frs").adaptive("wat");
        assert!(bad_mode.to_run_spec(&cfg).is_err());
    }
}
