//! Fleet-scale sharded simulation: a population of devices, not one
//! phone.
//!
//! The ROADMAP's north star is a system serving heavy traffic from
//! millions of users, but a single `adms serve` run simulates exactly one
//! device. This layer runs **N independent devices** — each one an
//! evaluation *arm* ([`ArmSpec`]: SoC preset × scheduler × workload or
//! scenario) with a per-device seed derived deterministically from the
//! fleet seed — sharded across worker threads, and **streams** each
//! per-device result into a per-arm [`FleetAgg`] the moment the device
//! completes. Nothing per-device is ever materialized or shipped between
//! threads: memory stays O(arms × workers) whether the fleet is six
//! devices or six hundred thousand (the per-device latency population
//! collapses into the fixed-size [`Digest`] histograms of `util::stats`,
//! and the digest live-gauge test in `tests/fleet_rt.rs` pins the bound).
//!
//! ## Determinism
//!
//! `adms fleet --devices N --seed S` is bit-deterministic across worker
//! counts *and* across sharding orders, by construction:
//!
//! 1. device `d` always runs arm `d % arms` with seed
//!    [`device_seed`]`(S, d)` — independent of which worker executes it.
//!    Workers claim *chunks* of device ids from a shared atomic cursor
//!    (dynamic load balancing for uneven arms), but the claim order only
//!    decides *who* runs a device, never *what* it runs;
//! 2. each device simulation is seed-deterministic (the PR-2/PR-3
//!    record-replay and rerun-identity properties), and population
//!    sampling ([`PopulationSpec`]) draws from salted streams off the
//!    device's own seed — a pure function of `(S, d)`;
//! 3. the fold is **order-independent, not order-pinned**: every counter
//!    is an exact u64/min/max fold, and every floating-point accumulator
//!    ([`FleetAgg`]'s sums and the [`Digest`] mean) is a
//!    [`util::stats::ExactSum`](crate::util::stats::ExactSum), whose
//!    reported f64 is the correctly-rounded value of the *mathematical*
//!    sum of its inputs. Racing workers may therefore absorb devices in
//!    any interleaving and merge partials in any grouping — the bytes of
//!    [`FleetReport::to_json`] cannot tell the difference. The
//!    `#[doc(hidden)]` [`run_fleet_materialized`] referee (the old
//!    collect-then-fold-in-device-order path) exists so the test suite
//!    can prove that claim rather than assume it.
//!
//! The plan / window-tuning memo tables (`util::memo`) are mutex-guarded
//! and keyed by graph fingerprint, so shards share one cached
//! partitioning per (model, SoC, ws) instead of recomputing it per
//! device.

pub mod population;
pub mod tournament;

pub use population::PopulationSpec;
pub use tournament::{run_tournament, TournamentReport, TournamentRow, TournamentSpec};

use crate::exec::{RunSpec, SimConfig, SCHEDULER_NAMES};
use crate::scenario::FleetEnvelope;
use crate::sim::SimReport;
use crate::soc::soc_by_name;
use crate::util::json::Json;
use crate::util::rng::splitmix64;
use crate::util::stats::{Digest, ExactSum};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering::Relaxed};

/// One evaluation arm of the fleet: which SoC preset the device is, which
/// scheduling policy it runs, and what workload its user drives — plus an
/// optional per-arm batching override, so batched and unbatched arms can
/// ride one fleet (the config is part of the cloneable [`RunSpec`], so
/// batched arms stay worker-count-deterministic like every other arm).
#[derive(Debug, Clone, PartialEq)]
pub struct ArmSpec {
    /// SoC preset name (`soc::SOC_NAMES`).
    pub soc: String,
    /// Scheduler name (`exec::SCHEDULER_NAMES`).
    pub scheduler: String,
    /// Workload in the `workload::resolve` grammar (named workload or
    /// comma-separated zoo models), or `scenario:<name-or-file>` for a
    /// dynamic scenario (`scenario::resolve`).
    pub workload: String,
    /// Per-arm `batch_max` override (`None` = the fleet-wide config's).
    pub batch_max: Option<usize>,
    /// Per-arm `batch_window_ms` override (`None` = the fleet-wide
    /// config's).
    pub batch_window_ms: Option<f64>,
    /// Per-arm fault-profile override in the `faults::FaultProfile::parse`
    /// grammar (`None` = the fleet-wide config's), so faulted and
    /// fault-free arms can ride one fleet.
    pub fault_profile: Option<String>,
    /// Per-arm adaptive re-partitioning mode (`exec::AdaptivePlan::parse`
    /// grammar; `None` = the fleet-wide config's), so static and adaptive
    /// arms can ride one fleet. Cooldown/threshold knobs ride the shared
    /// fleet config.
    pub adaptive: Option<String>,
}

impl ArmSpec {
    /// An arm with no per-arm batching override.
    pub fn new(soc: &str, scheduler: &str, workload: &str) -> Self {
        ArmSpec {
            soc: soc.into(),
            scheduler: scheduler.into(),
            workload: workload.into(),
            batch_max: None,
            batch_window_ms: None,
            fault_profile: None,
            adaptive: None,
        }
    }

    /// Builder: run this arm batched (`batch_max`, coalescing window).
    pub fn batched(mut self, batch_max: usize, window_ms: f64) -> Self {
        self.batch_max = Some(batch_max.max(1));
        self.batch_window_ms = Some(window_ms.max(0.0));
        self
    }

    /// Builder: run this arm under a fault profile (`"light"`, `"heavy"`,
    /// or a `crash=..,hang=..,transient=..,mttr=..` spec).
    pub fn faulty(mut self, profile: &str) -> Self {
        self.fault_profile = Some(profile.to_string());
        self
    }

    /// Builder: run this arm with runtime granularity switching
    /// (`"reactive"`; `"off"` restores the static default).
    pub fn adaptive(mut self, mode: &str) -> Self {
        self.adaptive = Some(mode.to_string());
        self
    }

    pub fn label(&self) -> String {
        let mut l = format!("{}/{}/{}", self.soc, self.scheduler, self.workload);
        if let Some(b) = self.batch_max {
            if b > 1 {
                l.push_str(&format!(" (batch {b})"));
            }
        }
        if let Some(p) = &self.fault_profile {
            l.push_str(&format!(" (faults {p})"));
        }
        if let Some(a) = &self.adaptive {
            if a != "off" {
                l.push_str(&format!(" (adaptive {a})"));
            }
        }
        l
    }

    /// Resolve the arm to a cloneable [`RunSpec`] (validating every
    /// name), with `cfg` as the shared per-device execution config
    /// (per-arm batching overrides applied on top).
    pub fn to_run_spec(&self, cfg: &SimConfig) -> Result<RunSpec> {
        let soc = soc_by_name(&self.soc)
            .ok_or_else(|| anyhow!("arm '{}': unknown soc '{}'", self.label(), self.soc))?;
        if !SCHEDULER_NAMES.contains(&self.scheduler.as_str()) {
            bail!(
                "arm '{}': unknown scheduler '{}' (expected one of: {})",
                self.label(),
                self.scheduler,
                SCHEDULER_NAMES.join(", ")
            );
        }
        let (apps, events) = if let Some(rest) = self.workload.strip_prefix("scenario:") {
            let sc = crate::scenario::resolve(rest)
                .map_err(|e| anyhow!("arm '{}': {e}", self.label()))?;
            sc.compile().map_err(|e| anyhow!("arm '{}': {e}", self.label()))?
        } else {
            let apps = crate::workload::resolve(&self.workload, &soc).map_err(|e| {
                anyhow!("arm '{}': {e} (or scenario:<name-or-file>)", self.label())
            })?;
            (apps, Vec::new())
        };
        let mut cfg = cfg.clone();
        if let Some(b) = self.batch_max {
            cfg.batch_max = b.max(1);
        }
        if let Some(w) = self.batch_window_ms {
            cfg.batch_window_ms = w.max(0.0);
        }
        if let Some(p) = &self.fault_profile {
            cfg.fault_profile = Some(crate::faults::FaultProfile::parse(p).ok_or_else(|| {
                anyhow!("arm '{}': bad fault profile '{p}'", self.label())
            })?);
        }
        if let Some(a) = &self.adaptive {
            cfg.adaptive_plan = crate::exec::AdaptivePlan::parse(a).ok_or_else(|| {
                anyhow!("arm '{}': bad adaptive mode '{a}' (off | reactive)", self.label())
            })?;
        }
        Ok(RunSpec {
            soc,
            scheduler: self.scheduler.clone(),
            apps,
            events,
            cfg,
            window_size: None,
        })
    }
}

/// A fleet: `devices` simulated devices assigned round-robin over `arms`,
/// all sharing one execution config (horizon, tick, quota) and deriving
/// per-device seeds from `seed`.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub arms: Vec<ArmSpec>,
    pub devices: usize,
    pub seed: u64,
    /// Per-device execution config; `cfg.seed` is overwritten per device.
    pub cfg: SimConfig,
    /// Device-population heterogeneity: per-device SoC mix and
    /// ambient/background-load jitter, sampled from each device's seed
    /// stream. `None` = every device is exactly its arm's nominal spec.
    pub population: Option<PopulationSpec>,
    /// Fleet-wide arrival-rate envelope (diurnal cycle / flash crowd)
    /// modulating every device's open-loop sessions on a shared
    /// wall-clock schedule. `None` = arrivals as compiled.
    pub envelope: Option<FleetEnvelope>,
}

/// Execution knobs for [`run_fleet_opts`] that change *how fast* the
/// fleet runs, never *what* it computes.
#[derive(Debug, Clone, Default)]
pub struct FleetOptions {
    /// Emit a stderr heartbeat (devices done / total, devices per
    /// second) roughly once a second while the fleet runs.
    pub progress: bool,
    /// Devices claimed per cursor grab (`0` = auto:
    /// `devices / (workers × 16)` clamped to `[1, 512]` — small enough
    /// that uneven arms load-balance at 100k devices, large enough that
    /// the cursor is not contended).
    pub chunk: usize,
}

/// The seed device `d` simulates under in a fleet seeded `fleet_seed`:
/// a SplitMix64 mix of both, so neighbouring devices get decorrelated
/// streams and the mapping never depends on sharding.
pub fn device_seed(fleet_seed: u64, device: usize) -> u64 {
    splitmix64(splitmix64(fleet_seed) ^ splitmix64(device as u64 ^ 0x9e37_79b9_7f4a_7c15))
}

/// Everything the fleet extracts from one device's run: counters and a
/// fixed-size latency digest, never raw samples. A digest is *transient*
/// — built when the device's simulation returns, absorbed into the
/// worker's per-arm [`FleetAgg`], and dropped — so live instances stay
/// O(arms × workers) no matter the fleet size.
#[derive(Debug, Clone)]
pub struct DeviceDigest {
    pub device: usize,
    pub arm: usize,
    pub seed: u64,
    /// Actual simulated span of this device's run, ms.
    pub sim_ms: f64,
    pub issued: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub latency: Digest,
    pub slo_ok: u64,
    pub slo_n: u64,
    pub energy_j: f64,
    pub throttle_events: u64,
    /// Σ busy fraction over processors (with `procs`, an exact average).
    pub busy_frac_sum: f64,
    pub procs: u64,
    pub events: u64,
    /// Weight-cache counters (all zero on unbudgeted runs — the driver
    /// never constructs a cache, so the report carries defaults).
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_bytes_loaded: u64,
    pub cold_load_ms: f64,
    /// Failure-reason split and fault-layer counters (all zero on
    /// fault-free runs — the driver never constructs the fault layer, so
    /// the report carries defaults).
    pub failed_budget: u64,
    pub failed_exec: u64,
    pub faulted: u64,
    pub retries_exhausted: u64,
    pub retries: u64,
    pub proc_fails: u64,
    pub proc_recovers: u64,
    pub timeouts: u64,
    /// Adaptive re-partitioning counters (all zero when `--adaptive-plan
    /// off` — the driver never constructs the controller, so the report
    /// carries no `replans` block).
    pub replans: u64,
    pub replans_finer: u64,
    pub replans_coarser: u64,
}

impl DeviceDigest {
    pub fn from_report(device: usize, arm: usize, seed: u64, r: &SimReport) -> Self {
        let mut latency = Digest::new();
        for s in &r.sessions {
            latency.merge(&Digest::from_summary(&s.latency));
        }
        DeviceDigest {
            device,
            arm,
            seed,
            sim_ms: r.duration_ms,
            issued: r.total_issued(),
            completed: r.total_completed(),
            failed: r.total_failed(),
            cancelled: r.total_cancelled(),
            latency,
            slo_ok: r.sessions.iter().map(|s| s.slo_ok).sum(),
            slo_n: r.sessions.iter().map(|s| s.slo_n).sum(),
            energy_j: r.energy_j,
            throttle_events: r.procs.iter().map(|p| p.throttle_events).sum(),
            busy_frac_sum: r.procs.iter().map(|p| p.busy_frac).sum(),
            procs: r.procs.len() as u64,
            events: r.events,
            cache_hits: r.cache.hits,
            cache_misses: r.cache.misses,
            cache_evictions: r.cache.evictions,
            cache_bytes_loaded: r.cache.bytes_loaded,
            cold_load_ms: r.cache.cold_load_ms,
            failed_budget: r.sessions.iter().map(|s| s.failed_budget).sum(),
            failed_exec: r.sessions.iter().map(|s| s.failed_exec).sum(),
            faulted: r.sessions.iter().map(|s| s.faulted).sum(),
            retries_exhausted: r.sessions.iter().map(|s| s.retries_exhausted).sum(),
            retries: r.sessions.iter().map(|s| s.retries).sum(),
            proc_fails: r.faults.map(|f| f.proc_fails).unwrap_or(0),
            proc_recovers: r.faults.map(|f| f.proc_recovers).unwrap_or(0),
            timeouts: r.faults.map(|f| f.timeouts).unwrap_or(0),
            replans: r.replans.as_ref().map(|p| p.replans).unwrap_or(0),
            replans_finer: r.replans.as_ref().map(|p| p.finer).unwrap_or(0),
            replans_coarser: r.replans.as_ref().map(|p| p.coarser).unwrap_or(0),
        }
    }
}

/// Aggregate over a set of devices (one arm, or the whole fleet).
/// (`Default` is the empty aggregate: zero devices, empty digest.)
///
/// The floating-point fields are [`ExactSum`] accumulators, so both
/// [`absorb`](FleetAgg::absorb)ing devices and [`merge`](FleetAgg::merge)ing
/// worker partials are order-independent down to the bit — the exactness
/// the fleet's dynamic sharding leans on (module docs, point 3). Read
/// them through the same-named accessor methods ([`sim_ms`](FleetAgg::sim_ms)
/// etc.), which round the exact sum to f64 once.
#[derive(Debug, Clone, Default)]
pub struct FleetAgg {
    pub devices: u64,
    pub sim_ms: ExactSum,
    pub issued: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub latency: Digest,
    pub slo_ok: u64,
    pub slo_n: u64,
    pub energy_j: ExactSum,
    pub throttle_events: u64,
    pub busy_frac_sum: ExactSum,
    pub procs: u64,
    pub events: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_bytes_loaded: u64,
    pub cold_load_ms: ExactSum,
    pub failed_budget: u64,
    pub failed_exec: u64,
    pub faulted: u64,
    pub retries_exhausted: u64,
    pub retries: u64,
    pub proc_fails: u64,
    pub proc_recovers: u64,
    pub timeouts: u64,
    pub replans: u64,
    pub replans_finer: u64,
    pub replans_coarser: u64,
}

impl FleetAgg {
    /// Fold one device in (streaming path — the digest is dropped by the
    /// caller right after).
    pub fn absorb(&mut self, d: &DeviceDigest) {
        self.devices += 1;
        self.sim_ms.add(d.sim_ms);
        self.issued += d.issued;
        self.completed += d.completed;
        self.failed += d.failed;
        self.cancelled += d.cancelled;
        self.latency.merge(&d.latency);
        self.slo_ok += d.slo_ok;
        self.slo_n += d.slo_n;
        self.energy_j.add(d.energy_j);
        self.throttle_events += d.throttle_events;
        self.busy_frac_sum.add(d.busy_frac_sum);
        self.procs += d.procs;
        self.events += d.events;
        self.cache_hits += d.cache_hits;
        self.cache_misses += d.cache_misses;
        self.cache_evictions += d.cache_evictions;
        self.cache_bytes_loaded += d.cache_bytes_loaded;
        self.cold_load_ms.add(d.cold_load_ms);
        self.failed_budget += d.failed_budget;
        self.failed_exec += d.failed_exec;
        self.faulted += d.faulted;
        self.retries_exhausted += d.retries_exhausted;
        self.retries += d.retries;
        self.proc_fails += d.proc_fails;
        self.proc_recovers += d.proc_recovers;
        self.timeouts += d.timeouts;
        self.replans += d.replans;
        self.replans_finer += d.replans_finer;
        self.replans_coarser += d.replans_coarser;
    }

    /// Fold another aggregate in (worker-partial merge). Exact in every
    /// field, so `a.merge(b)` and `b.merge(a)` report identical values.
    pub fn merge(&mut self, o: &FleetAgg) {
        self.devices += o.devices;
        self.sim_ms.merge(&o.sim_ms);
        self.issued += o.issued;
        self.completed += o.completed;
        self.failed += o.failed;
        self.cancelled += o.cancelled;
        self.latency.merge(&o.latency);
        self.slo_ok += o.slo_ok;
        self.slo_n += o.slo_n;
        self.energy_j.merge(&o.energy_j);
        self.throttle_events += o.throttle_events;
        self.busy_frac_sum.merge(&o.busy_frac_sum);
        self.procs += o.procs;
        self.events += o.events;
        self.cache_hits += o.cache_hits;
        self.cache_misses += o.cache_misses;
        self.cache_evictions += o.cache_evictions;
        self.cache_bytes_loaded += o.cache_bytes_loaded;
        self.cold_load_ms.merge(&o.cold_load_ms);
        self.failed_budget += o.failed_budget;
        self.failed_exec += o.failed_exec;
        self.faulted += o.faulted;
        self.retries_exhausted += o.retries_exhausted;
        self.retries += o.retries;
        self.proc_fails += o.proc_fails;
        self.proc_recovers += o.proc_recovers;
        self.timeouts += o.timeouts;
        self.replans += o.replans;
        self.replans_finer += o.replans_finer;
        self.replans_coarser += o.replans_coarser;
    }

    /// Total simulated span across the set's devices, ms.
    pub fn sim_ms(&self) -> f64 {
        self.sim_ms.value()
    }

    /// Total energy across the set's devices, J.
    pub fn energy_j(&self) -> f64 {
        self.energy_j.value()
    }

    /// Σ busy fraction over every (device × processor) in the set.
    pub fn busy_frac_sum(&self) -> f64 {
        self.busy_frac_sum.value()
    }

    /// Total cold-load stall across the set's devices, ms.
    pub fn cold_load_ms(&self) -> f64 {
        self.cold_load_ms.value()
    }

    /// Exact SLO attainment over every SLO-scored request in the set.
    pub fn slo_satisfaction(&self) -> Option<f64> {
        if self.slo_n > 0 {
            Some(self.slo_ok as f64 / self.slo_n as f64)
        } else {
            None
        }
    }

    /// Completed requests per simulated device-second.
    pub fn throughput_rps(&self) -> f64 {
        let sim_ms = self.sim_ms();
        if sim_ms > 0.0 {
            self.completed as f64 / (sim_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Mean device power over the set, W.
    pub fn avg_watts(&self) -> f64 {
        let sim_ms = self.sim_ms();
        if sim_ms > 0.0 {
            self.energy_j() / (sim_ms / 1e3)
        } else {
            0.0
        }
    }

    pub fn avg_busy_frac(&self) -> f64 {
        if self.procs > 0 {
            self.busy_frac_sum() / self.procs as f64
        } else {
            0.0
        }
    }

    pub(crate) fn to_json(&self) -> Json {
        let num_or_zero = |x: f64| Json::Num(if x.is_finite() { x } else { 0.0 });
        Json::obj(vec![
            ("devices", Json::Num(self.devices as f64)),
            ("sim_ms", Json::Num(self.sim_ms())),
            ("issued", Json::Num(self.issued as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("p50_ms", num_or_zero(self.latency.p50())),
            ("p95_ms", num_or_zero(self.latency.p95())),
            ("p99_ms", num_or_zero(self.latency.p99())),
            ("mean_ms", num_or_zero(self.latency.mean())),
            ("max_ms", num_or_zero(self.latency.max())),
            // True when any folded-in session had engaged its reservoir:
            // the percentiles above are then estimates weighted by
            // reservoir (not true) populations — same disclosure as the
            // '~' marker in serve output.
            ("latency_subsampled", Json::Bool(self.latency.is_subsampled())),
            ("slo_ok", Json::Num(self.slo_ok as f64)),
            ("slo_n", Json::Num(self.slo_n as f64)),
            ("energy_j", Json::Num(self.energy_j())),
            ("avg_watts", Json::Num(self.avg_watts())),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("throttle_events", Json::Num(self.throttle_events as f64)),
            ("avg_busy_frac", Json::Num(self.avg_busy_frac())),
            ("events", Json::Num(self.events as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_misses", Json::Num(self.cache_misses as f64)),
            ("cache_evictions", Json::Num(self.cache_evictions as f64)),
            ("cache_bytes_loaded", Json::Num(self.cache_bytes_loaded as f64)),
            ("cold_load_ms", Json::Num(self.cold_load_ms())),
            ("failed_budget", Json::Num(self.failed_budget as f64)),
            ("failed_exec", Json::Num(self.failed_exec as f64)),
            ("faulted", Json::Num(self.faulted as f64)),
            ("retries_exhausted", Json::Num(self.retries_exhausted as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("proc_fails", Json::Num(self.proc_fails as f64)),
            ("proc_recovers", Json::Num(self.proc_recovers as f64)),
            ("timeouts", Json::Num(self.timeouts as f64)),
            ("replans", Json::Num(self.replans as f64)),
            ("replans_finer", Json::Num(self.replans_finer as f64)),
            ("replans_coarser", Json::Num(self.replans_coarser as f64)),
        ])
    }
}

/// One arm's aggregate inside a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct ArmReport {
    pub spec: ArmSpec,
    pub agg: FleetAgg,
}

/// The merged result of a fleet run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub devices: usize,
    pub seed: u64,
    pub arms: Vec<ArmReport>,
    /// Fleet-wide aggregate. Folded from the per-arm aggregates in arm
    /// order — safe *because* every [`FleetAgg`] accumulator is exact
    /// (the old device-id-order fold produces identical bytes; the
    /// streaming-vs-materialized referee test holds both paths to that).
    pub total: FleetAgg,
    /// The population the devices were drawn from, when heterogeneous.
    pub population: Option<PopulationSpec>,
    /// Label of the applied fleet-wide arrival envelope, if any.
    pub envelope: Option<String>,
}

impl FleetReport {
    /// The old materialized fold, kept verbatim as the streaming path's
    /// referee: absorb raw device digests in device-id order.
    fn merge_materialized(spec: &FleetSpec, digests: Vec<DeviceDigest>) -> Self {
        let mut arms: Vec<ArmReport> = spec
            .arms
            .iter()
            .map(|a| ArmReport { spec: a.clone(), agg: FleetAgg::default() })
            .collect();
        let mut total = FleetAgg::default();
        for d in &digests {
            arms[d.arm].agg.absorb(d);
            total.absorb(d);
        }
        FleetReport {
            devices: spec.devices,
            seed: spec.seed,
            arms,
            total,
            population: spec.population.clone(),
            envelope: spec.envelope.as_ref().map(|e| e.label()),
        }
    }

    /// Assemble the report from per-arm aggregates (streaming path): the
    /// fleet total folds the arms in arm order.
    fn from_arm_aggs(spec: &FleetSpec, aggs: Vec<FleetAgg>) -> Self {
        let mut total = FleetAgg::default();
        for a in &aggs {
            total.merge(a);
        }
        let arms = spec
            .arms
            .iter()
            .zip(aggs)
            .map(|(s, agg)| ArmReport { spec: s.clone(), agg })
            .collect();
        FleetReport {
            devices: spec.devices,
            seed: spec.seed,
            arms,
            total,
            population: spec.population.clone(),
            envelope: spec.envelope.as_ref().map(|e| e.label()),
        }
    }

    pub fn to_json(&self) -> Json {
        let arms = self
            .arms
            .iter()
            .map(|a| {
                let mut obj = match a.agg.to_json() {
                    Json::Obj(o) => o,
                    _ => unreachable!("agg serializes as an object"),
                };
                obj.insert("arm".into(), Json::Str(a.spec.label()));
                Json::Obj(obj)
            })
            .collect();
        let mut obj = match Json::obj(vec![
            ("devices", Json::Num(self.devices as f64)),
            // A string, not a number: the report is a reproducibility
            // record, and u64 seeds above 2^53 would round through f64.
            ("seed", Json::Str(self.seed.to_string())),
            ("arms", Json::Arr(arms)),
            ("total", self.total.to_json()),
        ]) {
            Json::Obj(o) => o,
            _ => unreachable!("report serializes as an object"),
        };
        // Only present when configured, so homogeneous-fleet reports keep
        // their exact historical bytes.
        if let Some(p) = &self.population {
            obj.insert("population".into(), p.to_json());
        }
        if let Some(e) = &self.envelope {
            obj.insert("fleet_scenario".into(), Json::Str(e.clone()));
        }
        Json::Obj(obj)
    }

    /// Render the per-arm table plus fleet totals for the CLI.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:36} {:>4} {:>9} {:>7} {:>8} {:>8} {:>7} {:>9} {:>8} {:>6}",
            "arm", "dev", "completed", "failed", "p50 ms", "p95 ms", "SLO %", "req/s", "avg W",
            "thrtl"
        );
        let mut any_subsampled = false;
        let mut row = |label: &str, a: &FleetAgg| {
            // '~' marks reservoir-estimated percentiles, as in serve
            // output (sessions past the Summary cap fold in subsampled).
            let approx = if a.latency.is_subsampled() { "~" } else { "" };
            any_subsampled |= a.latency.is_subsampled();
            let _ = writeln!(
                out,
                "{:36} {:>4} {:>9} {:>7} {:>8} {:>8} {:>7} {:>9.2} {:>8.2} {:>6}",
                label,
                a.devices,
                a.completed,
                a.failed,
                format!(
                    "{approx}{:.2}",
                    if a.latency.is_empty() { 0.0 } else { a.latency.p50() }
                ),
                format!(
                    "{approx}{:.2}",
                    if a.latency.is_empty() { 0.0 } else { a.latency.p95() }
                ),
                a.slo_satisfaction()
                    .map(|v| format!("{:.1}", v * 100.0))
                    .unwrap_or_else(|| "-".into()),
                a.throughput_rps(),
                a.avg_watts(),
                a.throttle_events,
            );
        };
        for a in &self.arms {
            row(&a.spec.label(), &a.agg);
        }
        row("fleet total", &self.total);
        if let Some(p) = &self.population {
            let _ = writeln!(out, "population: {}", p.label());
        }
        if let Some(e) = &self.envelope {
            let _ = writeln!(out, "fleet scenario: {e}");
        }
        if self.total.cache_hits + self.total.cache_misses > 0 {
            let _ = writeln!(
                out,
                "weights: {} hits / {} misses / {} evictions, {:.1} MiB \
                 cold-loaded ({:.0} ms stall)",
                self.total.cache_hits,
                self.total.cache_misses,
                self.total.cache_evictions,
                self.total.cache_bytes_loaded as f64 / (1u64 << 20) as f64,
                self.total.cold_load_ms(),
            );
        }
        let t = &self.total;
        if t.proc_fails + t.faulted + t.retries + t.retries_exhausted + t.timeouts > 0 {
            let _ = writeln!(
                out,
                "faults: {} proc fails / {} recovers / {} timeouts; {} retries, \
                 {} faulted, {} retries exhausted",
                t.proc_fails, t.proc_recovers, t.timeouts, t.retries, t.faulted,
                t.retries_exhausted,
            );
        }
        if t.replans > 0 {
            let _ = writeln!(
                out,
                "replans: {} granularity switch(es) ({} finer, {} coarser)",
                t.replans, t.replans_finer, t.replans_coarser,
            );
        }
        if any_subsampled {
            let _ = writeln!(
                out,
                "note: '~' percentiles are reservoir estimates (a session exceeded the \
                 per-device sample cap)"
            );
        }
        out
    }
}

/// The fleet's shared, pre-resolved execution state: one warmed
/// [`RunSpec`] per (arm × population-SoC) variant, built once on one
/// thread before any worker starts. `run_device` is a pure function of
/// the device id from here on — that is the whole determinism story.
struct FleetRuntime<'a> {
    spec: &'a FleetSpec,
    /// `variants[arm][v]`: `v` indexes the population's SoC mix
    /// (declaration order), or the single nominal spec when homogeneous.
    variants: Vec<Vec<RunSpec>>,
}

impl<'a> FleetRuntime<'a> {
    fn prepare(spec: &'a FleetSpec) -> Result<Self> {
        if spec.arms.is_empty() {
            bail!("fleet has no arms: give at least one (soc, scheduler, workload) triple");
        }
        if spec.devices == 0 {
            bail!("fleet has no devices (--devices must be ≥ 1)");
        }
        if let Some(p) = &spec.population {
            p.validate()?;
        }
        // Resolve and validate every variant up front, on one thread, and
        // warm the plan/tuning memo tables (`RunSpec::warm_caches` really
        // builds the plans) so the shards start from shared cached
        // partitionings instead of racing to compute them N ways on a
        // cold process. The fleet envelope is applied here, once per
        // variant — it is a pure function of (compiled workload,
        // envelope, horizon), so every device of a variant shares the
        // same modulated event schedule.
        let mut variants = Vec::with_capacity(spec.arms.len());
        for arm in &spec.arms {
            // An empty mix means "conditions only": each arm keeps its
            // nominal SoC and every device lands on variant 0.
            let socs: Vec<String> = match &spec.population {
                Some(p) if !p.soc_mix.is_empty() => {
                    p.soc_names().iter().map(|s| s.to_string()).collect()
                }
                _ => vec![arm.soc.clone()],
            };
            let mut v = Vec::with_capacity(socs.len());
            for soc in socs {
                let variant = ArmSpec { soc, ..arm.clone() };
                let mut rs = variant.to_run_spec(&spec.cfg)?;
                if let Some(env) = &spec.envelope {
                    env.apply(&mut rs.apps, &mut rs.events, rs.cfg.duration_ms);
                }
                rs.warm_caches().map_err(|e| anyhow!("arm '{}': {e}", variant.label()))?;
                v.push(rs);
            }
            variants.push(v);
        }
        Ok(FleetRuntime { spec, variants })
    }

    /// Simulate device `d` and collapse its report to a digest. Same
    /// output for the same `d` no matter which worker calls this, when.
    fn run_device(&self, d: usize) -> Result<DeviceDigest> {
        let arm = d % self.variants.len();
        let dseed = device_seed(self.spec.seed, d);
        let variant = match &self.spec.population {
            Some(p) => p.sample_soc_index(dseed),
            None => 0,
        };
        let mut rs = self.variants[arm][variant].clone();
        rs.cfg.seed = dseed;
        if let Some(p) = &self.spec.population {
            let preset = rs.cfg.ambient_c.unwrap_or(rs.soc.ambient_c);
            if let Some(a) = p.sample_ambient_c(dseed, preset) {
                rs.cfg.ambient_c = Some(a);
            }
            if let Some(bg) = p.sample_bg_load(dseed) {
                rs.cfg.bg_load = bg;
            }
        }
        let report = rs
            .run_sim()
            .map_err(|e| anyhow!("device {d} (arm '{}'): {e}", self.spec.arms[arm].label()))?;
        Ok(DeviceDigest::from_report(d, arm, dseed, &report))
    }
}

/// Decrements a counter on scope exit — including panic unwind, so the
/// progress poller can never spin on a dead worker.
struct DecOnDrop<'a>(&'a AtomicUsize);

impl Drop for DecOnDrop<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Relaxed);
    }
}

/// Poll the shared progress counters from the coordinating thread,
/// printing a stderr heartbeat about once a second until the fleet
/// drains, errors, or every worker exits.
fn progress_loop(total: u64, done: &AtomicU64, failed: &AtomicBool, live: &AtomicUsize) {
    let t0 = std::time::Instant::now();
    let mut ticks = 0u32;
    loop {
        std::thread::sleep(std::time::Duration::from_millis(250));
        let dn = done.load(Relaxed);
        if dn >= total || failed.load(Relaxed) || live.load(Relaxed) == 0 {
            break;
        }
        ticks += 1;
        if ticks % 4 == 0 {
            let rate = dn as f64 / t0.elapsed().as_secs_f64().max(1e-9);
            eprintln!("fleet: {dn}/{total} devices ({rate:.0} dev/s)");
        }
    }
    let dn = done.load(Relaxed);
    let secs = t0.elapsed().as_secs_f64();
    eprintln!(
        "fleet: {dn}/{total} devices done in {secs:.1}s ({:.0} dev/s)",
        dn as f64 / secs.max(1e-9)
    );
}

/// Run the fleet with default [`FleetOptions`]. Device `d` runs arm
/// `d % arms` under seed [`device_seed`]`(spec.seed, d)`; per-device
/// results stream into per-arm aggregates (see the module docs for the
/// determinism argument and the O(arms × workers) memory bound).
pub fn run_fleet(spec: &FleetSpec, workers: usize) -> Result<FleetReport> {
    run_fleet_opts(spec, workers, &FleetOptions::default())
}

/// [`run_fleet`] with execution knobs (progress heartbeat, claim-chunk
/// size). The knobs never change the report's bytes.
pub fn run_fleet_opts(
    spec: &FleetSpec,
    workers: usize,
    opts: &FleetOptions,
) -> Result<FleetReport> {
    let rt = FleetRuntime::prepare(spec)?;
    let n_arms = spec.arms.len();
    let workers = workers.clamp(1, spec.devices);
    let chunk = if opts.chunk > 0 {
        opts.chunk
    } else {
        (spec.devices / (workers * 16)).clamp(1, 512)
    };

    // Dynamic sharding: workers claim half-open chunks [start, start+chunk)
    // of device ids from a shared cursor until it passes the end. A slow
    // chunk (heavy arm, hot device) just means that worker claims fewer
    // chunks — no static assignment to straggle on.
    let cursor = AtomicUsize::new(0);
    let done = AtomicU64::new(0);
    let failed = AtomicBool::new(false);
    let live = AtomicUsize::new(workers);

    let results: Vec<Result<Vec<FleetAgg>>> = std::thread::scope(|scope| {
        let rt = &rt;
        let (cursor, done, failed, live) = (&cursor, &done, &failed, &live);
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(move || {
                    let _live = DecOnDrop(live);
                    let mut aggs: Vec<FleetAgg> =
                        (0..n_arms).map(|_| FleetAgg::default()).collect();
                    loop {
                        let start = cursor.fetch_add(chunk, Relaxed);
                        if start >= spec.devices {
                            return Ok(aggs);
                        }
                        for d in start..(start + chunk).min(spec.devices) {
                            if failed.load(Relaxed) {
                                return Ok(aggs);
                            }
                            match rt.run_device(d) {
                                Ok(dig) => {
                                    aggs[dig.arm].absorb(&dig);
                                    done.fetch_add(1, Relaxed);
                                }
                                Err(e) => {
                                    failed.store(true, Relaxed);
                                    return Err(e);
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        if opts.progress {
            progress_loop(spec.devices as u64, done, failed, live);
        }
        handles.into_iter().map(|h| h.join().expect("fleet worker panicked")).collect()
    });

    // Merge worker partials per arm. Exactness makes the worker order
    // irrelevant; we still iterate in spawn order because it is the
    // natural one.
    let mut arm_aggs: Vec<FleetAgg> = (0..n_arms).map(|_| FleetAgg::default()).collect();
    for r in results {
        for (a, p) in arm_aggs.iter_mut().zip(&r?) {
            a.merge(p);
        }
    }
    Ok(FleetReport::from_arm_aggs(spec, arm_aggs))
}

/// Reference implementation: run every device on the calling thread,
/// materialize all digests, and fold them in device-id order — the
/// pre-streaming semantics, O(devices) memory and all. Exists so
/// `tests/fleet_rt.rs` can hold [`run_fleet`]'s byte-exactness to an
/// independent implementation; never call it for real work.
#[doc(hidden)]
pub fn run_fleet_materialized(spec: &FleetSpec) -> Result<FleetReport> {
    let rt = FleetRuntime::prepare(spec)?;
    let mut digests = Vec::with_capacity(spec.devices);
    for d in 0..spec.devices {
        digests.push(rt.run_device(d)?);
    }
    Ok(FleetReport::merge_materialized(spec, digests))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_seeds_are_distinct_and_stable() {
        let seen: std::collections::HashSet<u64> =
            (0..256).map(|d| device_seed(42, d)).collect();
        assert_eq!(seen.len(), 256, "device seeds collided");
        // Stable across calls (a pure function of (fleet seed, device)).
        assert_eq!(device_seed(42, 7), device_seed(42, 7));
        assert_ne!(device_seed(42, 7), device_seed(43, 7));
    }

    #[test]
    fn arm_validation_rejects_unknown_names() {
        let cfg = SimConfig::default();
        let bad_soc = ArmSpec::new("nope", "adms", "frs");
        assert!(bad_soc.to_run_spec(&cfg).is_err());
        let bad_sched = ArmSpec::new("dimensity9000", "nope", "frs");
        assert!(bad_sched.to_run_spec(&cfg).is_err());
        let bad_wl = ArmSpec::new("dimensity9000", "adms", "not_a_workload");
        assert!(bad_wl.to_run_spec(&cfg).is_err());
        let ok = ArmSpec::new("dimensity9000", "band", "mobilenet_v1,east");
        let rs = ok.to_run_spec(&cfg).unwrap();
        assert_eq!(rs.apps.len(), 2);
        let sc = ArmSpec::new("dimensity9000", "adms", "scenario:churn_mix");
        let rs = sc.to_run_spec(&cfg).unwrap();
        assert!(!rs.events.is_empty(), "scenario arm lost its lifecycle events");
        // Per-arm batching overrides land in the run spec's config.
        let batched = ArmSpec::new("dimensity9000", "adms", "frs").batched(4, 5.0);
        let rs = batched.to_run_spec(&cfg).unwrap();
        assert_eq!(rs.cfg.batch_max, 4);
        assert_eq!(rs.cfg.batch_window_ms, 5.0);
        assert!(batched.label().contains("batch 4"));
        // Per-arm fault profiles parse into the run spec's config.
        let faulty = ArmSpec::new("dimensity9000", "adms", "frs").faulty("light");
        let rs = faulty.to_run_spec(&cfg).unwrap();
        assert_eq!(rs.cfg.fault_profile.as_ref().unwrap().name, "light");
        assert!(faulty.label().contains("faults light"));
        let bad_profile = ArmSpec::new("dimensity9000", "adms", "frs").faulty("wat");
        assert!(bad_profile.to_run_spec(&cfg).is_err());
        // Per-arm adaptive modes parse into the run spec's config.
        let adaptive = ArmSpec::new("dimensity9000", "adms", "frs").adaptive("reactive");
        let rs = adaptive.to_run_spec(&cfg).unwrap();
        assert!(rs.cfg.adaptive_configured());
        assert!(adaptive.label().contains("adaptive reactive"));
        let bad_mode = ArmSpec::new("dimensity9000", "adms", "frs").adaptive("wat");
        assert!(bad_mode.to_run_spec(&cfg).is_err());
    }

    #[test]
    fn agg_merge_equals_absorb_for_split_sets() {
        // Synthesize digests with adversarial float magnitudes and check
        // that (absorb all) == (absorb halves, merge) on the exact sums.
        let mk = |i: usize| {
            let mut latency = Digest::new();
            latency.add(0.5 + i as f64);
            DeviceDigest {
                device: i,
                arm: 0,
                seed: device_seed(1, i),
                sim_ms: if i % 2 == 0 { 1e16 } else { 1e-8 },
                issued: 3,
                completed: 2,
                failed: 1,
                cancelled: 0,
                latency,
                slo_ok: 1,
                slo_n: 2,
                energy_j: 0.1 * (i as f64 + 1.0),
                throttle_events: 0,
                busy_frac_sum: (i as f64).sin(),
                procs: 4,
                events: 10,
                cache_hits: 0,
                cache_misses: 0,
                cache_evictions: 0,
                cache_bytes_loaded: 0,
                cold_load_ms: 1.0 / (i as f64 + 3.0),
                failed_budget: 0,
                failed_exec: 1,
                faulted: 0,
                retries_exhausted: 0,
                retries: 0,
                proc_fails: 0,
                proc_recovers: 0,
                timeouts: 0,
                replans: 0,
                replans_finer: 0,
                replans_coarser: 0,
            }
        };
        let digests: Vec<DeviceDigest> = (0..64).map(mk).collect();
        let mut whole = FleetAgg::default();
        for d in &digests {
            whole.absorb(d);
        }
        let mut lo = FleetAgg::default();
        let mut hi = FleetAgg::default();
        for d in &digests[..31] {
            lo.absorb(d);
        }
        for d in &digests[31..] {
            hi.absorb(d);
        }
        // Merge in the "wrong" (hi-first) order on purpose.
        let mut merged = FleetAgg::default();
        merged.merge(&hi);
        merged.merge(&lo);
        assert_eq!(whole.sim_ms().to_bits(), merged.sim_ms().to_bits());
        assert_eq!(whole.energy_j().to_bits(), merged.energy_j().to_bits());
        assert_eq!(whole.busy_frac_sum().to_bits(), merged.busy_frac_sum().to_bits());
        assert_eq!(whole.cold_load_ms().to_bits(), merged.cold_load_ms().to_bits());
        assert_eq!(whole.devices, merged.devices);
    }
}
