//! Samplable device-population heterogeneity for fleet runs.
//!
//! The "Potentials and Pitfalls" paper's field observation is that
//! per-device variability — which SoC a user actually has, how hot their
//! pocket is, what else their phone is doing — dominates real-world
//! inference latency distributions. A [`PopulationSpec`] makes that
//! variability samplable: a weighted device-mix over SoC presets plus
//! per-device ambient-temperature and background-load jitter, every draw
//! taken from a salted stream off the device's own
//! [`device_seed`](super::device_seed) — so the population a device
//! lands on is a pure function of `(fleet seed, device id)`, independent
//! of sharding, worker count, and completion order, exactly like its
//! arrival sequence.
//!
//! No-op discipline: a population of one SoC equal to the arm's own,
//! with no ambient override and zero jitter, leaves every `RunSpec`
//! byte-identical to the population-free build (`fleet_rt::
//! degenerate_population_is_byte_identical_noop` pins this): the SoC
//! sample picks variant 0 = the base spec, and the jitter path never
//! touches `cfg.ambient_c` / `cfg.bg_load`.

use crate::soc::{soc_by_name, SOC_NAMES};
use crate::util::json::Json;
use crate::util::rng::splitmix64;
use anyhow::{bail, Context, Result};

/// Background load is capped below 1.0 (a device fully consumed by
/// background work would never finish anything — and the sim's service
/// scaling 1/(1−bg) diverges).
const BG_MAX: f64 = 0.9;

/// A device-population distribution: who actually runs the fleet's
/// workload, and under what local conditions.
#[derive(Debug, Clone, PartialEq)]
pub struct PopulationSpec {
    /// Weighted SoC mix: `(preset name, weight > 0)`. Sampling replaces
    /// each arm's nominal SoC per device. Empty = keep every arm's own
    /// SoC (conditions-only population).
    pub soc_mix: Vec<(String, f64)>,
    /// Ambient mean, °C (`None` = each sampled SoC's own preset ambient).
    pub ambient_mean_c: Option<f64>,
    /// Uniform ambient jitter half-width, °C: each device draws ambient
    /// in `mean ± jitter`.
    pub ambient_jitter_c: f64,
    /// Mean background load fraction in `[0, 0.9]` (see
    /// [`SimConfig::bg_load`](crate::exec::SimConfig)).
    pub bg_mean: f64,
    /// Uniform background-load jitter half-width (draws clamp to
    /// `[0, 0.9]`).
    pub bg_jitter: f64,
}

impl PopulationSpec {
    /// A uniform mix over the given presets, conditions at defaults.
    pub fn uniform(socs: &[&str]) -> Self {
        PopulationSpec {
            soc_mix: socs.iter().map(|s| (s.to_string(), 1.0)).collect(),
            ambient_mean_c: None,
            ambient_jitter_c: 0.0,
            bg_mean: 0.0,
            bg_jitter: 0.0,
        }
    }

    /// Parse the CLI mix grammar: `all` (every preset, equal weight) or
    /// `name[:weight],name[:weight],...` (weights default to 1).
    pub fn parse_mix(s: &str) -> Result<Self> {
        if s == "all" {
            return Ok(Self::uniform(&SOC_NAMES));
        }
        let mut mix = Vec::new();
        if s.is_empty() {
            bail!("population mix is empty (try --population all)");
        }
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (name, w) = match part.split_once(':') {
                Some((n, w)) => {
                    (n, w.parse::<f64>().with_context(|| format!("mix weight in '{part}'"))?)
                }
                None => (part, 1.0),
            };
            mix.push((name.to_string(), w));
        }
        let spec = PopulationSpec { soc_mix: mix, ..Self::uniform(&[]) };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        for (name, w) in &self.soc_mix {
            if soc_by_name(name).is_none() {
                bail!(
                    "population mix: unknown soc '{name}' (expected one of: {})",
                    SOC_NAMES.join(", ")
                );
            }
            if !w.is_finite() || *w <= 0.0 {
                bail!("population mix: weight for '{name}' must be positive, got {w}");
            }
        }
        if !self.ambient_jitter_c.is_finite() || self.ambient_jitter_c < 0.0 {
            bail!("ambient jitter must be a finite non-negative °C value");
        }
        if let Some(m) = self.ambient_mean_c {
            if !m.is_finite() {
                bail!("ambient mean must be finite");
            }
        }
        if !(0.0..=BG_MAX).contains(&self.bg_mean) {
            bail!("bg load mean must be in [0, {BG_MAX}], got {}", self.bg_mean);
        }
        if !self.bg_jitter.is_finite() || self.bg_jitter < 0.0 {
            bail!("bg load jitter must be finite and non-negative");
        }
        Ok(())
    }

    /// The mix's preset names, in declaration order (variant indices for
    /// the fleet's pre-resolved per-arm `RunSpec` table follow this).
    pub fn soc_names(&self) -> Vec<&str> {
        self.soc_mix.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Which mix variant device with seed `dev_seed` lands on: a weighted
    /// draw from the device's salted population stream.
    pub fn sample_soc_index(&self, dev_seed: u64) -> usize {
        if self.soc_mix.len() <= 1 {
            return 0;
        }
        let total: f64 = self.soc_mix.iter().map(|(_, w)| w).sum();
        let mut x = unit_draw(dev_seed, SALT_SOC) * total;
        for (i, (_, w)) in self.soc_mix.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        self.soc_mix.len() - 1
    }

    /// Per-device ambient draw, °C, around `mean ± jitter` (`None` when
    /// the spec leaves ambient entirely at the preset default — the
    /// caller must then not touch `cfg.ambient_c`, preserving the no-op).
    pub fn sample_ambient_c(&self, dev_seed: u64, preset_ambient_c: f64) -> Option<f64> {
        if self.ambient_mean_c.is_none() && self.ambient_jitter_c == 0.0 {
            return None;
        }
        let mean = self.ambient_mean_c.unwrap_or(preset_ambient_c);
        Some(mean + (2.0 * unit_draw(dev_seed, SALT_AMBIENT) - 1.0) * self.ambient_jitter_c)
    }

    /// Per-device background-load draw in `[0, 0.9]` (`None` when the
    /// spec models no background load at all).
    pub fn sample_bg_load(&self, dev_seed: u64) -> Option<f64> {
        if self.bg_mean == 0.0 && self.bg_jitter == 0.0 {
            return None;
        }
        let bg = self.bg_mean + (2.0 * unit_draw(dev_seed, SALT_BG) - 1.0) * self.bg_jitter;
        Some(bg.clamp(0.0, BG_MAX))
    }

    /// Human label for reports.
    pub fn label(&self) -> String {
        let mut l = if self.soc_mix.is_empty() {
            "nominal socs".to_string()
        } else {
            let mix = self
                .soc_mix
                .iter()
                .map(|(n, w)| format!("{n}:{w}"))
                .collect::<Vec<_>>()
                .join(",");
            format!("mix {mix}")
        };
        if self.ambient_mean_c.is_some() || self.ambient_jitter_c > 0.0 {
            let mean = self
                .ambient_mean_c
                .map(|m| format!("{m}"))
                .unwrap_or_else(|| "preset".into());
            l.push_str(&format!(", ambient {mean}±{} °C", self.ambient_jitter_c));
        }
        if self.bg_mean > 0.0 || self.bg_jitter > 0.0 {
            l.push_str(&format!(", bg {}±{}", self.bg_mean, self.bg_jitter));
        }
        l
    }

    pub(crate) fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "soc_mix",
                Json::Arr(
                    self.soc_mix
                        .iter()
                        .map(|(n, w)| {
                            Json::obj(vec![
                                ("soc", Json::Str(n.clone())),
                                ("weight", Json::Num(*w)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "ambient_mean_c",
                self.ambient_mean_c.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("ambient_jitter_c", Json::Num(self.ambient_jitter_c)),
            ("bg_mean", Json::Num(self.bg_mean)),
            ("bg_jitter", Json::Num(self.bg_jitter)),
        ])
    }
}

// Distinct salts keep the population draws decorrelated from each other
// AND from the device's simulation streams (which consume the unsalted
// device seed through Pcg32).
const SALT_SOC: u64 = 0x5ca1ab1e_0000_0001;
const SALT_AMBIENT: u64 = 0x5ca1ab1e_0000_0002;
const SALT_BG: u64 = 0x5ca1ab1e_0000_0003;

/// One uniform draw in `[0, 1)` from the device's salted stream — a pure
/// function of `(device seed, salt)`.
fn unit_draw(dev_seed: u64, salt: u64) -> f64 {
    let u = splitmix64(dev_seed ^ splitmix64(salt));
    (u >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_mix_grammar() {
        let all = PopulationSpec::parse_mix("all").unwrap();
        assert_eq!(all.soc_mix.len(), SOC_NAMES.len());
        let two = PopulationSpec::parse_mix("dimensity9000:3,kirin970").unwrap();
        assert_eq!(
            two.soc_mix,
            vec![("dimensity9000".to_string(), 3.0), ("kirin970".to_string(), 1.0)]
        );
        assert!(PopulationSpec::parse_mix("").is_err());
        assert!(PopulationSpec::parse_mix("notasoc").is_err());
        assert!(PopulationSpec::parse_mix("kirin970:-1").is_err());
        assert!(PopulationSpec::parse_mix("kirin970:wat").is_err());
    }

    #[test]
    fn draws_are_deterministic_and_weighted() {
        let p = PopulationSpec::parse_mix("dimensity9000:9,snapdragon835:1").unwrap();
        let mut counts = [0usize; 2];
        for d in 0..2000u64 {
            let seed = crate::fleet::device_seed(42, d as usize);
            let i = p.sample_soc_index(seed);
            assert_eq!(i, p.sample_soc_index(seed), "sampling must be pure");
            counts[i] += 1;
        }
        // 9:1 mix: the heavy preset dominates (loose bound, seeded draw).
        assert!(counts[0] > counts[1] * 4, "mix weights ignored: {counts:?}");
    }

    #[test]
    fn condition_sampling_respects_the_noop_contract() {
        let quiet = PopulationSpec::uniform(&["kirin970"]);
        assert_eq!(quiet.sample_ambient_c(123, 25.0), None);
        assert_eq!(quiet.sample_bg_load(123), None);
        let mut hot = quiet.clone();
        hot.ambient_mean_c = Some(35.0);
        hot.ambient_jitter_c = 5.0;
        hot.bg_mean = 0.3;
        hot.bg_jitter = 0.2;
        hot.validate().unwrap();
        for d in 0..200u64 {
            let seed = crate::fleet::device_seed(7, d as usize);
            let a = hot.sample_ambient_c(seed, 25.0).unwrap();
            assert!((30.0..=40.0).contains(&a), "ambient {a} out of mean±jitter");
            let bg = hot.sample_bg_load(seed).unwrap();
            assert!((0.0..=0.5 + 1e-12).contains(&bg), "bg {bg} out of range");
        }
    }

    #[test]
    fn validate_rejects_bad_conditions() {
        let mut p = PopulationSpec::uniform(&["kirin970"]);
        p.bg_mean = 0.95;
        assert!(p.validate().is_err());
        p.bg_mean = 0.2;
        p.validate().unwrap();
        p.ambient_jitter_c = -1.0;
        assert!(p.validate().is_err());
    }
}
