//! Scheduler tournament: every scheduler × SoC preset × scenario, one
//! mergeable table — scheduler comparison as a single regenerable
//! experiment (`adms tournament`, written to `TOURNAMENT.json`).
//!
//! A tournament is a thin shape over the fleet layer: the (soc, sched,
//! scenario) cross product becomes one [`ArmSpec`] per cell with
//! `devices_per_arm` devices each, and the whole population runs through
//! [`run_fleet`] — so worker-count byte-determinism, per-device seeding,
//! and the digest merge order are all inherited rather than re-proven
//! (`tests/fleet_rt.rs` pins the inherited guarantee on the tournament
//! surface too). Rows come out sorted by (soc, sched, scenario), making
//! two tournaments over different cells trivially mergeable by
//! concatenation.

use super::{run_fleet, ArmSpec, FleetAgg, FleetSpec};
use crate::exec::SimConfig;
use crate::util::json::Json;
use anyhow::{bail, Result};

/// The cross product to evaluate. Name lists are sorted and deduplicated
/// at run time, so the row order of the output table is a function of the
/// *set* of cells, not of CLI argument order.
#[derive(Debug, Clone)]
pub struct TournamentSpec {
    /// SoC preset names (`soc::SOC_NAMES`).
    pub socs: Vec<String>,
    /// Scheduler names (`exec::SCHEDULER_NAMES`); `lookahead` arms take
    /// their horizon/beam/base from `cfg`.
    pub scheds: Vec<String>,
    /// Scenario names or spec files (`scenario::resolve`).
    pub scenarios: Vec<String>,
    /// Simulated devices per (soc, sched, scenario) cell.
    pub devices_per_arm: usize,
    pub seed: u64,
    /// Per-device execution config (`cfg.seed` is overwritten per device).
    pub cfg: SimConfig,
}

/// One (soc, sched, scenario) cell's merged result.
#[derive(Debug, Clone)]
pub struct TournamentRow {
    pub soc: String,
    pub sched: String,
    pub scenario: String,
    pub agg: FleetAgg,
}

/// The whole table, in (soc, sched, scenario) row order.
#[derive(Debug, Clone)]
pub struct TournamentReport {
    pub devices_per_arm: usize,
    pub seed: u64,
    pub rows: Vec<TournamentRow>,
}

impl TournamentReport {
    /// Find a cell (exact names, post-sort spelling).
    pub fn row(&self, soc: &str, sched: &str, scenario: &str) -> Option<&TournamentRow> {
        self.rows
            .iter()
            .find(|r| r.soc == soc && r.sched == sched && r.scenario == scenario)
    }

    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                let mut obj = match r.agg.to_json() {
                    Json::Obj(o) => o,
                    _ => unreachable!("agg serializes as an object"),
                };
                obj.insert("soc".into(), Json::Str(r.soc.clone()));
                obj.insert("sched".into(), Json::Str(r.sched.clone()));
                obj.insert("scenario".into(), Json::Str(r.scenario.clone()));
                Json::Obj(obj)
            })
            .collect();
        Json::obj(vec![
            ("devices_per_arm", Json::Num(self.devices_per_arm as f64)),
            // String for the same reason as the fleet report: u64 seeds
            // above 2^53 would round through f64.
            ("seed", Json::Str(self.seed.to_string())),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Render the table for the CLI, grouped by (soc, scenario) so the
    /// scheduler comparison reads down the column.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:14} {:16} {:10} {:>9} {:>7} {:>8} {:>8} {:>9} {:>6}",
            "soc", "scenario", "sched", "completed", "failed", "p50 ms", "p95 ms", "req/s",
            "thrtl"
        );
        for r in &self.rows {
            let a = &r.agg;
            let approx = if a.latency.is_subsampled() { "~" } else { "" };
            let _ = writeln!(
                out,
                "{:14} {:16} {:10} {:>9} {:>7} {:>8} {:>8} {:>9.2} {:>6}",
                r.soc,
                r.scenario,
                r.sched,
                a.completed,
                a.failed,
                format!(
                    "{approx}{:.2}",
                    if a.latency.is_empty() { 0.0 } else { a.latency.p50() }
                ),
                format!(
                    "{approx}{:.2}",
                    if a.latency.is_empty() { 0.0 } else { a.latency.p95() }
                ),
                a.throughput_rps(),
                a.throttle_events,
            );
        }
        out
    }
}

/// Run the full cross product, `devices_per_arm` devices per cell,
/// sharded over `workers` threads. Byte-deterministic across worker
/// counts (inherited from [`run_fleet`]).
pub fn run_tournament(spec: &TournamentSpec, workers: usize) -> Result<TournamentReport> {
    if spec.socs.is_empty() || spec.scheds.is_empty() || spec.scenarios.is_empty() {
        bail!("tournament needs at least one soc, one scheduler, and one scenario");
    }
    if spec.devices_per_arm == 0 {
        bail!("tournament needs at least one device per arm");
    }
    let canon = |names: &[String]| -> Vec<String> {
        let mut v = names.to_vec();
        v.sort();
        v.dedup();
        v
    };
    let socs = canon(&spec.socs);
    let scheds = canon(&spec.scheds);
    let scenarios = canon(&spec.scenarios);
    // Row order = arm order = (soc, sched, scenario) lexicographic.
    let mut arms = Vec::new();
    let mut cells = Vec::new();
    for soc in &socs {
        for sched in &scheds {
            for scenario in &scenarios {
                arms.push(ArmSpec::new(soc, sched, &format!("scenario:{scenario}")));
                cells.push((soc.clone(), sched.clone(), scenario.clone()));
            }
        }
    }
    let fleet = FleetSpec {
        devices: arms.len() * spec.devices_per_arm,
        arms,
        seed: spec.seed,
        cfg: spec.cfg.clone(),
        population: None,
        envelope: None,
    };
    let report = run_fleet(&fleet, workers)?;
    // Device d runs arm d % arms, so with devices = cells × per_arm every
    // cell gets exactly `devices_per_arm` devices; fleet arm order is the
    // arm vector's order, which is the cell order built above.
    let rows = report
        .arms
        .into_iter()
        .zip(cells)
        .map(|(a, (soc, sched, scenario))| TournamentRow { soc, sched, scenario, agg: a.agg })
        .collect();
    Ok(TournamentReport { devices_per_arm: spec.devices_per_arm, seed: spec.seed, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_lists_are_canonicalized_and_rows_ordered() {
        let spec = TournamentSpec {
            socs: vec!["kirin970".into(), "dimensity9000".into(), "kirin970".into()],
            scheds: vec!["band".into(), "adms".into()],
            scenarios: vec!["frs_burst".into()],
            devices_per_arm: 1,
            seed: 7,
            cfg: SimConfig {
                duration_ms: 400.0,
                max_requests: Some(2),
                ..SimConfig::default()
            },
        };
        let report = run_tournament(&spec, 2).unwrap();
        // Duplicate soc deduped: 2 socs × 2 scheds × 1 scenario = 4 rows.
        assert_eq!(report.rows.len(), 4);
        let keys: Vec<(String, String, String)> = report
            .rows
            .iter()
            .map(|r| (r.soc.clone(), r.sched.clone(), r.scenario.clone()))
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "rows must come out (soc, sched, scenario)-sorted");
        assert!(report.row("kirin970", "band", "frs_burst").is_some());
        assert!(report.row("kirin970", "nope", "frs_burst").is_none());
        // Every cell simulated its devices.
        for r in &report.rows {
            assert_eq!(r.agg.devices, 1, "cell {:?} device count", (&r.soc, &r.sched));
        }
    }

    #[test]
    fn empty_cells_are_rejected() {
        let spec = TournamentSpec {
            socs: vec![],
            scheds: vec!["adms".into()],
            scenarios: vec!["frs_burst".into()],
            devices_per_arm: 1,
            seed: 1,
            cfg: SimConfig::default(),
        };
        assert!(run_tournament(&spec, 1).is_err());
    }
}
