//! Fluent graph construction. Zoo model builders use this API; each
//! method appends one op node, computes its output shape and FLOPs/param
//! annotations, and returns the new node's id.

use super::ops::OpKind;
use super::shape::{conv2d_flops, depthwise_flops, fc_flops, TensorShape};
use super::{Graph, Node, NodeId};

/// Builder for a [`Graph`]. Nodes are appended in topological order by
/// construction.
pub struct GraphBuilder {
    name: String,
    nodes: Vec<Node>,
    dtype_bytes: u64,
}

impl GraphBuilder {
    /// `dtype_bytes`: 4 for float32 models, 1 for int8-quantized models.
    pub fn new(name: &str, dtype_bytes: u64) -> Self {
        GraphBuilder { name: name.to_string(), nodes: Vec::new(), dtype_bytes }
    }

    fn shape(&self, id: NodeId) -> TensorShape {
        self.nodes[id].out_shape
    }

    /// Output shape of an already-added node (for builders that need to
    /// size later ops from earlier ones).
    pub fn peek_shape(&self, id: NodeId) -> TensorShape {
        self.shape(id)
    }

    fn push(
        &mut self,
        kind: OpKind,
        inputs: Vec<NodeId>,
        out_shape: TensorShape,
        flops: u64,
        param_bytes: u64,
    ) -> NodeId {
        let id = self.nodes.len();
        let name = format!("{}_{}", kind.label().to_lowercase(), id);
        self.nodes.push(Node { id, kind, name, inputs, out_shape, flops, param_bytes });
        id
    }

    pub fn input(&mut self, dims: [u64; 4]) -> NodeId {
        let s = TensorShape::new(&dims);
        self.push(OpKind::Input, vec![], s, 0, 0)
    }

    pub fn input_vec(&mut self, dims: &[u64]) -> NodeId {
        let s = TensorShape::new(dims);
        self.push(OpKind::Input, vec![], s, 0, 0)
    }

    /// SAME-padded convolution, square kernel `k`, stride `s`.
    pub fn conv2d(&mut self, x: NodeId, c_out: u64, k: u64, stride: u64) -> NodeId {
        self.conv_like(OpKind::Conv2d, x, c_out, k, stride, 1)
    }

    /// Atrous convolution with the given dilation rate (stride 1).
    pub fn dilated_conv2d(&mut self, x: NodeId, c_out: u64, k: u64, dilation: u64) -> NodeId {
        self.conv_like(OpKind::DilatedConv2d, x, c_out, k, 1, dilation)
    }

    /// One half of a spatially factorized convolution (a 1×k or k×1
    /// kernel), stride 1. Weight and FLOP counts scale with `k`, not `k²`
    /// — InceptionV4's 1×7/7×1 pairs and block-C 1×3/3×1 splits use this.
    pub fn factorized_conv2d(&mut self, x: NodeId, c_out: u64, k: u64) -> NodeId {
        let s = self.shape(x);
        let flops = 2 * s.h() * s.w() * c_out * s.c() * k;
        let params = (s.c() * c_out * k + c_out) * self.dtype_bytes;
        self.push(
            OpKind::Conv2d,
            vec![x],
            TensorShape::nhwc(s.n(), s.h(), s.w(), c_out),
            flops,
            params,
        )
    }

    fn conv_like(
        &mut self,
        kind: OpKind,
        x: NodeId,
        c_out: u64,
        k: u64,
        stride: u64,
        _dilation: u64,
    ) -> NodeId {
        let s = self.shape(x);
        let (oh, ow) = s.conv_out(stride);
        let flops = conv2d_flops(oh, ow, s.c(), c_out, k);
        let params = (s.c() * c_out * k * k + c_out) * self.dtype_bytes;
        self.push(kind, vec![x], TensorShape::nhwc(s.n(), oh, ow, c_out), flops, params)
    }

    /// SAME-padded depthwise convolution (channel multiplier 1).
    pub fn depthwise_conv2d(&mut self, x: NodeId, k: u64, stride: u64) -> NodeId {
        let s = self.shape(x);
        let (oh, ow) = s.conv_out(stride);
        let flops = depthwise_flops(oh, ow, s.c(), k);
        let params = (s.c() * k * k + s.c()) * self.dtype_bytes;
        self.push(
            OpKind::DepthwiseConv2d,
            vec![x],
            TensorShape::nhwc(s.n(), oh, ow, s.c()),
            flops,
            params,
        )
    }

    /// Transposed convolution that doubles spatial dims.
    pub fn transpose_conv2d(&mut self, x: NodeId, c_out: u64, k: u64) -> NodeId {
        let s = self.shape(x);
        let (oh, ow) = (s.h() * 2, s.w() * 2);
        let flops = conv2d_flops(oh, ow, s.c(), c_out, k);
        let params = (s.c() * c_out * k * k + c_out) * self.dtype_bytes;
        self.push(
            OpKind::TransposeConv2d,
            vec![x],
            TensorShape::nhwc(s.n(), oh, ow, c_out),
            flops,
            params,
        )
    }

    pub fn fully_connected(&mut self, x: NodeId, c_out: u64) -> NodeId {
        let s = self.shape(x);
        let c_in = s.elements() / s.n();
        let flops = s.n() * fc_flops(c_in, c_out);
        let params = (c_in * c_out + c_out) * self.dtype_bytes;
        self.push(
            OpKind::FullyConnected,
            vec![x],
            TensorShape::new(&[s.n(), c_out]),
            flops,
            params,
        )
    }

    fn eltwise(&mut self, kind: OpKind, a: NodeId, b: NodeId) -> NodeId {
        let sa = self.shape(a);
        let sb = self.shape(b);
        // Broadcasting: output takes the larger element count.
        let out = if sa.elements() >= sb.elements() { sa } else { sb };
        self.push(kind, vec![a, b], out, out.elements(), 0)
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.eltwise(OpKind::Add, a, b)
    }
    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.eltwise(OpKind::Sub, a, b)
    }
    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.eltwise(OpKind::Mul, a, b)
    }
    pub fn div(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.eltwise(OpKind::Div, a, b)
    }

    fn unary(&mut self, kind: OpKind, x: NodeId, flops_per_elem: u64) -> NodeId {
        let s = self.shape(x);
        self.push(kind, vec![x], s, s.elements() * flops_per_elem, 0)
    }

    pub fn relu(&mut self, x: NodeId) -> NodeId {
        self.unary(OpKind::Relu, x, 1)
    }
    pub fn relu6(&mut self, x: NodeId) -> NodeId {
        self.unary(OpKind::Relu6, x, 1)
    }
    pub fn logistic(&mut self, x: NodeId) -> NodeId {
        self.unary(OpKind::Logistic, x, 4)
    }
    pub fn tanh(&mut self, x: NodeId) -> NodeId {
        self.unary(OpKind::Tanh, x, 4)
    }
    pub fn hard_swish(&mut self, x: NodeId) -> NodeId {
        self.unary(OpKind::HardSwish, x, 3)
    }
    pub fn softmax(&mut self, x: NodeId) -> NodeId {
        self.unary(OpKind::Softmax, x, 5)
    }
    pub fn batch_norm(&mut self, x: NodeId) -> NodeId {
        let s = self.shape(x);
        let params = 4 * s.c() * self.dtype_bytes;
        self.push(OpKind::BatchNorm, vec![x], s, 2 * s.elements(), params)
    }
    pub fn quantize(&mut self, x: NodeId) -> NodeId {
        self.unary(OpKind::Quantize, x, 1)
    }
    pub fn dequantize(&mut self, x: NodeId) -> NodeId {
        self.unary(OpKind::Dequantize, x, 1)
    }

    pub fn max_pool2d(&mut self, x: NodeId, k: u64, stride: u64) -> NodeId {
        self.pool(OpKind::MaxPool2d, x, k, stride)
    }
    pub fn avg_pool2d(&mut self, x: NodeId, k: u64, stride: u64) -> NodeId {
        self.pool(OpKind::AvgPool2d, x, k, stride)
    }

    fn pool(&mut self, kind: OpKind, x: NodeId, k: u64, stride: u64) -> NodeId {
        let s = self.shape(x);
        let (oh, ow) = s.conv_out(stride);
        let flops = oh * ow * s.c() * k * k;
        self.push(kind, vec![x], TensorShape::nhwc(s.n(), oh, ow, s.c()), flops, 0)
    }

    /// Global spatial mean (keepdims=false): NHWC -> [N, C].
    pub fn mean(&mut self, x: NodeId) -> NodeId {
        let s = self.shape(x);
        self.push(
            OpKind::Mean,
            vec![x],
            TensorShape::new(&[s.n(), s.c()]),
            s.elements(),
            0,
        )
    }

    /// Channel-axis concatenation.
    pub fn concat(&mut self, xs: &[NodeId]) -> NodeId {
        assert!(!xs.is_empty());
        let first = self.shape(xs[0]);
        let c: u64 = xs.iter().map(|&x| self.shape(x).c()).sum();
        let out = TensorShape::nhwc(first.n(), first.h(), first.w(), c);
        self.push(OpKind::Concat, xs.to_vec(), out, 0, 0)
    }

    pub fn reshape(&mut self, x: NodeId, dims: &[u64]) -> NodeId {
        let s = self.shape(x);
        let out = TensorShape::new(dims);
        assert_eq!(s.elements(), out.elements(), "reshape must preserve elements");
        self.push(OpKind::Reshape, vec![x], out, 0, 0)
    }

    pub fn squeeze(&mut self, x: NodeId) -> NodeId {
        let s = self.shape(x);
        let dims: Vec<u64> =
            s.dims[..s.rank].iter().copied().filter(|&d| d != 1).collect();
        let out = if dims.is_empty() { TensorShape::new(&[1]) } else { TensorShape::new(&dims) };
        self.push(OpKind::Squeeze, vec![x], out, 0, 0)
    }

    pub fn pad(&mut self, x: NodeId, amount: u64) -> NodeId {
        let s = self.shape(x);
        let out = TensorShape::nhwc(s.n(), s.h() + 2 * amount, s.w() + 2 * amount, s.c());
        self.push(OpKind::Pad, vec![x], out, 0, 0)
    }

    pub fn strided_slice(&mut self, x: NodeId, keep_c: u64) -> NodeId {
        let s = self.shape(x);
        let out = TensorShape::nhwc(s.n(), s.h(), s.w(), keep_c.min(s.c()));
        self.push(OpKind::StridedSlice, vec![x], out, 0, 0)
    }

    pub fn resize_bilinear(&mut self, x: NodeId, h: u64, w: u64) -> NodeId {
        let s = self.shape(x);
        let out = TensorShape::nhwc(s.n(), h, w, s.c());
        self.push(OpKind::ResizeBilinear, vec![x], out, out.elements() * 4, 0)
    }

    /// Splits channels evenly into `n` parts; returns the part node ids.
    pub fn split(&mut self, x: NodeId, n: u64) -> Vec<NodeId> {
        let s = self.shape(x);
        let c = s.c() / n;
        let out = TensorShape::nhwc(s.n(), s.h(), s.w(), c.max(1));
        (0..n).map(|_| self.push(OpKind::Split, vec![x], out, 0, 0)).collect()
    }

    pub fn pack(&mut self, xs: &[NodeId]) -> NodeId {
        let s = self.shape(xs[0]);
        let out = TensorShape::nhwc(s.n() * xs.len() as u64, s.h(), s.w(), s.c());
        self.push(OpKind::Pack, xs.to_vec(), out, 0, 0)
    }

    /// Number of ops added so far.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn finish(self) -> Graph {
        let g = Graph { name: self.name, nodes: self.nodes, dtype_bytes: self.dtype_bytes };
        g.validate().expect("builder produced an invalid graph");
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn conv_shapes_and_params() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.input([1, 224, 224, 3]);
        let c = b.conv2d(x, 32, 3, 2);
        let g = b.finish();
        assert_eq!(g.nodes[c].out_shape, TensorShape::nhwc(1, 112, 112, 32));
        assert_eq!(g.nodes[c].param_bytes, (3 * 32 * 9 + 32) * 4);
        assert_eq!(g.nodes[c].flops, 2 * 112 * 112 * 32 * 3 * 9);
    }

    #[test]
    fn depthwise_preserves_channels() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.input([1, 56, 56, 64]);
        let d = b.depthwise_conv2d(x, 3, 2);
        let g = b.finish();
        assert_eq!(g.nodes[d].out_shape, TensorShape::nhwc(1, 28, 28, 64));
    }

    #[test]
    fn concat_sums_channels() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.input([1, 14, 14, 32]);
        let a = b.conv2d(x, 64, 1, 1);
        let c = b.conv2d(x, 96, 3, 1);
        let cat = b.concat(&[a, c]);
        let g = b.finish();
        assert_eq!(g.nodes[cat].out_shape.c(), 160);
    }

    #[test]
    fn fully_connected_flattens() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.input([1, 7, 7, 1024]);
        let m = b.mean(x);
        let f = b.fully_connected(m, 1000);
        let g = b.finish();
        assert_eq!(g.nodes[m].out_shape, TensorShape::new(&[1, 1024]));
        assert_eq!(g.nodes[f].out_shape, TensorShape::new(&[1, 1000]));
        assert_eq!(g.nodes[f].flops, 2 * 1024 * 1000);
    }

    #[test]
    #[should_panic]
    fn bad_reshape_panics() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.input([1, 4, 4, 4]);
        b.reshape(x, &[1, 65]);
    }

    #[test]
    fn split_divides_channels() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.input([1, 8, 8, 32]);
        let parts = b.split(x, 4);
        let g = b.finish();
        assert_eq!(parts.len(), 4);
        for p in parts {
            assert_eq!(g.nodes[p].out_shape.c(), 8);
            assert_eq!(g.nodes[p].kind, OpKind::Split);
        }
    }

    #[test]
    fn factorized_conv_scales_with_k_not_k_squared() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.input([1, 17, 17, 192]);
        let f = b.factorized_conv2d(x, 224, 7);
        let g = b.finish();
        assert_eq!(g.nodes[f].kind, OpKind::Conv2d);
        assert_eq!(g.nodes[f].out_shape, TensorShape::nhwc(1, 17, 17, 224));
        assert_eq!(g.nodes[f].param_bytes, (192 * 224 * 7 + 224) * 4);
        assert_eq!(g.nodes[f].flops, 2 * 17 * 17 * 224 * 192 * 7);
    }

    #[test]
    fn quantized_dtype_params() {
        let mut b = GraphBuilder::new("q", 1);
        let x = b.input([1, 16, 16, 8]);
        let c = b.conv2d(x, 8, 1, 1);
        let g = b.finish();
        assert_eq!(g.nodes[c].param_bytes, 8 * 8 + 8);
        assert_eq!(g.dtype_bytes, 1);
    }
}
