//! Graphviz DOT export for debugging partitions (`adms partition --dot`).

use super::Graph;

/// Render the graph in DOT format. `partition` optionally assigns a color
/// class per node (e.g. the subgraph index from the analyzer).
pub fn to_dot(g: &Graph, partition: Option<&[usize]>) -> String {
    const PALETTE: [&str; 8] = [
        "#e6194b", "#3cb44b", "#4363d8", "#f58231", "#911eb4", "#46f0f0", "#f032e6", "#bcf60c",
    ];
    let mut out = format!("digraph \"{}\" {{\n  rankdir=TB;\n  node [shape=box, style=filled];\n", g.name);
    for n in &g.nodes {
        let color = match partition {
            Some(p) => PALETTE[p.get(n.id).copied().unwrap_or(0) % PALETTE.len()],
            None => "#dddddd",
        };
        out.push_str(&format!(
            "  n{} [label=\"{}\\n{} {}\", fillcolor=\"{}\"];\n",
            n.id,
            n.name,
            n.kind.label(),
            n.out_shape,
            color
        ));
    }
    for n in &g.nodes {
        for &i in &n.inputs {
            out.push_str(&format!("  n{} -> n{};\n", i, n.id));
        }
    }
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn dot_contains_nodes_and_edges() {
        let mut b = GraphBuilder::new("d", 4);
        let x = b.input([1, 4, 4, 3]);
        let c = b.conv2d(x, 8, 3, 1);
        b.relu(c);
        let g = b.finish();
        let dot = to_dot(&g, None);
        assert!(dot.contains("digraph"));
        assert!(dot.contains("n0 -> n1"));
        assert!(dot.contains("n1 -> n2"));
        assert!(dot.contains("CONV_2D"));
    }

    #[test]
    fn partition_colors_differ() {
        let mut b = GraphBuilder::new("d", 4);
        let x = b.input([1, 4, 4, 3]);
        b.relu(x);
        let g = b.finish();
        let dot = to_dot(&g, Some(&[0, 1]));
        assert!(dot.contains("#e6194b"));
        assert!(dot.contains("#3cb44b"));
    }
}
