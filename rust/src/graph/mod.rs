//! DNN model intermediate representation.
//!
//! A model is a directed acyclic graph of operations (paper §2.1): each
//! node is one op, each edge a tensor dependency. The analyzer
//! ([`crate::analyzer`]) partitions this DAG into processor-specific
//! subgraphs; the SoC cost model ([`crate::soc`]) prices each node from
//! the FLOPs / byte annotations computed here.

pub mod ops;
pub mod shape;
pub mod builder;
pub mod dot;

pub use builder::GraphBuilder;
pub use ops::{OpCategory, OpKind};
pub use shape::TensorShape;

/// Index of a node within its graph.
pub type NodeId = usize;

/// One operation in the model DAG.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub kind: OpKind,
    pub name: String,
    /// Producer nodes whose outputs this op consumes.
    pub inputs: Vec<NodeId>,
    /// Shape of this op's (single) output tensor.
    pub out_shape: TensorShape,
    /// Multiply-accumulate-style floating-point work, in FLOPs.
    pub flops: u64,
    /// Bytes of trained parameters attached to this op (weights, biases).
    pub param_bytes: u64,
}

impl Node {
    /// Bytes of the output activation tensor.
    pub fn out_bytes(&self, dtype_bytes: u64) -> u64 {
        self.out_shape.elements() * dtype_bytes
    }
}

/// A DNN model as a DAG of ops, stored in a topological order (builders
/// construct nodes producer-first; [`Graph::validate`] enforces it).
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Bytes per activation element (4 for f32 models, 1 for quantized).
    pub dtype_bytes: u64,
}

impl Graph {
    pub fn num_ops(&self) -> usize {
        self.nodes.len()
    }

    /// Op count excluding `Input` pseudo-nodes — the convention the paper
    /// uses when reporting model sizes (Tables 1 and 3).
    pub fn num_real_ops(&self) -> usize {
        self.nodes.iter().filter(|n| n.kind != OpKind::Input).count()
    }

    pub fn total_flops(&self) -> u64 {
        self.nodes.iter().map(|n| n.flops).sum()
    }

    pub fn total_param_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.param_bytes).sum()
    }

    /// Structural fingerprint: FNV-1a over every planning-relevant
    /// property — op kinds, edges, output shapes, FLOP/parameter
    /// annotations, and the activation dtype width. Two graphs with equal
    /// names but different fingerprints are *different models*: the plan
    /// and tuner memo tables ([`crate::sched::ModelPlan::build_cached`],
    /// `analyzer::tuner`) key on this alongside the name so a same-name
    /// structural variant can never be served a stale cached plan.
    /// Node display names are deliberately excluded — they don't affect
    /// partitioning or costs.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        mix(self.dtype_bytes);
        mix(self.nodes.len() as u64);
        for n in &self.nodes {
            mix(n.kind as u64);
            mix(n.inputs.len() as u64);
            for &i in &n.inputs {
                mix(i as u64);
            }
            mix(n.out_shape.rank as u64);
            for &d in &n.out_shape.dims {
                mix(d);
            }
            mix(n.flops);
            mix(n.param_bytes);
        }
        h
    }

    /// Consumers adjacency: for each node, which nodes read its output.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// Ops with no consumers (model outputs).
    pub fn outputs(&self) -> Vec<NodeId> {
        let cons = self.consumers();
        (0..self.nodes.len()).filter(|&i| cons[i].is_empty()).collect()
    }

    /// Census of op kinds (`Input` pseudo-ops excluded): `(kind, count)`
    /// sorted by count descending.
    pub fn census(&self) -> Vec<(OpKind, usize)> {
        let mut counts: std::collections::BTreeMap<OpKind, usize> = Default::default();
        for n in self.nodes.iter().filter(|n| n.kind != OpKind::Input) {
            *counts.entry(n.kind).or_default() += 1;
        }
        let mut v: Vec<_> = counts.into_iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v
    }

    /// Census folded into the paper's Table 1 categories, as percentages
    /// of real (non-`Input`) ops.
    pub fn category_percentages(&self) -> Vec<(OpCategory, f64)> {
        let mut counts: std::collections::BTreeMap<OpCategory, usize> = Default::default();
        for n in self.nodes.iter().filter(|n| n.kind != OpKind::Input) {
            *counts.entry(n.kind.category()).or_default() += 1;
        }
        let total = self.num_real_ops().max(1) as f64;
        counts
            .into_iter()
            .map(|(c, n)| (c, 100.0 * n as f64 / total))
            .collect()
    }

    /// Structural validation: ids match positions, inputs reference earlier
    /// nodes only (therefore the graph is acyclic and topologically sorted),
    /// and every non-first node is reachable-connected via some input.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                anyhow::bail!("node {} has id {}", i, n.id);
            }
            for &inp in &n.inputs {
                if inp >= i {
                    anyhow::bail!(
                        "node {} ('{}') depends on node {} which is not earlier in topo order",
                        i,
                        n.name,
                        inp
                    );
                }
            }
            if i > 0 && n.inputs.is_empty() && n.kind != OpKind::Input {
                anyhow::bail!("non-input node {} ('{}') has no inputs", i, n.name);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut b = GraphBuilder::new("tiny", 4);
        let x = b.input([1, 8, 8, 3]);
        let c = b.conv2d(x, 16, 3, 1);
        let r = b.relu(c);
        let d = b.depthwise_conv2d(r, 3, 1);
        let s = b.add(r, d);
        b.softmax(s);
        b.finish()
    }

    #[test]
    fn builds_and_validates() {
        let g = tiny();
        assert_eq!(g.num_ops(), 6);
        g.validate().unwrap();
        assert_eq!(g.outputs(), vec![5]);
    }

    #[test]
    fn consumers_are_inverse_of_inputs() {
        let g = tiny();
        let cons = g.consumers();
        for n in &g.nodes {
            for &i in &n.inputs {
                assert!(cons[i].contains(&n.id));
            }
        }
        // relu output feeds both the depthwise conv and the add.
        assert_eq!(cons[2].len(), 2);
    }

    #[test]
    fn census_counts_kinds() {
        let g = tiny();
        let census = g.census();
        let total: usize = census.iter().map(|(_, c)| c).sum();
        assert_eq!(total, g.num_real_ops());
        assert_eq!(g.num_real_ops(), g.num_ops() - 1); // one Input node
        assert!(census.iter().any(|(k, c)| *k == OpKind::Conv2d && *c == 1));
    }

    #[test]
    fn category_percentages_sum_to_100() {
        let g = tiny();
        let sum: f64 = g.category_percentages().iter().map(|(_, p)| p).sum();
        assert!((sum - 100.0).abs() < 1e-9);
    }

    #[test]
    fn validate_rejects_forward_edges() {
        let mut g = tiny();
        g.nodes[1].inputs = vec![3]; // points forward
        assert!(g.validate().is_err());
    }

    #[test]
    fn flops_are_positive_for_compute_ops() {
        let g = tiny();
        assert!(g.nodes[1].flops > 0); // conv
        assert!(g.total_flops() > 0);
    }

    #[test]
    fn fingerprint_tracks_structure_not_names() {
        let a = tiny();
        // Renaming the graph or its nodes changes nothing structural.
        let mut renamed = a.clone();
        renamed.name = "something_else".into();
        renamed.nodes[1].name = "renamed_op".into();
        assert_eq!(a.fingerprint(), renamed.fingerprint());
        // Any structural edit — kind, shape, flops, dtype — changes it.
        let mut kind = a.clone();
        kind.nodes[1].kind = OpKind::DepthwiseConv2d;
        assert_ne!(a.fingerprint(), kind.fingerprint());
        let mut flops = a.clone();
        flops.nodes[1].flops += 1;
        assert_ne!(a.fingerprint(), flops.fingerprint());
        let mut dtype = a.clone();
        dtype.dtype_bytes = 1;
        assert_ne!(a.fingerprint(), dtype.fingerprint());
    }
}
