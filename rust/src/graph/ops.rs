//! Operation kinds and the paper's Table 1 category folding.

/// Every op type appearing in the paper's evaluation models. The set
/// mirrors the TFLite builtin ops those models compile to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpKind {
    /// Pseudo-op marking a model input tensor.
    Input,
    Conv2d,
    /// Atrous / dilated convolution (DeepLabV3's ASPP). Folded into the
    /// paper's "DLG" Table-1 column together with `Logistic`.
    DilatedConv2d,
    DepthwiseConv2d,
    TransposeConv2d,
    FullyConnected,
    Add,
    Sub,
    Mul,
    Div,
    Relu,
    Relu6,
    /// Sigmoid / logistic activation (paper Table 1 "DLG" column).
    Logistic,
    Tanh,
    HardSwish,
    Softmax,
    MaxPool2d,
    AvgPool2d,
    Mean,
    Concat,
    Reshape,
    Squeeze,
    Pad,
    StridedSlice,
    ResizeBilinear,
    BatchNorm,
    Quantize,
    Dequantize,
    Split,
    Pack,
}

/// Paper Table 1 columns: ADD, C2D, DLG, DW, Others.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpCategory {
    Add,
    Conv2d,
    /// "DLG": dilated convs and logistic-gate activations.
    Dlg,
    DepthwiseConv,
    Others,
}

impl OpCategory {
    pub fn label(self) -> &'static str {
        match self {
            OpCategory::Add => "ADD",
            OpCategory::Conv2d => "C2D",
            OpCategory::Dlg => "DLG",
            OpCategory::DepthwiseConv => "DW",
            OpCategory::Others => "Others",
        }
    }
    pub const ALL: [OpCategory; 5] = [
        OpCategory::Add,
        OpCategory::Conv2d,
        OpCategory::Dlg,
        OpCategory::DepthwiseConv,
        OpCategory::Others,
    ];
}

impl OpKind {
    pub fn category(self) -> OpCategory {
        match self {
            OpKind::Add => OpCategory::Add,
            OpKind::Conv2d => OpCategory::Conv2d,
            OpKind::DilatedConv2d | OpKind::Logistic => OpCategory::Dlg,
            OpKind::DepthwiseConv2d => OpCategory::DepthwiseConv,
            _ => OpCategory::Others,
        }
    }

    /// Compute-bound ops (priced by FLOPs against a processor's peak);
    /// everything else is memory-bound (priced by bytes moved).
    pub fn is_compute_bound(self) -> bool {
        matches!(
            self,
            OpKind::Conv2d
                | OpKind::DilatedConv2d
                | OpKind::DepthwiseConv2d
                | OpKind::TransposeConv2d
                | OpKind::FullyConnected
        )
    }

    /// Pure data-movement / metadata ops with negligible arithmetic.
    pub fn is_layout_op(self) -> bool {
        matches!(
            self,
            OpKind::Reshape
                | OpKind::Squeeze
                | OpKind::Pad
                | OpKind::StridedSlice
                | OpKind::Concat
                | OpKind::Split
                | OpKind::Pack
        )
    }

    pub fn label(self) -> &'static str {
        match self {
            OpKind::Input => "INPUT",
            OpKind::Conv2d => "CONV_2D",
            OpKind::DilatedConv2d => "DILATED_CONV_2D",
            OpKind::DepthwiseConv2d => "DEPTHWISE_CONV_2D",
            OpKind::TransposeConv2d => "TRANSPOSE_CONV",
            OpKind::FullyConnected => "FULLY_CONNECTED",
            OpKind::Add => "ADD",
            OpKind::Sub => "SUB",
            OpKind::Mul => "MUL",
            OpKind::Div => "DIV",
            OpKind::Relu => "RELU",
            OpKind::Relu6 => "RELU6",
            OpKind::Logistic => "LOGISTIC",
            OpKind::Tanh => "TANH",
            OpKind::HardSwish => "HARD_SWISH",
            OpKind::Softmax => "SOFTMAX",
            OpKind::MaxPool2d => "MAX_POOL_2D",
            OpKind::AvgPool2d => "AVERAGE_POOL_2D",
            OpKind::Mean => "MEAN",
            OpKind::Concat => "CONCATENATION",
            OpKind::Reshape => "RESHAPE",
            OpKind::Squeeze => "SQUEEZE",
            OpKind::Pad => "PAD",
            OpKind::StridedSlice => "STRIDED_SLICE",
            OpKind::ResizeBilinear => "RESIZE_BILINEAR",
            OpKind::BatchNorm => "BATCH_NORM",
            OpKind::Quantize => "QUANTIZE",
            OpKind::Dequantize => "DEQUANTIZE",
            OpKind::Split => "SPLIT",
            OpKind::Pack => "PACK",
        }
    }

    /// All kinds, for support-table construction and property generators.
    pub const ALL: [OpKind; 30] = [
        OpKind::Input,
        OpKind::Conv2d,
        OpKind::DilatedConv2d,
        OpKind::DepthwiseConv2d,
        OpKind::TransposeConv2d,
        OpKind::FullyConnected,
        OpKind::Add,
        OpKind::Sub,
        OpKind::Mul,
        OpKind::Div,
        OpKind::Relu,
        OpKind::Relu6,
        OpKind::Logistic,
        OpKind::Tanh,
        OpKind::HardSwish,
        OpKind::Softmax,
        OpKind::MaxPool2d,
        OpKind::AvgPool2d,
        OpKind::Mean,
        OpKind::Concat,
        OpKind::Reshape,
        OpKind::Squeeze,
        OpKind::Pad,
        OpKind::StridedSlice,
        OpKind::ResizeBilinear,
        OpKind::BatchNorm,
        OpKind::Quantize,
        OpKind::Dequantize,
        OpKind::Split,
        OpKind::Pack,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn categories_match_paper_columns() {
        assert_eq!(OpKind::Add.category(), OpCategory::Add);
        assert_eq!(OpKind::Conv2d.category(), OpCategory::Conv2d);
        assert_eq!(OpKind::DepthwiseConv2d.category(), OpCategory::DepthwiseConv);
        assert_eq!(OpKind::Logistic.category(), OpCategory::Dlg);
        assert_eq!(OpKind::DilatedConv2d.category(), OpCategory::Dlg);
        assert_eq!(OpKind::Softmax.category(), OpCategory::Others);
    }

    #[test]
    fn all_list_is_unique_and_complete_for_labels() {
        let mut labels: Vec<&str> = OpKind::ALL.iter().map(|k| k.label()).collect();
        let n = labels.len();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), n, "duplicate labels in OpKind::ALL");
    }

    #[test]
    fn compute_bound_ops_are_convs_and_fc() {
        assert!(OpKind::Conv2d.is_compute_bound());
        assert!(OpKind::FullyConnected.is_compute_bound());
        assert!(!OpKind::Add.is_compute_bound());
        assert!(!OpKind::Reshape.is_compute_bound());
    }
}
