//! Tensor shapes (NHWC, the TFLite convention) and arithmetic-cost helpers.

/// A tensor shape of up to 4 dimensions, NHWC for feature maps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub dims: [u64; 4],
    pub rank: usize,
}

impl TensorShape {
    pub fn new(dims: &[u64]) -> Self {
        assert!(!dims.is_empty() && dims.len() <= 4, "rank must be 1..=4");
        let mut d = [1u64; 4];
        d[..dims.len()].copy_from_slice(dims);
        TensorShape { dims: d, rank: dims.len() }
    }

    pub fn nhwc(n: u64, h: u64, w: u64, c: u64) -> Self {
        Self::new(&[n, h, w, c])
    }

    pub fn elements(&self) -> u64 {
        self.dims[..self.rank].iter().product()
    }

    pub fn n(&self) -> u64 {
        self.dims[0]
    }
    pub fn h(&self) -> u64 {
        self.dims[1]
    }
    pub fn w(&self) -> u64 {
        self.dims[2]
    }
    pub fn c(&self) -> u64 {
        self.dims[self.rank - 1]
    }

    /// Output spatial size for a strided, SAME-padded convolution/pool.
    pub fn conv_out(&self, stride: u64) -> (u64, u64) {
        assert!(stride >= 1);
        ((self.h() + stride - 1) / stride, (self.w() + stride - 1) / stride)
    }
}

impl std::fmt::Display for TensorShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> =
            self.dims[..self.rank].iter().map(|d| d.to_string()).collect();
        write!(f, "[{}]", parts.join("x"))
    }
}

/// FLOPs for a standard convolution (2 × MACs).
pub fn conv2d_flops(out_h: u64, out_w: u64, c_in: u64, c_out: u64, k: u64) -> u64 {
    2 * out_h * out_w * c_out * c_in * k * k
}

/// FLOPs for a depthwise convolution.
pub fn depthwise_flops(out_h: u64, out_w: u64, c: u64, k: u64) -> u64 {
    2 * out_h * out_w * c * k * k
}

/// FLOPs for a fully connected layer.
pub fn fc_flops(c_in: u64, c_out: u64) -> u64 {
    2 * c_in * c_out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_basics() {
        let s = TensorShape::nhwc(1, 224, 224, 3);
        assert_eq!(s.elements(), 224 * 224 * 3);
        assert_eq!(s.c(), 3);
        assert_eq!(s.to_string(), "[1x224x224x3]");
        let v = TensorShape::new(&[1, 1000]);
        assert_eq!(v.c(), 1000);
        assert_eq!(v.elements(), 1000);
    }

    #[test]
    fn same_padding_out_size() {
        let s = TensorShape::nhwc(1, 224, 224, 3);
        assert_eq!(s.conv_out(2), (112, 112));
        assert_eq!(s.conv_out(1), (224, 224));
        let odd = TensorShape::nhwc(1, 7, 7, 3);
        assert_eq!(odd.conv_out(2), (4, 4));
    }

    #[test]
    fn flop_formulas() {
        // 1x1 conv on 112x112x32 -> 64 channels: 2*112*112*64*32
        assert_eq!(conv2d_flops(112, 112, 32, 64, 1), 2 * 112 * 112 * 64 * 32);
        assert_eq!(depthwise_flops(112, 112, 32, 3), 2 * 112 * 112 * 32 * 9);
        assert_eq!(fc_flops(1024, 1000), 2 * 1024 * 1000);
    }

    #[test]
    #[should_panic]
    fn rank_zero_rejected() {
        TensorShape::new(&[]);
    }
}
