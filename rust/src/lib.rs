//! # ADMS — Advanced Multi-DNN Model Scheduling
//!
//! A reproduction of *"Optimizing Multi-DNN Inference on Mobile Devices
//! through Heterogeneous Processor Co-Execution"* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas serving framework.
//!
//! The paper's contribution — window-size-bounded subgraph partitioning
//! ([`analyzer`]), a processor-state-aware multi-factor scheduler
//! ([`sched`]), and a real-time hardware monitor ([`monitor`]) — lives in
//! this crate (Layer 3), together with every substrate the evaluation
//! depends on:
//!
//! * [`graph`] / [`zoo`] — a DNN DAG IR and builders for the paper's 13
//!   evaluation models (op censuses match the paper's Tables 1 and 3);
//! * [`soc`] / [`thermal`] / [`power`] — a calibrated heterogeneous
//!   mobile-SoC simulator (Dimensity 9000, Kirin 970, Snapdragon 835)
//!   with DVFS ladders, lumped-RC thermal dynamics, and power accounting;
//! * [`sim`] — a discrete-event engine that drives the schedulers against
//!   the SoC model and records execution timelines;
//! * [`coordinator`] / [`runtime`] — a wall-clock serving runtime that
//!   executes AOT-compiled HLO artifacts (Layer 2 JAX models built from
//!   Layer 1 Pallas kernels) through PJRT, with Python never on the
//!   request path;
//! * [`experiments`] — regenerators for every table and figure in the
//!   paper's evaluation section.
//!
//! See `DESIGN.md` for the full system inventory and the hardware
//! substitution rationale, and `EXPERIMENTS.md` for paper-vs-measured
//! results.

pub mod util;
pub mod testing;
pub mod graph;
pub mod zoo;
pub mod soc;
pub mod thermal;
pub mod power;
pub mod sim;
pub mod monitor;
pub mod analyzer;
pub mod sched;
pub mod workload;
pub mod metrics;
pub mod coordinator;
pub mod runtime;
pub mod experiments;

/// Simulation time in milliseconds. All latency figures in the paper are
/// reported in ms; keeping one unit end-to-end avoids conversion bugs.
pub type TimeMs = f64;
