//! # ADMS — Advanced Multi-DNN Model Scheduling
//!
//! A reproduction of *"Optimizing Multi-DNN Inference on Mobile Devices
//! through Heterogeneous Processor Co-Execution"* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas serving framework.
//!
//! The paper's contribution — window-size-bounded subgraph partitioning
//! ([`analyzer`]), a processor-state-aware multi-factor scheduler
//! ([`sched`]), and a real-time hardware monitor ([`monitor`]) — lives in
//! this crate (Layer 3), together with every substrate the evaluation
//! depends on:
//!
//! * [`graph`] / [`zoo`] — a DNN DAG IR and builders for the paper's 13
//!   evaluation models (op censuses match the paper's Tables 1 and 3);
//! * [`soc`] / [`thermal`] / [`power`] — a calibrated heterogeneous
//!   mobile-SoC simulator (Dimensity 9000, Kirin 970, Snapdragon 835)
//!   with DVFS ladders, lumped-RC thermal dynamics, and power accounting;
//! * [`weights`] — model weights as a scheduled resource: per-model
//!   shard manifests aligned with unit subgraphs, and the per-processor
//!   residency cache (cold-load pricing, cost-aware eviction) behind
//!   `--mem-budget`;
//! * [`exec`] — the backend-agnostic execution core: the shared
//!   scheduler-driven dispatch loop ([`exec::Driver`]), the
//!   [`exec::ExecutionBackend`] contract, its two substrates
//!   ([`exec::SimBackend`] — the calibrated discrete-event SoC model —
//!   and [`exec::ThreadPoolBackend`] — wall-clock serving on a worker
//!   pool), and the [`exec::Server`] builder that fronts them;
//! * [`sim`] — the evaluation entry point over the sim backend, plus the
//!   shared report types (timelines, per-session/processor statistics);
//! * [`scenario`] — the open-system workload layer: timed session
//!   churn/burst/phase scenarios (JSON-serializable, seed-generatable)
//!   and run-trace record/replay;
//! * [`fleet`] — fleet-scale sharded simulation: N independent devices
//!   (SoC × scheduler × workload arms, per-device seeds derived from one
//!   fleet seed) across worker threads, merged into a deterministic
//!   [`fleet::FleetReport`] of mergeable digests;
//! * [`coordinator`] / [`runtime`] — the AOT-artifact path: HLO stages
//!   compiled through PJRT (behind the `pjrt` feature) and the legacy
//!   probe-serving coordinator, with Python never on the request path;
//! * [`experiments`] — regenerators for every table and figure in the
//!   paper's evaluation section.
//!
//! See `DESIGN.md` for the full system inventory, the execution-backend
//! architecture, and the hardware substitution rationale.

pub mod util;
pub mod testing;
pub mod graph;
pub mod zoo;
pub mod soc;
pub mod thermal;
pub mod power;
pub mod monitor;
pub mod analyzer;
pub mod faults;
pub mod sched;
pub mod weights;
pub mod exec;
pub mod sim;
pub mod scenario;
pub mod workload;
pub mod fleet;
pub mod metrics;
pub mod coordinator;
pub mod runtime;
pub mod experiments;

/// Simulation time in milliseconds. All latency figures in the paper are
/// reported in ms; keeping one unit end-to-end avoids conversion bugs.
pub type TimeMs = f64;
