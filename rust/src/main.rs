//! `adms` — command-line entry point.
//!
//! Subcommands:
//!   experiment <id|all>   regenerate a paper table/figure (DESIGN.md §5)
//!   partition <model>     analyze a model's subgraph partition
//!   tune <model>          sweep window sizes and report the optimum
//!   simulate              run a custom workload under a scheduler
//!   serve                 scheduler-driven serving (exec::Server): pick a
//!                         --sched and --workload or a dynamic --scenario,
//!                         run wall-clock on the thread pool or on the sim
//!                         backend; --record/--replay capture and re-run
//!                         traces; --probe keeps the legacy AOT
//!                         numerics-probe path (PJRT)
//!   scenario              list/show/generate dynamic scenarios
//!   fleet                 simulate a population of devices — (SoC ×
//!                         scheduler × workload) arms sharded across
//!                         worker threads, merged into one FleetReport
//!   tournament            scheduler tournament: every scheduler × SoC ×
//!                         scenario cell as a fleet arm, one sorted,
//!                         mergeable table written to TOURNAMENT.json
//!   bench                 run the simulator throughput suite and write
//!                         BENCH_sim.json (the tracked perf trajectory)
//!   models | socs         list the zoo (with weight/activation
//!                         footprints; --model for per-unit shards) /
//!                         the SoC presets

use adms::analyzer;
use adms::experiments;
use adms::sim::{App, SimConfig};
use adms::soc::{soc_by_name, SOC_NAMES};
use adms::util::cli::{parse, render_help, OptSpec};
use adms::util::table::fnum;
use adms::zoo;
use anyhow::{bail, Result};

fn main() {
    env_logger_lite();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn env_logger_lite() {
    // Minimal logger so `log::warn!` in the runtime is visible.
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::Level::Info
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let _ = log::set_logger(&LOGGER).map(|_| log::set_max_level(log::LevelFilter::Info));
}

const USAGE: &str =
    "adms <experiment|partition|tune|simulate|serve|scenario|fleet|tournament|bench|models|socs> [options]";

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().map(String::as_str) else {
        println!("{USAGE}");
        println!("\nexperiments: {}", experiments::EXPERIMENTS.join(", "));
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd {
        "experiment" => cmd_experiment(rest),
        "partition" => cmd_partition(rest),
        "tune" => cmd_tune(rest),
        "simulate" => cmd_simulate(rest),
        "serve" => cmd_serve(rest),
        "scenario" => cmd_scenario(rest),
        "fleet" => cmd_fleet(rest),
        "tournament" => cmd_tournament(rest),
        "bench" => cmd_bench(rest),
        "models" => cmd_models(rest),
        "socs" => {
            for s in SOC_NAMES {
                let soc = soc_by_name(s).unwrap();
                println!("{s:15} {} — {} processors", soc.device, soc.num_processors());
                for p in &soc.processors {
                    println!(
                        "  {:4} {:22} {:7.1} GFLOPS  {:5.1} GB/s  {} DVFS states",
                        p.kind.label(),
                        p.name,
                        p.peak_gflops,
                        p.mem_bw_gbps,
                        p.freqs_mhz.len()
                    );
                }
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\nusage: {USAGE}"),
    }
}

/// `adms models`: the zoo listing, footprint-aware. The summary table
/// partitions every model on `--soc` at `--ws` and reports its shard
/// manifest totals; `--model` prints the per-unit shard table (weight and
/// peak-activation bytes per unit — the numbers `--mem-budget` schedules
/// against).
fn cmd_models(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "model", takes_value: true, help: "print the per-unit shard table for one model", default: None },
        OptSpec { name: "soc", takes_value: true, help: "SoC whose partition defines the units", default: Some("dimensity9000") },
        OptSpec { name: "ws", takes_value: true, help: "partition window size", default: Some("1") },
        OptSpec { name: "plan-set", takes_value: false, help: "with --model: print the adaptive granularity ladder (one row per plan variant)", default: None },
        OptSpec { name: "help", takes_value: false, help: "show help", default: None },
    ];
    let args = parse(argv, &specs)?;
    if args.flag("help") {
        println!(
            "{}",
            render_help("adms models [--model NAME [--plan-set]] [--soc SOC] [--ws N]", &specs)
        );
        println!("models: {}", zoo::MODEL_NAMES.join(", "));
        return Ok(());
    }
    let soc_name = args.get_or("soc", "dimensity9000");
    let soc =
        soc_by_name(&soc_name).ok_or_else(|| anyhow::anyhow!("unknown soc '{soc_name}'"))?;
    let ws = args.get_usize("ws", 1)?.max(1);
    const MIB: f64 = (1u64 << 20) as f64;
    if let Some(name) = args.get("model") {
        let g = zoo::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (`adms models` lists them)"))?;
        if args.flag("plan-set") {
            // The granularity ladder the adaptive controller switches
            // over: one row per variant, with the totals a switch trades
            // off (unit count vs. estimated single-request chain latency).
            let ladder = analyzer::tune_plan_set(&g, &soc, 12);
            println!(
                "{} — plan set on {soc_name}: {} variant(s), window sizes {:?}",
                zoo::display_name(name),
                ladder.len(),
                ladder
            );
            println!(
                "{:>6} {:>5} {:>11} {:>12} {:>18}",
                "window", "units", "weights MiB", "est chain ms", "manifest fp"
            );
            for &w in &ladder {
                let p = analyzer::partition(&g, &soc, w);
                let m = adms::weights::ShardManifest::build(&g, &p);
                println!(
                    "{:>6} {:>5} {:>11.2} {:>12} {:>18}",
                    w,
                    p.units.len(),
                    m.total_weight_bytes() as f64 / MIB,
                    fnum(analyzer::estimate_chain_latency_ms(&g, &soc, &p), 2),
                    format!("{:016x}", m.fingerprint)
                );
            }
            return Ok(());
        }
        let m = adms::weights::ShardManifest::build(&g, &analyzer::partition(&g, &soc, ws));
        println!(
            "{} — {} unit(s) at window {ws} on {soc_name}, manifest fingerprint {:016x}",
            zoo::display_name(name),
            m.shards.len(),
            m.fingerprint
        );
        println!("{:>5} {:>5} {:>12} {:>13}", "unit", "ops", "weights MiB", "peak act MiB");
        for sh in &m.shards {
            println!(
                "{:>5} {:>5} {:>12.2} {:>13.2}",
                sh.unit,
                sh.ops,
                sh.weight_bytes as f64 / MIB,
                sh.activation_bytes as f64 / MIB
            );
        }
        println!(
            "{:>5} {:>5} {:>12.2} {:>13.2}",
            "all",
            m.shards.iter().map(|sh| sh.ops).sum::<usize>(),
            m.total_weight_bytes() as f64 / MIB,
            m.peak_activation_bytes() as f64 / MIB
        );
    } else {
        println!(
            "{:18} {:22} {:>4} {:>8} {:>5} {:>11} {:>13}",
            "model", "display", "ops", "GFLOPs", "units", "weights MiB", "peak act MiB"
        );
        for name in zoo::MODEL_NAMES {
            let g = zoo::by_name(name).unwrap();
            let m = adms::weights::ShardManifest::build(&g, &analyzer::partition(&g, &soc, ws));
            println!(
                "{name:18} {:22} {:>4} {:>8.2} {:>5} {:>11.2} {:>13.2}",
                zoo::display_name(name),
                g.num_real_ops(),
                g.total_flops() as f64 / 1e9,
                m.shards.len(),
                m.total_weight_bytes() as f64 / MIB,
                m.peak_activation_bytes() as f64 / MIB
            );
        }
    }
    Ok(())
}

/// Parse a `--mem-budget` value: `0`/`off` disables residency modeling,
/// `spec` uses each processor's `weight_mem_bytes` from the SoC preset,
/// and a number with an optional K/M/G suffix (KiB/MiB/GiB) is a uniform
/// per-processor byte budget.
fn parse_mem_budget(s: &str) -> Result<u64> {
    let t = s.trim();
    if t.eq_ignore_ascii_case("off") {
        return Ok(0);
    }
    if t.eq_ignore_ascii_case("spec") {
        return Ok(adms::weights::SPEC_BUDGET);
    }
    let (digits, mult) = match t.as_bytes().last() {
        Some(&b'k') | Some(&b'K') => (&t[..t.len() - 1], 1u64 << 10),
        Some(&b'm') | Some(&b'M') => (&t[..t.len() - 1], 1u64 << 20),
        Some(&b'g') | Some(&b'G') => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t, 1),
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("--mem-budget: expected BYTES[K|M|G], 'spec', or 'off', got '{s}'"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("--mem-budget '{s}' overflows u64"))
}

fn parse_mem_policy(s: &str) -> Result<adms::weights::MemPolicy> {
    adms::weights::MemPolicy::parse(s)
        .ok_or_else(|| anyhow::anyhow!("--mem-policy: expected 'cost' or 'lru', got '{s}'"))
}

/// Parse a `--fault-profile` value in the `faults::FaultProfile` grammar:
/// a named profile (`off` | `light` | `heavy`) or a
/// `crash=R,hang=R,transient=R,mttr=MS` spec (rates in events/s).
fn parse_fault_profile(s: &str) -> Result<adms::faults::FaultProfile> {
    adms::faults::FaultProfile::parse(s).ok_or_else(|| {
        anyhow::anyhow!(
            "--fault-profile: expected off|light|heavy or \
             crash=R,hang=R,transient=R,mttr=MS, got '{s}'"
        )
    })
}

/// Parse `--base` for the `lookahead` scheduler: any of the four bare
/// policies (the `tflite` alias for vanilla included).
fn parse_base(s: &str) -> Result<adms::sched::BasePolicy> {
    adms::sched::BasePolicy::parse(s)
        .ok_or_else(|| anyhow::anyhow!("--base: expected vanilla|band|adms|pinned, got '{s}'"))
}

fn parse_adaptive(s: &str) -> Result<adms::exec::AdaptivePlan> {
    adms::exec::AdaptivePlan::parse(s)
        .ok_or_else(|| anyhow::anyhow!("--adaptive-plan: expected off|reactive, got '{s}'"))
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "quick", takes_value: false, help: "compressed durations (CI)", default: None },
        OptSpec { name: "help", takes_value: false, help: "show help", default: None },
    ];
    let args = parse(argv, &specs)?;
    if args.flag("help") || args.positional.is_empty() {
        println!("{}", render_help("adms experiment <id|all> [--quick]", &specs));
        println!("ids: {}", experiments::EXPERIMENTS.join(", "));
        return Ok(());
    }
    let quick = args.flag("quick");
    let id = args.positional[0].as_str();
    if id == "all" {
        for id in experiments::EXPERIMENTS {
            println!("{}", experiments::run(id, quick)?);
        }
    } else {
        println!("{}", experiments::run(id, quick)?);
    }
    Ok(())
}

fn cmd_partition(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "soc", takes_value: true, help: "target SoC", default: Some("dimensity9000") },
        OptSpec { name: "ws", takes_value: true, help: "window size", default: Some("1") },
        OptSpec { name: "dot", takes_value: false, help: "emit graphviz DOT", default: None },
    ];
    let args = parse(argv, &specs)?;
    let Some(model) = args.positional.first() else {
        bail!("usage: adms partition <model> [--soc S] [--ws N] [--dot]");
    };
    let g = zoo::by_name(model).ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))?;
    let soc = soc_by_name(&args.get_or("soc", "dimensity9000"))
        .ok_or_else(|| anyhow::anyhow!("unknown soc"))?;
    let ws = args.get_usize("ws", 1)?;
    let p = analyzer::partition(&g, &soc, ws);
    if args.flag("dot") {
        let mut colors = vec![0usize; g.num_ops()];
        for (ui, u) in p.units.iter().enumerate() {
            for &op in &u.ops {
                colors[op] = ui;
            }
        }
        println!("{}", adms::graph::dot::to_dot(&g, Some(&colors)));
        return Ok(());
    }
    println!(
        "{model} on {} at ws={ws}: {} ops, {} units, {} merged candidates, {} total",
        soc.device,
        g.num_real_ops(),
        p.units.len(),
        p.merged_candidates,
        p.total_subgraphs
    );
    for (i, u) in p.units.iter().enumerate() {
        let procs: Vec<&str> =
            u.support.iter().map(|&q| soc.processors[q].kind.label()).collect();
        println!(
            "  unit {i:3}: ops {:3}..{:3} ({:3})  [{}]",
            u.ops.first().unwrap(),
            u.ops.last().unwrap(),
            u.len(),
            procs.join(",")
        );
    }
    Ok(())
}

fn cmd_tune(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "soc", takes_value: true, help: "target SoC", default: Some("dimensity9000") },
        OptSpec { name: "max-ws", takes_value: true, help: "max window size", default: Some("12") },
    ];
    let args = parse(argv, &specs)?;
    let Some(model) = args.positional.first() else {
        bail!("usage: adms tune <model> [--soc S] [--max-ws N]");
    };
    let g = zoo::by_name(model).ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))?;
    let soc = soc_by_name(&args.get_or("soc", "dimensity9000"))
        .ok_or_else(|| anyhow::anyhow!("unknown soc"))?;
    let (best, sweep) = analyzer::tune_window_size(&g, &soc, args.get_usize("max-ws", 12)?);
    println!("ws  units  merged  total  est_ms");
    for p in sweep {
        let mark = if p.window_size == best { " <- optimal" } else { "" };
        println!(
            "{:2}  {:5}  {:6}  {:5}  {}{}",
            p.window_size,
            p.units,
            p.merged,
            p.total,
            fnum(p.est_latency_ms, 2),
            mark
        );
    }
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    use adms::experiments::common::{run_framework, Framework};
    let specs = [
        OptSpec { name: "soc", takes_value: true, help: "target SoC", default: Some("dimensity9000") },
        OptSpec { name: "scheduler", takes_value: true, help: "tflite|band|adms", default: Some("adms") },
        OptSpec { name: "models", takes_value: true, help: "comma-separated zoo models", default: Some("retinaface,arcface_mobile,arcface_resnet50") },
        OptSpec { name: "duration", takes_value: true, help: "simulated ms", default: Some("10000") },
        OptSpec { name: "seed", takes_value: true, help: "rng seed", default: Some("42") },
    ];
    let args = parse(argv, &specs)?;
    let soc = soc_by_name(&args.get_or("soc", "dimensity9000"))
        .ok_or_else(|| anyhow::anyhow!("unknown soc"))?;
    let fw = match args.get_or("scheduler", "adms").as_str() {
        "tflite" | "vanilla" => Framework::Tflite,
        "band" => Framework::Band,
        "adms" => Framework::Adms,
        other => bail!("unknown scheduler '{other}'"),
    };
    let mut apps: Vec<App> = Vec::new();
    for m in args.get_or("models", "").split(',').filter(|s| !s.is_empty()) {
        if zoo::by_name(m).is_none() {
            bail!("unknown model '{m}'");
        }
        apps.push(App::closed_loop(m));
    }
    let cfg = SimConfig {
        duration_ms: args.get_f64("duration", 10_000.0)?,
        seed: args.get_u64("seed", 42)?,
        ..Default::default()
    };
    let report = run_framework(&soc, fw, apps, cfg);
    let refs = [&report];
    println!("{}", adms::metrics::fps_table("Simulation", &refs).render());
    println!("{}", adms::metrics::comparison_table("Summary", &refs).render());
    for p in &report.procs {
        println!(
            "{:22} busy {:5.1}%  dispatches {:6}  max temp {:5.1} °C  throttles {}",
            p.name,
            100.0 * p.busy_frac,
            p.dispatches,
            p.temp.max(),
            p.throttle_events
        );
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    use adms::exec::Server;
    use adms::scenario::RunTrace;
    let specs = [
        OptSpec { name: "sched", takes_value: true, help: "vanilla|band|adms|pinned|lookahead", default: Some("adms") },
        OptSpec { name: "workload", takes_value: true, help: "frs|ros|stress[:n]|copies:<model>[:n]|slo[:mult] or comma-separated zoo models", default: Some("frs") },
        OptSpec { name: "scenario", takes_value: true, help: "dynamic scenario: a name (adms scenario list) or a JSON file; overrides --workload/--slo", default: None },
        OptSpec { name: "record", takes_value: true, help: "write the run trace (arrivals + dispatches) to this JSON file", default: None },
        OptSpec { name: "replay", takes_value: true, help: "re-run a recorded trace file (uses the trace's scheduler, seed, backend, horizon)", default: None },
        OptSpec { name: "backend", takes_value: true, help: "threadpool (wall-clock) | sim", default: Some("threadpool") },
        OptSpec { name: "soc", takes_value: true, help: "target SoC", default: Some("dimensity9000") },
        OptSpec { name: "requests", takes_value: true, help: "requests per session; 0 = unbounded (default 64 for --workload, unbounded for --scenario so churn plays out to --duration)", default: None },
        OptSpec { name: "duration", takes_value: true, help: "horizon, ms", default: Some("60000") },
        OptSpec { name: "slo", takes_value: true, help: "per-request SLO in ms (all sessions)", default: None },
        OptSpec { name: "batch-max", takes_value: true, help: "largest task group one dispatch may fuse (1 = batching off)", default: Some("1") },
        OptSpec { name: "batch-window", takes_value: true, help: "coalescing window in ms: how long a batchable task may wait for peers", default: Some("0") },
        OptSpec { name: "mem-budget", takes_value: true, help: "per-processor weight-residency budget: BYTES[K|M|G], 'spec' (SoC preset budgets), or 'off' (0 = residency modeling disabled)", default: Some("0") },
        OptSpec { name: "mem-policy", takes_value: true, help: "weight-cache eviction policy: cost (GreedyDual-Size) | lru", default: Some("cost") },
        OptSpec { name: "horizon", takes_value: true, help: "lookahead: completions each forked rollout observes before scoring (0 = rollouts off; lookahead degenerates to --base)", default: Some("2") },
        OptSpec { name: "beam", takes_value: true, help: "lookahead: candidate processors evaluated per decision (1 likewise degenerates)", default: Some("3") },
        OptSpec { name: "base", takes_value: true, help: "lookahead: base policy to refine (vanilla|band|adms|pinned)", default: Some("adms") },
        OptSpec { name: "pace", takes_value: true, help: "synthetic payload pace multiplier", default: Some("1") },
        OptSpec { name: "seed", takes_value: true, help: "rng seed", default: Some("42") },
        OptSpec { name: "dispatch-timeout", takes_value: true, help: "declare a dispatch lost after this multiple of its predicted latency (0 = detection off)", default: Some("0") },
        OptSpec { name: "retry-limit", takes_value: true, help: "per-request retry budget for fault-aborted work", default: Some("3") },
        OptSpec { name: "retry-backoff", takes_value: true, help: "base retry backoff in ms, doubled per attempt", default: Some("25") },
        OptSpec { name: "quarantine", takes_value: true, help: "ms a recovered processor stays Degraded (re-priced) before being trusted Up", default: Some("500") },
        OptSpec { name: "fault-profile", takes_value: true, help: "seeded fault injection: off|light|heavy or crash=R,hang=R,transient=R,mttr=MS (rates in events/s)", default: None },
        OptSpec { name: "fault-seed", takes_value: true, help: "dedicated fault-plan seed (default: --seed), so fault timing varies while arrivals stay fixed", default: None },
        OptSpec { name: "fault-blind", takes_value: false, help: "ablation: faults still happen but the driver neither marks health nor retries", default: None },
        OptSpec { name: "ws", takes_value: true, help: "freeze the partition window size for every session (default: per-policy tuned)", default: None },
        OptSpec { name: "adaptive-plan", takes_value: true, help: "runtime granularity switching: off | reactive (per-model plan-set, re-partitioned at safe boundaries under pressure)", default: Some("off") },
        OptSpec { name: "replan-cooldown", takes_value: true, help: "adaptive: min ms between granularity switches of one session", default: Some("1000") },
        OptSpec { name: "replan-threshold", takes_value: true, help: "adaptive: smoothed pressure above which the controller refines (coarsens below half of it)", default: Some("0.5") },
        OptSpec { name: "probe", takes_value: false, help: "legacy: serve the AOT numerics probe (PJRT)", default: None },
        OptSpec { name: "workers", takes_value: true, help: "probe mode: worker threads", default: Some("2") },
        OptSpec { name: "no-verify", takes_value: false, help: "probe mode: skip logits verification", default: None },
        OptSpec { name: "help", takes_value: false, help: "show help", default: None },
    ];
    let args = parse(argv, &specs)?;
    if args.flag("help") {
        println!("{}", render_help("adms serve [options]", &specs));
        println!("named scenarios: {}", adms::scenario::SCENARIO_NAMES.join(", "));
        return Ok(());
    }
    if args.flag("probe") {
        return serve_probe_legacy(&args);
    }

    let soc_name = args.get_or("soc", "dimensity9000");
    let soc = soc_by_name(&soc_name).ok_or_else(|| anyhow::anyhow!("unknown soc"))?;
    let seed = args.get_u64("seed", 42)?;
    let pace = args.get_f64("pace", 1.0)?;

    // Replay path: the trace dictates workload, scheduler, seed, SoC,
    // and backend.
    if let Some(path) = args.get("replay") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--replay '{path}': {e}"))?;
        let trace = RunTrace::from_json_str(&text)?;
        let soc = soc_by_name(&trace.soc)
            .ok_or_else(|| anyhow::anyhow!("trace references unknown soc '{}'", trace.soc))?;
        let sc = trace.to_replay_scenario();
        let (apps, events) = sc.compile()?;
        // The trace's batch config is run-defining: a batched recording
        // replayed unbatched would legitimately diverge. Same for the
        // fault layer: scenario-driven faults replay as recorded events,
        // and a recorded profile re-derives its plan from the recorded
        // knobs (same profile, SoC, seed, duration → identical plan).
        let mut replay_cfg = SimConfig::default();
        if let Some(f) = &trace.faults {
            f.apply_to(&mut replay_cfg);
        }
        // Adaptive knobs are run-defining the same way: the controller
        // re-derives every switch deterministically from them.
        if let Some(a) = &trace.adaptive {
            a.apply_to(&mut replay_cfg);
        }
        let server = Server::new(soc)
            .scheduler_name(&trace.scheduler)
            .apps(apps.clone())
            .events(events.clone())
            .duration_ms(trace.duration_ms)
            .seed(trace.seed)
            .batch_max(trace.batch_max)
            .batch_window_ms(trace.batch_window_ms)
            .dispatch_timeout(replay_cfg.dispatch_timeout_mult)
            .retry_limit(replay_cfg.retry_limit)
            .retry_backoff_ms(replay_cfg.retry_backoff_ms)
            .fault_quarantine_ms(replay_cfg.fault_quarantine_ms)
            .fault_profile(replay_cfg.fault_profile.clone())
            .fault_seed(replay_cfg.fault_seed)
            .fault_blind(replay_cfg.fault_blind)
            .adaptive_plan(replay_cfg.adaptive_plan)
            .replan_cooldown_ms(replay_cfg.replan_cooldown_ms)
            .replan_threshold(replay_cfg.replan_threshold)
            .pace(pace);
        let report = match trace.backend.as_str() {
            "sim" => server.run_sim()?,
            "threadpool" => server.run_threadpool()?,
            other => bail!("trace records unknown backend '{other}' (sim|threadpool)"),
        };
        print_serve_report(&report);
        let verdict = if report.assignments == trace.assignments {
            "IDENTICAL to the recording"
        } else {
            "DIVERGED from the recording"
        };
        println!(
            "replayed {} arrivals, {} dispatches — assignment trace {verdict}",
            report.arrivals.len(),
            report.assignments.len()
        );
        maybe_record(
            &args,
            &trace.soc,
            &apps,
            &events,
            &report,
            trace.seed,
            (trace.batch_max, trace.batch_window_ms),
            &replay_cfg,
        )?;
        return Ok(());
    }

    // Scheduler-name validation happens in Server (exec::scheduler_by_name).
    let sched = args.get_or("sched", "adms");
    let mut events = Vec::new();
    let apps = if let Some(scn) = args.get("scenario") {
        let sc = adms::scenario::resolve(scn).map_err(|e| anyhow::anyhow!("--scenario {e}"))?;
        let (apps, ev) = sc.compile()?;
        events = ev;
        apps
    } else {
        let wl = args.get_or("workload", "frs");
        let mut apps = adms::workload::resolve(&wl, &soc)
            .map_err(|e| anyhow::anyhow!("--workload: {e}"))?;
        if let Some(slo) = args.get("slo") {
            let slo: f64 = slo
                .parse()
                .map_err(|_| anyhow::anyhow!("--slo: expected a number, got '{slo}'"))?;
            for a in &mut apps {
                a.slo_ms = Some(slo);
            }
        }
        apps
    };
    let batch_max = args.get_usize("batch-max", 1)?;
    let batch_window = args.get_f64("batch-window", 0.0)?;
    let fault_profile = match args.get("fault-profile") {
        Some(p) => Some(parse_fault_profile(p)?),
        None => None,
    };
    let fault_seed = match args.get("fault-seed") {
        Some(_) => Some(args.get_u64("fault-seed", 0)?),
        None => None,
    };
    let mut server = Server::new(soc)
        .scheduler_name(&sched)
        .apps(apps.clone())
        .events(events.clone())
        .duration_ms(args.get_f64("duration", 60_000.0)?)
        .seed(seed)
        .batch_max(batch_max)
        .batch_window_ms(batch_window)
        .mem_budget_bytes(parse_mem_budget(&args.get_or("mem-budget", "0"))?)
        .mem_policy(parse_mem_policy(&args.get_or("mem-policy", "cost"))?)
        .lookahead_horizon(args.get_u64("horizon", 2)? as u32)
        .lookahead_beam(args.get_u64("beam", 3)? as u32)
        .lookahead_base(parse_base(&args.get_or("base", "adms"))?)
        .dispatch_timeout(args.get_f64("dispatch-timeout", 0.0)?)
        .retry_limit(args.get_u64("retry-limit", 3)? as u32)
        .retry_backoff_ms(args.get_f64("retry-backoff", 25.0)?)
        .fault_quarantine_ms(args.get_f64("quarantine", 500.0)?)
        .fault_profile(fault_profile.clone())
        .fault_seed(fault_seed)
        .fault_blind(args.flag("fault-blind"))
        .adaptive_plan(parse_adaptive(&args.get_or("adaptive-plan", "off"))?)
        .replan_cooldown_ms(args.get_f64("replan-cooldown", 1000.0)?)
        .replan_threshold(args.get_f64("replan-threshold", 0.5)?)
        .pace(pace);
    if args.get("ws").is_some() {
        server = server.window_size(args.get_usize("ws", 1)?.max(1));
    }
    // Replica of the fault-layer and adaptive knobs for trace recording
    // (the server consumes its config when it runs).
    let mut fault_cfg = SimConfig::default();
    fault_cfg.dispatch_timeout_mult = args.get_f64("dispatch-timeout", 0.0)?.max(0.0);
    fault_cfg.retry_limit = args.get_u64("retry-limit", 3)? as u32;
    fault_cfg.retry_backoff_ms = args.get_f64("retry-backoff", 25.0)?.max(0.0);
    fault_cfg.fault_quarantine_ms = args.get_f64("quarantine", 500.0)?.max(0.0);
    fault_cfg.fault_profile = fault_profile;
    fault_cfg.fault_seed = fault_seed;
    fault_cfg.fault_blind = args.flag("fault-blind");
    fault_cfg.adaptive_plan = parse_adaptive(&args.get_or("adaptive-plan", "off"))?;
    fault_cfg.replan_cooldown_ms = args.get_f64("replan-cooldown", 1000.0)?.max(0.0);
    fault_cfg.replan_threshold = args.get_f64("replan-threshold", 0.5)?.clamp(0.0, 1.0);
    // Scenarios control their own lifecycle: an implicit quota would end
    // the run before the declared churn plays out, so only an explicit
    // --requests bounds them. Plain workloads keep the finite default.
    let requests = args.get_u64("requests", if args.get("scenario").is_some() { 0 } else { 64 })?;
    if requests > 0 {
        server = server.requests(requests);
    }
    let backend = args.get_or("backend", "threadpool");
    let report = match backend.as_str() {
        "threadpool" => server.run_threadpool()?,
        "sim" => server.run_sim()?,
        other => bail!("unknown backend '{other}' (threadpool|sim)"),
    };
    print_serve_report(&report);
    maybe_record(
        &args,
        &soc_name,
        &apps,
        &events,
        &report,
        seed,
        (batch_max, batch_window),
        &fault_cfg,
    )?;
    Ok(())
}

fn print_serve_report(report: &adms::sim::SimReport) {
    println!(
        "served with scheduler '{}' on backend '{}' ({} sessions)",
        report.scheduler,
        report.backend,
        report.sessions.len()
    );
    println!(
        "{:20} {:>7} {:>9} {:>7} {:>7} {:>9} {:>9} {:>9} {:>8}",
        "session", "issued", "completed", "failed", "cancel", "p50 ms", "p95 ms", "mean ms",
        "SLO %"
    );
    for s in &report.sessions {
        // '~' marks reservoir-subsampled percentiles (see Summary docs):
        // past 65 536 completions p50/p95 are estimates, and pretending
        // otherwise on million-request runs would be dishonest.
        let approx = if s.latency.is_subsampled() { "~" } else { "" };
        println!(
            "{:20} {:>7} {:>9} {:>7} {:>7} {:>9} {:>9} {:>9} {:>8}",
            s.model,
            s.issued,
            s.completed,
            s.failed,
            s.cancelled,
            format!("{approx}{}", fnum(s.latency.p50(), 2)),
            format!("{approx}{}", fnum(s.latency.p95(), 2)),
            fnum(s.latency.mean(), 2),
            s.slo_satisfaction
                .map(|v| fnum(v * 100.0, 1))
                .unwrap_or_else(|| "-".into())
        );
    }
    println!(
        "total: {} issued, {} completed, {} failed, {} cancelled, {} exec errors, \
         {} dispatches traced, {} driver events",
        report.total_issued(),
        report.total_completed(),
        report.total_failed(),
        report.total_cancelled(),
        report.exec_errors,
        report.assignments.len(),
        report.events
    );
    if let Some(f) = &report.faults {
        let retries: u64 = report.sessions.iter().map(|s| s.retries).sum();
        let faulted: u64 = report.sessions.iter().map(|s| s.faulted).sum();
        let exhausted: u64 = report.sessions.iter().map(|s| s.retries_exhausted).sum();
        println!(
            "faults: {} proc fails / {} recovers / {} dispatch timeouts; \
             {} retries, {} requests faulted, {} retries exhausted",
            f.proc_fails, f.proc_recovers, f.timeouts, retries, faulted, exhausted
        );
    }
    if let Some(r) = &report.replans {
        println!(
            "replans: {} granularity switch(es) ({} finer, {} coarser)",
            r.replans, r.finer, r.coarser
        );
        for &(at, s, ws) in &r.events {
            println!("  t={:.0} ms  session {s} -> window {ws}", at);
        }
    }
    if report.latency_subsampled() {
        println!(
            "note: '~' percentiles are reservoir estimates (> 65536 samples per session)"
        );
    }
    for p in &report.procs {
        if p.cold_loads > 0 {
            println!(
                "  {:22} busy {:5.1}%  dispatches {:6}  cold loads {:4}",
                p.name,
                100.0 * p.busy_frac,
                p.dispatches,
                p.cold_loads
            );
        } else {
            println!(
                "  {:22} busy {:5.1}%  dispatches {:6}",
                p.name,
                100.0 * p.busy_frac,
                p.dispatches
            );
        }
    }
    let c = &report.cache;
    if c.hits + c.misses > 0 {
        println!(
            "weights: {} hits / {} misses / {} evictions, {:.1} MiB cold-loaded \
             ({:.0} ms stall), {:.1} MiB resident at end",
            c.hits,
            c.misses,
            c.evictions,
            c.bytes_loaded as f64 / (1u64 << 20) as f64,
            c.cold_load_ms,
            c.bytes_resident as f64 / (1u64 << 20) as f64,
        );
    }
}

/// Honor `--record <file>`: persist the run trace for later `--replay`.
/// `batch` is the (batch_max, batch_window_ms) the run executed under —
/// stamped into the trace so a batched recording replays batched — and
/// `fault_cfg` carries the fault-layer knobs the same way.
fn maybe_record(
    args: &adms::util::cli::Args,
    soc_name: &str,
    apps: &[App],
    events: &[adms::exec::SessionEvent],
    report: &adms::sim::SimReport,
    seed: u64,
    batch: (usize, f64),
    fault_cfg: &SimConfig,
) -> Result<()> {
    if let Some(path) = args.get("record") {
        let trace = adms::scenario::RunTrace::record(soc_name, apps, events, report, seed)
            .with_batch(batch.0, batch.1)
            .with_faults(fault_cfg)
            .with_adaptive(fault_cfg, report);
        std::fs::write(path, trace.to_json_string())
            .map_err(|e| anyhow::anyhow!("--record '{path}': {e}"))?;
        println!(
            "recorded {} arrivals + {} dispatches to {path} (re-run: adms serve --replay {path})",
            trace.arrivals.len(),
            trace.assignments.len()
        );
    }
    Ok(())
}

/// `adms fleet`: simulate a population of devices. Arms are the cross
/// product of `--socs × --scheds × --workloads`; device `i` runs arm
/// `i % arms` under a seed derived from `--seed` and `i`. The report is
/// bit-identical for any `--workers` value (per-device results stream
/// into exact per-arm accumulators, so the fold order can't show).
fn cmd_fleet(argv: &[String]) -> Result<()> {
    use adms::fleet::{run_fleet_opts, ArmSpec, FleetOptions, FleetSpec, PopulationSpec};
    let specs = [
        OptSpec { name: "devices", takes_value: true, help: "number of simulated devices", default: Some("8") },
        OptSpec { name: "seed", takes_value: true, help: "fleet seed (per-device seeds derive from it)", default: Some("42") },
        OptSpec { name: "workers", takes_value: true, help: "worker threads (0 = ADMS_FLEET_WORKERS or available parallelism; never affects results)", default: Some("0") },
        OptSpec { name: "socs", takes_value: true, help: "comma-separated SoC presets", default: Some("dimensity9000") },
        OptSpec { name: "scheds", takes_value: true, help: "comma-separated schedulers (vanilla|band|adms|pinned|lookahead)", default: Some("adms") },
        OptSpec { name: "workloads", takes_value: true, help: "comma-separated workloads: names, model lists (use + within an arm, e.g. retinaface+east), or scenario:<name-or-file>", default: Some("frs") },
        OptSpec { name: "fault-profiles", takes_value: true, help: "comma-separated per-arm fault profiles (off|light|heavy or crash=..;hang=..;transient=..;mttr=.. with ';' within an arm); an extra arm axis", default: Some("off") },
        OptSpec { name: "dispatch-timeout", takes_value: true, help: "all arms: declare a dispatch lost after this multiple of predicted latency (0 = off)", default: Some("0") },
        OptSpec { name: "retry-limit", takes_value: true, help: "all arms: per-request retry budget for fault-aborted work", default: Some("3") },
        OptSpec { name: "retry-backoff", takes_value: true, help: "all arms: base retry backoff ms, doubled per attempt", default: Some("25") },
        OptSpec { name: "quarantine", takes_value: true, help: "all arms: ms a recovered processor stays Degraded", default: Some("500") },
        OptSpec { name: "fault-blind", takes_value: false, help: "all arms: ablation — faults happen but the driver neither marks health nor retries", default: None },
        OptSpec { name: "adaptive-plans", takes_value: true, help: "comma-separated per-arm adaptive modes (off|reactive); an extra arm axis", default: Some("off") },
        OptSpec { name: "replan-cooldown", takes_value: true, help: "adaptive arms: min ms between granularity switches of one session", default: Some("1000") },
        OptSpec { name: "replan-threshold", takes_value: true, help: "adaptive arms: smoothed pressure above which the controller refines", default: Some("0.5") },
        OptSpec { name: "duration", takes_value: true, help: "per-device horizon, simulated ms", default: Some("5000") },
        OptSpec { name: "requests", takes_value: true, help: "per-session request quota per device; 0 = unbounded", default: Some("0") },
        OptSpec { name: "batch-max", takes_value: true, help: "largest task group one dispatch may fuse, all arms (1 = off)", default: Some("1") },
        OptSpec { name: "batch-window", takes_value: true, help: "coalescing window in ms for batchable tasks", default: Some("0") },
        OptSpec { name: "mem-budget", takes_value: true, help: "per-processor weight-residency budget, all arms: BYTES[K|M|G], 'spec', or 'off'", default: Some("0") },
        OptSpec { name: "mem-policy", takes_value: true, help: "weight-cache eviction policy: cost | lru", default: Some("cost") },
        OptSpec { name: "horizon", takes_value: true, help: "lookahead arms: rollout completions observed before scoring (0 = degenerate to --base)", default: Some("2") },
        OptSpec { name: "beam", takes_value: true, help: "lookahead arms: candidate processors per decision", default: Some("3") },
        OptSpec { name: "base", takes_value: true, help: "lookahead arms: base policy (vanilla|band|adms|pinned)", default: Some("adms") },
        OptSpec { name: "population", takes_value: true, help: "device-mix over SoC presets: 'all' or name[:weight],... (overrides each arm's --socs entry per device)", default: None },
        OptSpec { name: "ambient-mean", takes_value: true, help: "population: mean ambient °C (default: each sampled SoC's preset ambient)", default: None },
        OptSpec { name: "ambient-jitter", takes_value: true, help: "population: uniform ambient jitter half-width, °C, per device", default: Some("0") },
        OptSpec { name: "bg-load", takes_value: true, help: "population: mean background-load fraction in [0,0.9] stretching on-device service times", default: Some("0") },
        OptSpec { name: "bg-jitter", takes_value: true, help: "population: uniform background-load jitter half-width, per device", default: Some("0") },
        OptSpec { name: "fleet-scenario", takes_value: true, help: "fleet-wide arrival envelope: diurnal[:period=MS,low=F,high=F,steps=N] or flash[:at=MS,width=MS,mult=F,steps=N]", default: None },
        OptSpec { name: "progress", takes_value: false, help: "stderr heartbeat: devices done/total and devices/sec, about once a second", default: None },
        OptSpec { name: "chunk", takes_value: true, help: "devices claimed per work-grab (0 = auto; never affects results)", default: Some("0") },
        OptSpec { name: "json", takes_value: true, help: "also write the FleetReport as JSON here", default: None },
        OptSpec { name: "help", takes_value: false, help: "show help", default: None },
    ];
    let args = parse(argv, &specs)?;
    if args.flag("help") {
        println!("{}", render_help("adms fleet [options]", &specs));
        println!("socs: {}", SOC_NAMES.join(", "));
        println!("named workloads: {}", adms::workload::WORKLOAD_NAMES.join(", "));
        println!("named scenarios: {}", adms::scenario::SCENARIO_NAMES.join(", "));
        return Ok(());
    }
    let csv = |key: &str, default: &str| -> Vec<String> {
        args.get_or(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    };
    let socs = csv("socs", "dimensity9000");
    let scheds = csv("scheds", "adms");
    // `,` separates arms; `+` separates models within one arm's list.
    // Scenario entries are left untouched — a `scenario:` value is a name
    // or a file path, where '+' is a legitimate character.
    let workloads: Vec<String> = csv("workloads", "frs")
        .into_iter()
        .map(|w| {
            if w.starts_with("scenario:") {
                w
            } else {
                w.replace('+', ",")
            }
        })
        .collect();
    // `,` separates the fault-profile axis; `;` separates the key=value
    // fields of a custom spec within one arm (the spec grammar itself
    // uses ',').
    let profiles: Vec<String> =
        csv("fault-profiles", "off").into_iter().map(|p| p.replace(';', ",")).collect();
    let adaptives = csv("adaptive-plans", "off");
    for ap in &adaptives {
        if adms::exec::AdaptivePlan::parse(ap).is_none() {
            bail!("--adaptive-plans: expected off|reactive entries, got '{ap}'");
        }
    }
    let mut arms = Vec::new();
    for soc in &socs {
        for sched in &scheds {
            for wl in &workloads {
                for fp in &profiles {
                    for ap in &adaptives {
                        let mut arm = ArmSpec::new(soc, sched, wl);
                        if fp != "off" && fp != "none" {
                            arm = arm.faulty(fp);
                        }
                        if ap != "off" {
                            arm = arm.adaptive(ap);
                        }
                        arms.push(arm);
                    }
                }
            }
        }
    }
    let requests = args.get_u64("requests", 0)?;
    let cfg = adms::exec::SimConfig {
        duration_ms: args.get_f64("duration", 5_000.0)?,
        max_requests: (requests > 0).then_some(requests),
        batch_max: args.get_usize("batch-max", 1)?.max(1),
        batch_window_ms: args.get_f64("batch-window", 0.0)?.max(0.0),
        mem_budget_bytes: parse_mem_budget(&args.get_or("mem-budget", "0"))?,
        mem_policy: parse_mem_policy(&args.get_or("mem-policy", "cost"))?,
        lookahead_horizon: args.get_u64("horizon", 2)? as u32,
        lookahead_beam: args.get_u64("beam", 3)? as u32,
        lookahead_base: parse_base(&args.get_or("base", "adms"))?,
        dispatch_timeout_mult: args.get_f64("dispatch-timeout", 0.0)?.max(0.0),
        retry_limit: args.get_u64("retry-limit", 3)? as u32,
        retry_backoff_ms: args.get_f64("retry-backoff", 25.0)?.max(0.0),
        fault_quarantine_ms: args.get_f64("quarantine", 500.0)?.max(0.0),
        fault_blind: args.flag("fault-blind"),
        replan_cooldown_ms: args.get_f64("replan-cooldown", 1000.0)?.max(0.0),
        replan_threshold: args.get_f64("replan-threshold", 0.5)?.clamp(0.0, 1.0),
        ..Default::default()
    };
    // Population heterogeneity: a SoC mix and/or per-device condition
    // jitter. Condition flags work without --population (the mix then
    // stays each arm's nominal SoC).
    let population = {
        let mut p = match args.get("population") {
            Some(mix) => PopulationSpec::parse_mix(mix)?,
            None => PopulationSpec::uniform(&[]),
        };
        p.ambient_mean_c = match args.get("ambient-mean") {
            Some(_) => Some(args.get_f64("ambient-mean", 0.0)?),
            None => None,
        };
        p.ambient_jitter_c = args.get_f64("ambient-jitter", 0.0)?;
        p.bg_mean = args.get_f64("bg-load", 0.0)?;
        p.bg_jitter = args.get_f64("bg-jitter", 0.0)?;
        p.validate()?;
        let configured = !p.soc_mix.is_empty()
            || p.ambient_mean_c.is_some()
            || p.ambient_jitter_c > 0.0
            || p.bg_mean > 0.0
            || p.bg_jitter > 0.0;
        configured.then_some(p)
    };
    let envelope = args
        .get("fleet-scenario")
        .map(adms::scenario::FleetEnvelope::parse)
        .transpose()?;
    let spec = FleetSpec {
        arms,
        devices: args.get_usize("devices", 8)?,
        seed: args.get_u64("seed", 42)?,
        cfg,
        population,
        envelope,
    };
    let workers = match args.get_usize("workers", 0)? {
        0 => adms::util::env::fleet_workers().unwrap_or_else(|| {
            std::thread::available_parallelism().map(usize::from).unwrap_or(4).min(8)
        }),
        n => n,
    };
    let opts = FleetOptions {
        progress: args.flag("progress"),
        chunk: args.get_usize("chunk", 0)?,
    };
    let t0 = std::time::Instant::now();
    let report = run_fleet_opts(&spec, workers, &opts)?;
    let wall_s = t0.elapsed().as_secs_f64();
    println!(
        "fleet: {} devices × {} arm(s), seed {}, {} workers",
        spec.devices,
        report.arms.len(),
        spec.seed,
        workers.min(spec.devices)
    );
    print!("{}", report.render());
    println!(
        "simulated {:.1} device-seconds in {:.2} s wall ({:.0} sim-ms/wall-s), {} driver events",
        report.total.sim_ms() / 1e3,
        wall_s,
        report.total.sim_ms() / wall_s.max(1e-9),
        report.total.events
    );
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_pretty())
            .map_err(|e| anyhow::anyhow!("--json '{path}': {e}"))?;
        println!("wrote FleetReport to {path}");
    }
    Ok(())
}

/// `adms tournament`: the scheduler tournament — every requested
/// scheduler × SoC preset × scenario cell becomes one fleet arm with
/// `--devices-per-arm` devices, and the merged table lands in
/// `TOURNAMENT.json`. `all` (the default) expands each axis to its full
/// registry; rows come out (soc, sched, scenario)-sorted regardless of
/// argument order, so tables from different runs merge by concatenation.
fn cmd_tournament(argv: &[String]) -> Result<()> {
    use adms::fleet::{run_tournament, TournamentSpec};
    let specs = [
        OptSpec { name: "socs", takes_value: true, help: "comma-separated SoC presets, or 'all'", default: Some("all") },
        OptSpec { name: "scheds", takes_value: true, help: "comma-separated schedulers, or 'all'", default: Some("all") },
        OptSpec { name: "scenarios", takes_value: true, help: "comma-separated scenario names or spec files, or 'all' (named scenarios)", default: Some("all") },
        OptSpec { name: "devices-per-arm", takes_value: true, help: "simulated devices per (soc, sched, scenario) cell", default: Some("2") },
        OptSpec { name: "seed", takes_value: true, help: "tournament seed (per-device seeds derive from it)", default: Some("42") },
        OptSpec { name: "workers", takes_value: true, help: "worker threads (0 = ADMS_FLEET_WORKERS or available parallelism; never affects results)", default: Some("0") },
        OptSpec { name: "duration", takes_value: true, help: "per-device horizon, simulated ms", default: Some("3000") },
        OptSpec { name: "requests", takes_value: true, help: "per-session request quota per device; 0 = unbounded", default: Some("0") },
        OptSpec { name: "batch-max", takes_value: true, help: "largest task group one dispatch may fuse, all cells (1 = off)", default: Some("1") },
        OptSpec { name: "batch-window", takes_value: true, help: "coalescing window in ms for batchable tasks", default: Some("0") },
        OptSpec { name: "mem-budget", takes_value: true, help: "per-processor weight-residency budget, all cells: BYTES[K|M|G], 'spec', or 'off'", default: Some("0") },
        OptSpec { name: "mem-policy", takes_value: true, help: "weight-cache eviction policy: cost | lru", default: Some("cost") },
        OptSpec { name: "horizon", takes_value: true, help: "lookahead cells: rollout completions observed before scoring (0 = degenerate to --base)", default: Some("2") },
        OptSpec { name: "beam", takes_value: true, help: "lookahead cells: candidate processors per decision", default: Some("3") },
        OptSpec { name: "base", takes_value: true, help: "lookahead cells: base policy (vanilla|band|adms|pinned)", default: Some("adms") },
        OptSpec { name: "adaptive-plan", takes_value: true, help: "all cells: runtime granularity switching (off | reactive)", default: Some("off") },
        OptSpec { name: "replan-cooldown", takes_value: true, help: "adaptive cells: min ms between granularity switches of one session", default: Some("1000") },
        OptSpec { name: "replan-threshold", takes_value: true, help: "adaptive cells: smoothed pressure above which the controller refines", default: Some("0.5") },
        OptSpec { name: "out", takes_value: true, help: "write the TournamentReport as JSON here", default: Some("TOURNAMENT.json") },
        OptSpec { name: "help", takes_value: false, help: "show help", default: None },
    ];
    let args = parse(argv, &specs)?;
    if args.flag("help") {
        println!("{}", render_help("adms tournament [options]", &specs));
        println!("socs: {}", SOC_NAMES.join(", "));
        println!("schedulers: {}", adms::exec::SCHEDULER_NAMES.join(", "));
        println!("named scenarios: {}", adms::scenario::SCENARIO_NAMES.join(", "));
        return Ok(());
    }
    let expand = |key: &str, all: &[&str]| -> Vec<String> {
        let raw = args.get_or(key, "all");
        if raw == "all" {
            all.iter().map(|s| s.to_string()).collect()
        } else {
            raw.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect()
        }
    };
    let requests = args.get_u64("requests", 0)?;
    let spec = TournamentSpec {
        socs: expand("socs", &SOC_NAMES),
        scheds: expand("scheds", &adms::exec::SCHEDULER_NAMES),
        scenarios: expand("scenarios", &adms::scenario::SCENARIO_NAMES),
        devices_per_arm: args.get_usize("devices-per-arm", 2)?,
        seed: args.get_u64("seed", 42)?,
        cfg: adms::exec::SimConfig {
            duration_ms: args.get_f64("duration", 3_000.0)?,
            max_requests: (requests > 0).then_some(requests),
            batch_max: args.get_usize("batch-max", 1)?.max(1),
            batch_window_ms: args.get_f64("batch-window", 0.0)?.max(0.0),
            mem_budget_bytes: parse_mem_budget(&args.get_or("mem-budget", "0"))?,
            mem_policy: parse_mem_policy(&args.get_or("mem-policy", "cost"))?,
            lookahead_horizon: args.get_u64("horizon", 2)? as u32,
            lookahead_beam: args.get_u64("beam", 3)? as u32,
            lookahead_base: parse_base(&args.get_or("base", "adms"))?,
            adaptive_plan: parse_adaptive(&args.get_or("adaptive-plan", "off"))?,
            replan_cooldown_ms: args.get_f64("replan-cooldown", 1_000.0)?.max(0.0),
            replan_threshold: args.get_f64("replan-threshold", 0.5)?.clamp(0.0, 1.0),
            ..Default::default()
        },
    };
    let workers = match args.get_usize("workers", 0)? {
        0 => adms::util::env::fleet_workers().unwrap_or_else(|| {
            std::thread::available_parallelism().map(usize::from).unwrap_or(4).min(8)
        }),
        n => n,
    };
    let t0 = std::time::Instant::now();
    let report = run_tournament(&spec, workers)?;
    let wall_s = t0.elapsed().as_secs_f64();
    println!(
        "tournament: {} cell(s) × {} device(s), seed {}, {} workers, {:.2} s wall",
        report.rows.len(),
        spec.devices_per_arm,
        spec.seed,
        workers,
        wall_s
    );
    print!("{}", report.render());
    let path = args.get_or("out", "TOURNAMENT.json");
    std::fs::write(&path, report.to_json().to_pretty())
        .map_err(|e| anyhow::anyhow!("--out '{path}': {e}"))?;
    println!("wrote TournamentReport to {path}");
    Ok(())
}

/// `adms bench`: run the simulator throughput suite (the same
/// measurements as `cargo bench --bench bench_sim`) and persist the
/// results as `BENCH_sim.json` — the tracked perf trajectory that
/// EXPERIMENTS.md §Perf and the CI smoke-bench job consume.
fn cmd_bench(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "out", takes_value: true, help: "results file (JSON)", default: Some("BENCH_sim.json") },
        OptSpec { name: "json", takes_value: false, help: "also print the JSON to stdout", default: None },
        OptSpec { name: "check", takes_value: false, help: "fail if events/sec regresses >20% vs the existing --out file (read before overwriting)", default: None },
        OptSpec { name: "strict", takes_value: false, help: "with --check: a missing baseline is an error, not a warning", default: None },
        OptSpec { name: "help", takes_value: false, help: "show help", default: None },
    ];
    let args = parse(argv, &specs)?;
    if args.flag("help") {
        println!(
            "{}",
            render_help("adms bench [--out FILE] [--json] [--check [--strict]]", &specs)
        );
        println!("budget per measurement: ADMS_BENCH_MS (ms, default 300)");
        return Ok(());
    }
    let path = args.get_or("out", "BENCH_sim.json");
    // Baseline for --check: whatever the previous run committed at the
    // --out path, read BEFORE this run overwrites it.
    let baseline = if args.flag("check") {
        match std::fs::read_to_string(&path) {
            Ok(text) => Some(bench_baseline(&text)?),
            Err(e) => {
                if args.flag("strict") {
                    bail!(
                        "bench --check --strict: no baseline at {path} ({e}); run `adms \
                         bench --out {path}` on a quiet machine and commit it first"
                    );
                }
                eprintln!(
                    "warning: bench --check has no baseline at {path} ({e}) — measuring \
                     WITHOUT a regression gate (pass --strict to make this fatal)"
                );
                None
            }
        }
    } else {
        None
    };
    let (budget_ms, entries) = adms::testing::bench::run_sim_suite();
    println!();
    adms::testing::bench::print_sim_suite(&entries);
    println!(
        "memo: {} plan-cache entr(ies), {} tuner-cache entr(ies)",
        adms::sched::plan_cache_len(),
        adms::analyzer::tune_cache_len()
    );
    let json = adms::testing::bench::sim_suite_json(budget_ms, &entries).to_pretty();
    std::fs::write(&path, &json).map_err(|e| anyhow::anyhow!("--out '{path}': {e}"))?;
    println!("\nwrote {} bench entries to {path}", entries.len());
    if args.flag("json") {
        println!("{json}");
    }
    if let Some(base) = baseline {
        let mut regressions = Vec::new();
        for e in &entries {
            if let Some(&old) = base.get(&e.name) {
                let new = e.events_per_sec();
                if old > 0.0 && new < 0.8 * old {
                    regressions.push(format!(
                        "{}: {:.0} events/s vs baseline {:.0} ({:+.1}%)",
                        e.name,
                        new,
                        old,
                        100.0 * (new / old - 1.0)
                    ));
                }
            }
        }
        if regressions.is_empty() {
            println!("bench --check: no entry regressed >20% vs the baseline");
        } else {
            bail!(
                "bench --check: events/sec regressed >20% vs {path}:\n  {}",
                regressions.join("\n  ")
            );
        }
    }
    Ok(())
}

/// Parse a committed `BENCH_sim.json` into `name → events_per_sec` for
/// the `bench --check` regression gate.
fn bench_baseline(text: &str) -> Result<std::collections::HashMap<String, f64>> {
    let v = adms::util::json::parse(text).map_err(|e| anyhow::anyhow!("baseline: {e}"))?;
    let entries = v
        .get("entries")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("baseline: missing 'entries'"))?;
    let mut out = std::collections::HashMap::new();
    for e in entries {
        if let (Some(name), Some(eps)) =
            (e.get("name").as_str(), e.get("events_per_sec").as_f64())
        {
            out.insert(name.to_string(), eps);
        }
    }
    Ok(out)
}

fn cmd_scenario(argv: &[String]) -> Result<()> {
    use adms::scenario::{by_name, describe, generate, GenConfig, Scenario, SCENARIO_NAMES};
    let specs = [
        OptSpec { name: "seed", takes_value: true, help: "gen: rng seed", default: Some("42") },
        OptSpec { name: "sessions", takes_value: true, help: "gen: number of sessions", default: Some("4") },
        OptSpec { name: "duration", takes_value: true, help: "gen: event horizon, ms", default: Some("20000") },
        OptSpec { name: "churn", takes_value: true, help: "gen: per-session stop probability", default: Some("0.5") },
        OptSpec { name: "rate-change", takes_value: true, help: "gen: per-session rate-change probability", default: Some("0.5") },
        OptSpec { name: "out", takes_value: true, help: "write JSON here instead of stdout", default: None },
        OptSpec { name: "help", takes_value: false, help: "show help", default: None },
    ];
    let args = parse(argv, &specs)?;
    let usage = "adms scenario <list|show <name|file>|gen> [options]";
    if args.flag("help") {
        println!("{}", render_help(usage, &specs));
        return Ok(());
    }
    let emit = |sc: &Scenario| -> Result<()> {
        let json = sc.to_json_string();
        match args.get("out") {
            Some(path) => {
                std::fs::write(path, &json)
                    .map_err(|e| anyhow::anyhow!("--out '{path}': {e}"))?;
                println!(
                    "wrote scenario '{}' ({} sessions, {} events) to {path}",
                    sc.name,
                    sc.num_sessions(),
                    sc.events.len()
                );
            }
            None => println!("{json}"),
        }
        Ok(())
    };
    match args.positional.first().map(String::as_str) {
        None | Some("list") => {
            for n in SCENARIO_NAMES {
                println!("{n:12} {}", describe(n));
            }
            println!("\nrun one:  adms serve --scenario <name> --backend sim");
            Ok(())
        }
        Some("show") => {
            let Some(name) = args.positional.get(1) else {
                bail!("usage: adms scenario show <name|file>");
            };
            let sc = match by_name(name) {
                Some(sc) => sc,
                None => {
                    let text = std::fs::read_to_string(name)
                        .map_err(|e| anyhow::anyhow!("'{name}': not a named scenario and not a readable file: {e}"))?;
                    Scenario::from_json_str(&text)?
                }
            };
            emit(&sc)
        }
        Some("gen") => {
            let cfg = GenConfig {
                sessions: args.get_usize("sessions", 4)?,
                duration_ms: args.get_f64("duration", 20_000.0)?,
                churn: args.get_f64("churn", 0.5)?,
                rate_change: args.get_f64("rate-change", 0.5)?,
            };
            let sc = generate(args.get_u64("seed", 42)?, &cfg);
            sc.compile()?; // validate before emitting
            emit(&sc)
        }
        Some(other) => bail!("unknown scenario command '{other}'\nusage: {usage}"),
    }
}

/// The pre-0.2 probe path: round-robin the AOT numerics probe over a
/// worker pool through PJRT, verifying logits.
fn serve_probe_legacy(args: &adms::util::cli::Args) -> Result<()> {
    let rt = adms::runtime::Runtime::cpu()?;
    let dir = adms::runtime::default_artifact_dir();
    let art = rt.load_dir(&dir)?;
    println!(
        "loaded '{}' from {dir:?} on {} ({} stages, pipeline {:?})",
        art.model,
        rt.platform(),
        art.stages.len(),
        art.pipeline
    );
    let cfg = adms::coordinator::ServeConfig {
        workers: args.get_usize("workers", 2)?,
        requests: args.get_usize("requests", 64)?,
        verify: !args.flag("no-verify"),
    };
    #[allow(deprecated)]
    let r = adms::coordinator::serve_probe(&art, &cfg)?;
    println!(
        "served {} requests on {} workers in {} ms: p50 {} ms, p95 {} ms, {} req/s, {} errors, {} verify failures",
        r.completed,
        r.workers,
        fnum(r.wall_ms, 1),
        fnum(r.latency.p50(), 3),
        fnum(r.latency.p95(), 3),
        fnum(r.throughput_rps, 1),
        r.errors,
        r.verify_failures
    );
    Ok(())
}
