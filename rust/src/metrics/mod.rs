//! Cross-framework comparison helpers for the experiment harness.

use crate::sim::SimReport;
use crate::util::table::{fnum, Table};

/// Render a framework-comparison table (one row per metric, one column
/// per report) in the style of the paper's Tables 6/7.
pub fn comparison_table(title: &str, reports: &[&SimReport]) -> Table {
    let mut header = vec!["Metric"];
    let names: Vec<String> = reports.iter().map(|r| r.scheduler.clone()).collect();
    for n in &names {
        header.push(n);
    }
    let mut t = Table::new(title, &header);
    let row = |t: &mut Table, name: &str, vals: Vec<String>| {
        let mut cells = vec![name.to_string()];
        cells.extend(vals);
        t.row(&cells);
    };
    row(
        &mut t,
        "Total FPS",
        reports.iter().map(|r| fnum(r.total_fps(), 2)).collect(),
    );
    row(
        &mut t,
        "Pipeline FPS",
        reports.iter().map(|r| fnum(r.pipeline_fps(), 2)).collect(),
    );
    row(
        &mut t,
        "Mean latency (ms)",
        reports.iter().map(|r| fnum(r.mean_latency_ms(), 2)).collect(),
    );
    row(
        &mut t,
        "Avg power (W)",
        reports.iter().map(|r| fnum(r.avg_power_w(), 2)).collect(),
    );
    row(
        &mut t,
        "Energy (J)",
        reports.iter().map(|r| fnum(r.energy_j, 1)).collect(),
    );
    row(
        &mut t,
        "Frames/Joule (pipeline)",
        reports.iter().map(|r| fnum(r.pipeline_frames_per_joule(), 3)).collect(),
    );
    row(
        &mut t,
        "Failure rate (%)",
        reports.iter().map(|r| fnum(r.failure_rate() * 100.0, 2)).collect(),
    );
    row(
        &mut t,
        "Avg processor busy (%)",
        reports.iter().map(|r| fnum(r.avg_busy_frac() * 100.0, 1)).collect(),
    );
    t
}

/// Per-session FPS table (Fig 8 style).
pub fn fps_table(title: &str, reports: &[&SimReport]) -> Table {
    let mut header = vec!["Model"];
    let names: Vec<String> = reports.iter().map(|r| r.scheduler.clone()).collect();
    for n in &names {
        header.push(n);
    }
    let mut t = Table::new(title, &header);
    if reports.is_empty() {
        return t;
    }
    for (i, s) in reports[0].sessions.iter().enumerate() {
        let mut cells = vec![s.model.clone()];
        for r in reports {
            cells.push(fnum(r.sessions.get(i).map(|x| x.fps).unwrap_or(f64::NAN), 2));
        }
        t.row(&cells);
    }
    let mut cells = vec!["TOTAL".to_string()];
    for r in reports {
        cells.push(fnum(r.total_fps(), 2));
    }
    t.row(&cells);
    let mut cells = vec!["PIPELINE".to_string()];
    for r in reports {
        cells.push(fnum(r.pipeline_fps(), 2));
    }
    t.row(&cells);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Adms;
    use crate::sim::{App, Engine, SimConfig};
    use crate::soc::dimensity9000;

    fn tiny_report() -> SimReport {
        Engine::new(
            dimensity9000(),
            SimConfig { duration_ms: 500.0, ..Default::default() },
            vec![App::closed_loop("mobilenet_v1")],
            Box::new(Adms::default()),
            &|_| 5,
        )
        .unwrap()
        .run()
    }

    #[test]
    fn tables_render_without_panic() {
        let r = tiny_report();
        let cmp = comparison_table("t", &[&r, &r]);
        let s = cmp.render();
        assert!(s.contains("Total FPS"));
        assert!(s.contains("Frames/Joule"));
        let fps = fps_table("f", &[&r]);
        assert!(fps.render().contains("mobilenet_v1"));
        assert!(fps.render().contains("TOTAL"));
    }
}
