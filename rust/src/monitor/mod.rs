//! Hardware Monitor (paper §3.3).
//!
//! The paper's monitor reads `/sys` thermal/cpufreq files, OpenGL and
//! NNAPI interfaces, caching results so a full snapshot costs ~10 ms
//! instead of 40–50 ms of raw file reads. Here the "hardware" is the SoC
//! simulation state; the monitor reproduces the *interface* and its
//! staleness/overhead trade-off: schedulers see a snapshot that may lag
//! reality by up to the cache interval, and each refresh charges a small
//! amount of CPU time (the sampling daemon's cost).

use crate::soc::{ProcId, ProcKind, ProcessorSpec};
use crate::TimeMs;

/// Fault-layer health of a processor, as the scheduler sees it.
///
/// Distinct from thermal `offline`: offline is the SoC protecting itself
/// (critical temperature), health is the *driver's* belief about whether
/// the processor executes work at all. `Down` processors are masked from
/// scheduling entirely ([`crate::sched::SchedCtx::free_slots`] reports 0
/// slots); `Degraded` is the quarantine-and-probe state after a recovery
/// — schedulable, but cost-aware policies re-price it until it has been
/// up for `fault_quarantine_ms`. Fault-blind runs never leave `Up`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Up,
    Degraded,
    Down,
}

impl Health {
    pub fn label(self) -> &'static str {
        match self {
            Health::Up => "up",
            Health::Degraded => "degraded",
            Health::Down => "down",
        }
    }
}

/// Monitor's view of one processor — what the paper's scheduler reads:
/// load, temperature, frequency, and operational status.
#[derive(Debug, Clone)]
pub struct ProcView {
    pub id: ProcId,
    pub kind: ProcKind,
    /// Junction temperature, °C.
    pub temp_c: f64,
    /// Current frequency, MHz (0 when offline).
    pub freq_mhz: f64,
    /// Frequency scale factor vs max, `(0, 1]`.
    pub freq_scale: f64,
    /// Offline due to critical temperature.
    pub offline: bool,
    /// Occupied execution slots / total slots, `[0, 1]`.
    pub load: f64,
    /// Queued-work backlog in estimated ms (the `B_current` of Eq 3).
    pub backlog_ms: f64,
    /// Distinct sessions recently resident (contention driver).
    pub active_sessions: usize,
    /// Utilization over the last governor tick, `[0, 1]`.
    pub util: f64,
    /// Thermal headroom before the throttle threshold, °C.
    pub headroom_c: f64,
    /// Fault-layer health. Backends always report `Up` (they model
    /// hardware, not beliefs); the driver overlays its health state onto
    /// the cached snapshot when the fault layer is active — see
    /// [`HardwareMonitor::overlay_health`].
    pub health: Health,
}

impl ProcView {
    /// Nameplate view of an idle processor at `temp_c`: max frequency, no
    /// load/backlog, online. This is the canonical "cold snapshot" that
    /// scheduler tests and benches used to hand-roll in three places —
    /// one constructor so a new `ProcView` field can't silently get three
    /// different defaults.
    pub fn nameplate(id: ProcId, spec: &ProcessorSpec, temp_c: f64) -> Self {
        ProcView {
            id,
            kind: spec.kind,
            temp_c,
            freq_mhz: spec.max_freq(),
            freq_scale: 1.0,
            offline: false,
            load: 0.0,
            backlog_ms: 0.0,
            active_sessions: 0,
            util: 0.0,
            headroom_c: spec.throttle_temp_c - temp_c,
            health: Health::Up,
        }
    }
}

/// Caching monitor. `sample` returns the cached snapshot unless it is
/// older than `cache_interval_ms`, in which case `refresh_fn` is invoked
/// (and the refresh counted — the paper's ~10 ms retrieval cost is charged
/// to the CPU by the simulation engine via `refresh_count`).
#[derive(Debug)]
pub struct HardwareMonitor {
    cache_interval_ms: f64,
    last_refresh: TimeMs,
    cached: Vec<ProcView>,
    refreshes: u64,
}

/// CPU time consumed by one monitor refresh (paper §3.3: "the entire data
/// retrieval process taking approximately 10 ms" — amortized across the
/// monitor thread; we charge a fraction since retrieval overlaps I/O).
pub const REFRESH_CPU_MS: f64 = 0.5;

impl HardwareMonitor {
    pub fn new(cache_interval_ms: f64) -> Self {
        HardwareMonitor {
            cache_interval_ms,
            last_refresh: f64::NEG_INFINITY,
            cached: Vec::new(),
            refreshes: 0,
        }
    }

    /// Get the (possibly stale) snapshot at time `now`. Thin wrapper over
    /// [`HardwareMonitor::sample_with`] so the cache-miss rule has one
    /// source of truth.
    pub fn sample(
        &mut self,
        now: TimeMs,
        refresh_fn: impl FnOnce() -> Vec<ProcView>,
    ) -> &[ProcView] {
        self.sample_with(now, |buf| buf.extend(refresh_fn()))
    }

    /// [`HardwareMonitor::sample`] with an in-place refresh: on a cache
    /// miss `refresh_fn` fills the monitor's own (cleared) buffer instead
    /// of returning a fresh `Vec`. This is the dispatch loop's hot-path
    /// form — a refresh reuses the cached vector's capacity, and a cache
    /// hit borrows the snapshot without copying it.
    pub fn sample_with(
        &mut self,
        now: TimeMs,
        refresh_fn: impl FnOnce(&mut Vec<ProcView>),
    ) -> &[ProcView] {
        if now - self.last_refresh >= self.cache_interval_ms || self.cached.is_empty() {
            self.cached.clear();
            refresh_fn(&mut self.cached);
            self.last_refresh = now;
            self.refreshes += 1;
        }
        &self.cached
    }

    /// Unconditional refresh (used at simulation start).
    pub fn force_refresh(&mut self, now: TimeMs, views: Vec<ProcView>) {
        self.cached = views;
        self.last_refresh = now;
        self.refreshes += 1;
    }

    /// Overlay the driver's health beliefs onto the cached snapshot
    /// (positional: `health[i]` applies to cached view `i`). Called by
    /// the driver after every `sample_with` when the fault layer is
    /// active, so schedulers see `Down`/`Degraded` *immediately* even
    /// while the rest of the snapshot is cached-stale — the paper's
    /// monitor polls hardware, but a driver crash is a synchronous signal
    /// the runtime gets for free. Faults-off runs never call this, which
    /// is part of the byte-identity no-op argument.
    pub fn overlay_health(&mut self, health: &[Health]) {
        for (v, &h) in self.cached.iter_mut().zip(health) {
            v.health = h;
        }
    }

    /// The current cached snapshot, without staleness accounting. The
    /// dispatch loop samples (possibly refreshing), overlays health, then
    /// re-borrows the snapshot through this — a second `sample_with`
    /// would re-trigger the refresh rule under a zero cache interval.
    pub fn cached_views(&self) -> &[ProcView] {
        &self.cached
    }

    pub fn refresh_count(&self) -> u64 {
        self.refreshes
    }

    pub fn staleness(&self, now: TimeMs) -> f64 {
        now - self.last_refresh
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(temp: f64) -> Vec<ProcView> {
        vec![ProcView {
            id: 0,
            kind: ProcKind::Cpu,
            temp_c: temp,
            freq_mhz: 3000.0,
            freq_scale: 1.0,
            offline: false,
            load: 0.0,
            backlog_ms: 0.0,
            active_sessions: 0,
            util: 0.0,
            headroom_c: 68.0 - temp,
            health: Health::Up,
        }]
    }

    #[test]
    fn overlay_health_marks_cached_views() {
        let mut m = HardwareMonitor::new(1e9);
        m.sample(0.0, || view(30.0));
        m.overlay_health(&[Health::Down]);
        // The overlay survives cache hits (no refresh happens) ...
        let s = m.sample(10.0, || panic!("cache hit expected"));
        assert_eq!(s[0].health, Health::Down);
        // ... and a forced refresh resets it to the backend's Up.
        m.force_refresh(20.0, view(31.0));
        let s = m.sample(20.0, || panic!("just refreshed"));
        assert_eq!(s[0].health, Health::Up);
    }

    #[test]
    fn caches_within_interval() {
        let mut m = HardwareMonitor::new(50.0);
        let s = m.sample(0.0, || view(30.0));
        assert_eq!(s[0].temp_c, 30.0);
        // Within the interval the cached (stale) view is returned and the
        // refresh closure must not run.
        let s = m.sample(30.0, || panic!("refreshed too early"));
        assert_eq!(s[0].temp_c, 30.0);
        assert_eq!(m.refresh_count(), 1);
        assert_eq!(m.staleness(30.0), 30.0);
    }

    #[test]
    fn sample_with_matches_sample_semantics() {
        let mut m = HardwareMonitor::new(50.0);
        let s = m.sample_with(0.0, |out| out.extend(view(30.0)));
        assert_eq!(s[0].temp_c, 30.0);
        // Cache hit: the closure must not run and no copy is made.
        let s = m.sample_with(30.0, |_| panic!("refreshed too early"));
        assert_eq!(s[0].temp_c, 30.0);
        assert_eq!(m.refresh_count(), 1);
        // Miss at the interval boundary refreshes in place.
        let s = m.sample_with(50.0, |out| out.extend(view(55.0)));
        assert_eq!(s[0].temp_c, 55.0);
        assert_eq!(m.refresh_count(), 2);
    }

    #[test]
    fn refreshes_after_interval() {
        let mut m = HardwareMonitor::new(50.0);
        m.sample(0.0, || view(30.0));
        let s = m.sample(50.0, || view(55.0));
        assert_eq!(s[0].temp_c, 55.0);
        assert_eq!(m.refresh_count(), 2);
    }

    #[test]
    fn zero_interval_always_refreshes() {
        let mut m = HardwareMonitor::new(0.0);
        m.sample(1.0, || view(1.0));
        let s = m.sample(1.0, || view(2.0));
        assert_eq!(s[0].temp_c, 2.0);
        assert_eq!(m.refresh_count(), 2);
    }

    /// Staleness contract under arbitrary sampling patterns: after every
    /// `sample(now, ..)` the returned snapshot lags the true state by
    /// *less than* `cache_interval_ms` (a lag of exactly the interval
    /// triggers a refresh), and `refresh_count` equals the number of
    /// cache misses. The refresh closure encodes its capture time in
    /// `temp_c`, so the snapshot's age is directly observable.
    #[test]
    fn prop_staleness_bounded_and_refreshes_counted() {
        use crate::testing::prop::{check, iters};
        check("monitor staleness < cache interval", iters(200), |g| {
            let interval = g.f64(0.5, 120.0);
            let mut m = HardwareMonitor::new(interval);
            let mut now = 0.0f64;
            let mut expected_refreshes = 0u64;
            let mut last_refresh = f64::NEG_INFINITY;
            let steps = g.usize(1..40);
            for _ in 0..steps {
                // Gaps straddle the interval so both hit and miss paths
                // are exercised, including zero-gap resampling.
                now += if g.chance(0.2) { 0.0 } else { g.f64(0.0, interval * 1.5) };
                // Model of the cache-miss rule (same expression the
                // monitor evaluates, so float ties agree).
                let miss = now - last_refresh >= interval || expected_refreshes == 0;
                if miss {
                    expected_refreshes += 1;
                    last_refresh = now;
                }
                let t = now;
                // Copy the capture time out so the borrow of `m` ends
                // before `staleness()` is queried.
                let captured_at = m.sample(now, move || view(t))[0].temp_c;
                assert!(
                    now - captured_at < interval || captured_at == now,
                    "snapshot lags by {} ≥ interval {interval}",
                    now - captured_at
                );
                assert_eq!(
                    m.staleness(now),
                    now - captured_at,
                    "staleness() disagrees with the snapshot's age"
                );
                if miss {
                    assert_eq!(captured_at, now, "cache miss must resample now");
                }
            }
            assert_eq!(
                m.refresh_count(),
                expected_refreshes,
                "refresh_count != number of cache misses"
            );
        });
    }
}
