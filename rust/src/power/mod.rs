//! Power and energy model (paper Table 6, Fig 11).
//!
//! Per-processor power is `idle + (tdp − idle) · util · (f/f_max)^2.5`
//! (dynamic power ≈ C·f·V² with V roughly affine in f). Device power adds
//! a board baseline (display, rails, DRAM refresh) so absolute wattage is
//! comparable to the paper's Monsoon measurements (~7–8 W under the FRS
//! workload).

use crate::soc::ProcessorSpec;

/// Board-level constant draw (display + rails) added on top of processor
/// power, in watts. The paper's Monsoon numbers include the whole phone.
pub const BOARD_BASELINE_W: f64 = 2.6;

/// Instantaneous power of one processor given utilization in `[0, 1]` and
/// the current frequency scale in `(0, 1]`.
pub fn processor_power_w(spec: &ProcessorSpec, util: f64, freq_scale: f64) -> f64 {
    debug_assert!((0.0..=1.0 + 1e-9).contains(&util));
    spec.idle_w + (spec.tdp_w - spec.idle_w) * util.clamp(0.0, 1.0) * freq_scale.powf(2.5)
}

/// Accumulates energy over time: feed it (power, dt) segments.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    joules: f64,
    ms: f64,
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn accumulate(&mut self, watts: f64, dt_ms: f64) {
        self.joules += watts * dt_ms / 1e3;
        self.ms += dt_ms;
    }
    pub fn joules(&self) -> f64 {
        self.joules
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.ms
    }
    pub fn avg_watts(&self) -> f64 {
        if self.ms == 0.0 {
            0.0
        } else {
            self.joules / (self.ms / 1e3)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::dimensity9000;

    #[test]
    fn idle_and_peak_bounds() {
        let spec = &dimensity9000().processors[0];
        assert_eq!(processor_power_w(spec, 0.0, 1.0), spec.idle_w);
        assert!((processor_power_w(spec, 1.0, 1.0) - spec.tdp_w).abs() < 1e-9);
        let half_freq = processor_power_w(spec, 1.0, 0.5);
        assert!(half_freq < spec.tdp_w * 0.4, "DVFS should cut power superlinearly");
        assert!(half_freq > spec.idle_w);
    }

    #[test]
    fn energy_meter_integrates() {
        let mut m = EnergyMeter::new();
        m.accumulate(2.0, 500.0); // 2 W for 0.5 s = 1 J
        m.accumulate(4.0, 250.0); // 4 W for 0.25 s = 1 J
        assert!((m.joules() - 2.0).abs() < 1e-12);
        assert!((m.avg_watts() - 2.0 / 0.75).abs() < 1e-12);
        assert_eq!(m.elapsed_ms(), 750.0);
    }

    #[test]
    fn throttled_processor_draws_less() {
        let spec = &dimensity9000().processors[0];
        let hot = processor_power_w(spec, 0.9, 1.0);
        let throttled = processor_power_w(spec, 0.9, 1000.0 / 3050.0);
        assert!(throttled < hot * 0.3);
    }
}
