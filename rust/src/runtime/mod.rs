//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the Rust request path (Python is never invoked at serving time).
//!
//! Interchange is HLO *text* — jax ≥ 0.5 emits 64-bit instruction ids in
//! serialized HloModuleProto which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). Each stage of
//! the Layer-2 model compiles to one `PjRtLoadedExecutable`, cached here.
//!
//! The PJRT path needs the `xla` crate, which is not always available
//! (offline builds, CI). It is gated behind the `pjrt` cargo feature:
//! without it, [`Stage::execute_f32`] and [`Runtime::cpu`] return clear
//! errors, manifest handling still works, and everything built on the
//! [`StageExec`] abstraction (the serving coordinator, the thread-pool
//! backend) compiles and runs with synthetic or mock stages.

use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Anything that can execute one model stage on a flat f32 buffer. The
/// serving coordinator and the thread-pool execution backend are written
/// against this trait so they do not depend on PJRT being compiled in.
pub trait StageExec: Send + Sync {
    fn stage_name(&self) -> &str;
    fn execute_f32(&self, input: &[f32]) -> Result<Vec<f32>>;
}

/// One compiled model stage.
pub struct Stage {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl Stage {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }
    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Execute on a flat f32 buffer (row-major, the stage's input shape).
    #[cfg(feature = "pjrt")]
    pub fn execute_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            input.len() == self.input_len(),
            "stage '{}' expects {} elements, got {}",
            self.name,
            self.input_len(),
            input.len()
        );
        let dims: Vec<i64> = self.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute({}): {e:?}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // Stages are lowered with return_tuple=True → 1-tuples.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Stub when built without the `pjrt` feature.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            input.len() == self.input_len(),
            "stage '{}' expects {} elements, got {}",
            self.name,
            self.input_len(),
            input.len()
        );
        anyhow::bail!(
            "stage '{}': built without the `pjrt` feature — rebuild with \
             `--features pjrt` (requires the xla crate) to execute artifacts",
            self.name
        )
    }
}

impl StageExec for Stage {
    fn stage_name(&self) -> &str {
        &self.name
    }
    fn execute_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
        Stage::execute_f32(self, input)
    }
}

// SAFETY: the PJRT C API guarantees thread-safe `Execute` on loaded
// executables and clients (PJRT_Client / PJRT_LoadedExecutable are
// documented as thread-safe); the `xla` crate simply doesn't declare it.
// Stages are only shared immutably after construction.
#[cfg(feature = "pjrt")]
unsafe impl Send for Stage {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Stage {}

/// The numerics probe exported by `aot.py`: a fixed input and the fused
/// model's logits, used as the end-to-end correctness check.
#[derive(Debug, Clone)]
pub struct Probe {
    pub input: Vec<f32>,
    pub expected_logits: Vec<f32>,
}

/// A loaded artifact directory: compiled stages + pipeline order.
pub struct ArtifactSet {
    pub model: String,
    pub stages: BTreeMap<String, Arc<Stage>>,
    /// Stage names in serving order (e.g. stem → body → head).
    pub pipeline: Vec<String>,
    pub probe: Option<Probe>,
}

impl ArtifactSet {
    pub fn stage(&self, name: &str) -> Result<Arc<Stage>> {
        self.stages
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no stage '{name}'"))
    }

    /// The pipeline stages in execution order.
    pub fn pipeline_stages(&self) -> Result<Vec<Arc<Stage>>> {
        self.pipeline.iter().map(|n| self.stage(n)).collect()
    }
}

/// PJRT client wrapper + artifact loader.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
}

// SAFETY: see `Stage` — PJRT clients are thread-safe per the C API spec.
#[cfg(feature = "pjrt")]
unsafe impl Send for Runtime {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Runtime {}

impl Runtime {
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Runtime { client })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> Result<Self> {
        anyhow::bail!(
            "PJRT unavailable: this binary was built without the `pjrt` feature \
             (enable it with `--features pjrt`; requires the xla crate)"
        )
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Compile one HLO-text file.
    #[cfg(feature = "pjrt")]
    pub fn compile_hlo_text(
        &self,
        path: &Path,
        name: &str,
        input_shape: Vec<usize>,
        output_shape: Vec<usize>,
    ) -> Result<Stage> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        Ok(Stage { name: name.to_string(), input_shape, output_shape, exe })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn compile_hlo_text(
        &self,
        _path: &Path,
        name: &str,
        _input_shape: Vec<usize>,
        _output_shape: Vec<usize>,
    ) -> Result<Stage> {
        anyhow::bail!("cannot compile stage '{name}': built without the `pjrt` feature")
    }

    /// Load a full artifact directory produced by `make artifacts`.
    pub fn load_dir(&self, dir: &Path) -> Result<ArtifactSet> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let j = json::parse(&text).context("parsing manifest.json")?;
        let model = j.get("model").as_str().unwrap_or("?").to_string();
        let mut stages = BTreeMap::new();
        let stage_obj = j
            .get("stages")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: missing 'stages'"))?;
        for (name, info) in stage_obj {
            let file = info
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("stage {name}: missing file"))?;
            let shape = |key: &str| -> Result<Vec<usize>> {
                info.get(key)
                    .as_arr()
                    .ok_or_else(|| anyhow!("stage {name}: missing {key}"))?
                    .iter()
                    .map(|v| {
                        v.as_u64()
                            .map(|u| u as usize)
                            .ok_or_else(|| anyhow!("bad dim"))
                    })
                    .collect()
            };
            let stage = self.compile_hlo_text(
                &dir.join(file),
                name,
                shape("input_shape")?,
                shape("output_shape")?,
            )?;
            stages.insert(name.clone(), Arc::new(stage));
        }
        let pipeline: Vec<String> = j
            .get("pipeline")
            .as_arr()
            .map(|a| {
                a.iter()
                    .filter_map(|v| v.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();
        let probe = match (
            j.get("probe").get("input").as_arr(),
            j.get("probe").get("expected_logits").as_arr(),
        ) {
            (Some(inp), Some(exp)) => Some(Probe {
                input: inp.iter().filter_map(Json::as_f64).map(|v| v as f32).collect(),
                expected_logits: exp
                    .iter()
                    .filter_map(Json::as_f64)
                    .map(|v| v as f32)
                    .collect(),
            }),
            _ => None,
        };
        Ok(ArtifactSet { model, stages, pipeline, probe })
    }
}

/// Default artifact directory: `$ADMS_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("ADMS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True when artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}
