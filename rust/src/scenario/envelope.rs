//! Fleet-level arrival envelopes: diurnal and flash-crowd load shapes
//! applied on a **shared wall-clock** across every device of a fleet run
//! (`adms fleet --fleet-scenario`). The envelope multiplies each
//! session's arrival rate by a time-varying factor, compiled down to the
//! plain [`EventKind::Rate`] events the driver already understands — so
//! record/replay, fleet determinism, and both backends see nothing new.
//!
//! Determinism: the envelope is applied ONCE per arm `RunSpec` at fleet
//! setup (a pure function of the compiled workload, the envelope
//! parameters, and the run duration), then shared by every device of the
//! arm. Devices differ only through their seeds, exactly as before.
//!
//! No-op discipline: a flat envelope (factor ≡ 1) emits no events and
//! rewrites every rate by ×1.0 (bit-identical f64), so the modulated
//! run is byte-identical to the unmodulated one by construction —
//! `fleet_rt::flat_envelope_is_byte_identical_noop` pins this. Rate
//! events are only emitted when the factor actually changes for a
//! session, because re-asserting an unchanged mode would re-arm its
//! arrival timer and perturb the sequence.

use crate::exec::{App, ArrivalMode, EventKind, SessionEvent};
use anyhow::{bail, Context, Result};

/// The load shape, as a multiplicative factor over base arrival rates.
#[derive(Debug, Clone, PartialEq)]
pub enum Envelope {
    /// One sinusoidal day: factor swings `low → high → low` over
    /// `period_ms` (starting at `low` at t = 0, peaking at half period).
    Diurnal { period_ms: f64, low: f64, high: f64 },
    /// A flash crowd: factor 1 everywhere except a raised-cosine pulse
    /// of total width `width_ms` centered at `at_ms`, peaking at `mult`.
    Flash { at_ms: f64, width_ms: f64, mult: f64 },
}

/// A fleet arrival envelope: the shape plus the step resolution at which
/// it is compiled into discrete [`EventKind::Rate`] events.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetEnvelope {
    pub envelope: Envelope,
    /// Piecewise-constant steps the run duration is divided into when
    /// compiling the continuous shape to rate events.
    pub steps: usize,
}

/// The factor never reaches zero: a zero rate would wedge arrival
/// processes (`validate_mode` rejects non-positive rates for the same
/// reason).
const FACTOR_FLOOR: f64 = 0.01;

impl FleetEnvelope {
    /// Parse the CLI grammar:
    /// `diurnal[:period=MS,low=F,high=F,steps=N]` |
    /// `flash[:at=MS,width=MS,mult=F,steps=N]`.
    /// Defaults: diurnal spans the run duration (period 0 = "one day per
    /// run"), low 0.25, high 2.0; flash at half duration (at 0 = midpoint),
    /// width a quarter duration (0 = duration/4), mult 4; steps 32.
    pub fn parse(s: &str) -> Result<FleetEnvelope> {
        let (kind, params) = match s.split_once(':') {
            Some((k, p)) => (k, p),
            None => (s, ""),
        };
        let mut kv = std::collections::BTreeMap::new();
        for part in params.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .with_context(|| format!("envelope param '{part}' is not k=v"))?;
            let v: f64 = v.parse().with_context(|| format!("envelope param '{part}'"))?;
            if !v.is_finite() {
                bail!("envelope param '{part}' must be finite");
            }
            kv.insert(k.to_string(), v);
        }
        let get = |k: &str, default: f64| kv.get(k).copied().unwrap_or(default);
        let steps = get("steps", 32.0);
        if !(1.0..=100_000.0).contains(&steps) {
            bail!("envelope steps must be in 1..=100000, got {steps}");
        }
        let env = match kind {
            "diurnal" => {
                let low = get("low", 0.25);
                let high = get("high", 2.0);
                if low <= 0.0 || high <= 0.0 {
                    bail!("diurnal low/high must be positive");
                }
                Envelope::Diurnal { period_ms: get("period", 0.0), low, high }
            }
            "flash" => {
                let mult = get("mult", 4.0);
                if mult <= 0.0 {
                    bail!("flash mult must be positive");
                }
                Envelope::Flash { at_ms: get("at", 0.0), width_ms: get("width", 0.0), mult }
            }
            other => bail!("unknown fleet scenario '{other}' (expected diurnal|flash)"),
        };
        Ok(FleetEnvelope { envelope: env, steps: steps as usize })
    }

    /// Human label for reports.
    pub fn label(&self) -> String {
        match &self.envelope {
            Envelope::Diurnal { period_ms, low, high } => {
                format!("diurnal(period={period_ms},low={low},high={high},steps={})", self.steps)
            }
            Envelope::Flash { at_ms, width_ms, mult } => {
                format!("flash(at={at_ms},width={width_ms},mult={mult},steps={})", self.steps)
            }
        }
    }

    /// The envelope with its duration-relative defaults resolved against
    /// an actual run horizon (period/at/width of 0 mean "derive from the
    /// duration" — see [`FleetEnvelope::parse`]).
    fn resolved(&self, duration_ms: f64) -> Envelope {
        match self.envelope {
            Envelope::Diurnal { period_ms, low, high } => Envelope::Diurnal {
                period_ms: if period_ms > 0.0 { period_ms } else { duration_ms.max(1.0) },
                low,
                high,
            },
            Envelope::Flash { at_ms, width_ms, mult } => Envelope::Flash {
                at_ms: if at_ms > 0.0 { at_ms } else { duration_ms * 0.5 },
                width_ms: if width_ms > 0.0 { width_ms } else { (duration_ms * 0.25).max(1.0) },
                mult,
            },
        }
    }

    /// The (resolved) arrival-rate factor at wall-clock `t`.
    pub fn factor_at(&self, t: f64, duration_ms: f64) -> f64 {
        let f = match self.resolved(duration_ms) {
            Envelope::Diurnal { period_ms, low, high } => {
                let phase = (t / period_ms) * std::f64::consts::TAU;
                low + (high - low) * 0.5 * (1.0 - phase.cos())
            }
            Envelope::Flash { at_ms, width_ms, mult } => {
                let d = t - at_ms;
                if d.abs() < width_ms * 0.5 {
                    let phase = (d / width_ms) * std::f64::consts::TAU;
                    1.0 + (mult - 1.0) * 0.5 * (1.0 + phase.cos())
                } else {
                    1.0
                }
            }
        };
        f.max(FACTOR_FLOOR)
    }

    /// Compile the envelope onto a compiled workload in place: scale
    /// every rate-driven arrival process by the factor at the time it
    /// takes effect, and emit piecewise-constant re-rate events at step
    /// boundaries where the factor changed. Closed-loop and replay
    /// sessions are untouched (they have no rate to modulate).
    pub fn apply(&self, apps: &mut [App], events: &mut Vec<SessionEvent>, duration_ms: f64) {
        let n = apps.len();
        // Session lifecycle from the existing event list: start time
        // (0 unless a Start event admits it later), first stop time, and
        // the chronological rate-change schedule per session.
        let mut start = vec![0.0f64; n];
        let mut stop = vec![f64::INFINITY; n];
        let mut rates: Vec<Vec<(f64, ArrivalMode)>> = vec![Vec::new(); n];
        for ev in events.iter() {
            match &ev.kind {
                EventKind::Start { session } if *session < n => start[*session] = ev.at_ms,
                EventKind::Stop { session } if *session < n => {
                    stop[*session] = stop[*session].min(ev.at_ms);
                }
                EventKind::Rate { session, mode } if *session < n => {
                    rates[*session].push((ev.at_ms, mode.clone()));
                }
                _ => {}
            }
        }
        for r in rates.iter_mut() {
            r.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite event times"));
        }
        // Scale the initial modes (take effect at the session's start)
        // and every existing rate event (takes effect at its own time).
        for (s, app) in apps.iter_mut().enumerate() {
            if let Some(m) = scale_mode(&app.mode, self.factor_at(start[s], duration_ms)) {
                app.mode = m;
            }
        }
        for ev in events.iter_mut() {
            if let EventKind::Rate { mode, .. } = &mut ev.kind {
                if let Some(m) = scale_mode(mode, self.factor_at(ev.at_ms, duration_ms)) {
                    *mode = m;
                }
            }
        }
        // Step boundaries: emit a re-rate only where the factor actually
        // changed since the session's last modulation point (start, a
        // scenario rate change, or a previous boundary) — re-asserting an
        // unchanged mode would re-arm the arrival timer, so a flat
        // envelope must emit nothing.
        let mut last_f: Vec<f64> =
            (0..n).map(|s| self.factor_at(start[s], duration_ms)).collect();
        let mut next_rate = vec![0usize; n];
        for k in 1..self.steps {
            let t = duration_ms * k as f64 / self.steps as f64;
            let f = self.factor_at(t, duration_ms);
            for s in 0..n {
                // Scenario rate changes up to t reset the session's
                // applied factor to the factor at their own time.
                while next_rate[s] < rates[s].len() && rates[s][next_rate[s]].0 <= t {
                    last_f[s] = self.factor_at(rates[s][next_rate[s]].0, duration_ms);
                    next_rate[s] += 1;
                }
                if start[s] > t || stop[s] <= t || f == last_f[s] {
                    continue;
                }
                // Base (unscaled) mode in force at t: the latest scenario
                // rate change before t, else the declared app mode.
                let base = rates[s][..next_rate[s]]
                    .last()
                    .map(|(_, m)| m)
                    .unwrap_or(&apps[s].mode);
                if let Some(m) = scale_mode(base, f) {
                    events.push(SessionEvent {
                        at_ms: t,
                        kind: EventKind::Rate { session: s, mode: m },
                    });
                    last_f[s] = f;
                }
            }
        }
    }
}

/// Scale a rate-driven arrival mode by `f`; `None` for modes with no
/// rate (closed loop, replay). A factor of exactly 1.0 returns the same
/// numbers bit-for-bit (×1.0 and ÷1.0 are exact), which is what makes
/// the flat envelope a byte-identical no-op.
fn scale_mode(mode: &ArrivalMode, f: f64) -> Option<ArrivalMode> {
    match mode {
        ArrivalMode::Periodic(p) => Some(ArrivalMode::Periodic(p / f)),
        ArrivalMode::Poisson(r) => Some(ArrivalMode::Poisson(r * f)),
        ArrivalMode::Bursty { rate_rps, burst_factor, period_ms } => Some(ArrivalMode::Bursty {
            rate_rps: rate_rps * f,
            burst_factor: *burst_factor,
            period_ms: *period_ms,
        }),
        ArrivalMode::ClosedLoop | ArrivalMode::Replay(_) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grammar_and_defaults() {
        let d = FleetEnvelope::parse("diurnal").unwrap();
        assert_eq!(d.steps, 32);
        assert!(matches!(d.envelope, Envelope::Diurnal { period_ms, .. } if period_ms == 0.0));
        let d = FleetEnvelope::parse("diurnal:period=60000,low=0.5,high=3,steps=8").unwrap();
        assert_eq!(d.steps, 8);
        assert_eq!(
            d.envelope,
            Envelope::Diurnal { period_ms: 60_000.0, low: 0.5, high: 3.0 }
        );
        let f = FleetEnvelope::parse("flash:at=5000,width=2000,mult=6").unwrap();
        assert_eq!(f.envelope, Envelope::Flash { at_ms: 5000.0, width_ms: 2000.0, mult: 6.0 });
        assert!(FleetEnvelope::parse("tsunami").is_err());
        assert!(FleetEnvelope::parse("diurnal:low=0").is_err());
        assert!(FleetEnvelope::parse("diurnal:bogus").is_err());
    }

    #[test]
    fn diurnal_factor_swings_low_high_low() {
        let e = FleetEnvelope::parse("diurnal:low=0.5,high=2").unwrap();
        let d = 10_000.0;
        assert!((e.factor_at(0.0, d) - 0.5).abs() < 1e-9);
        assert!((e.factor_at(d / 2.0, d) - 2.0).abs() < 1e-9);
        assert!((e.factor_at(d, d) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn flash_factor_is_one_outside_the_pulse() {
        let e = FleetEnvelope::parse("flash:at=5000,width=2000,mult=5").unwrap();
        let d = 10_000.0;
        assert_eq!(e.factor_at(0.0, d), 1.0);
        assert_eq!(e.factor_at(3999.0, d), 1.0);
        assert!((e.factor_at(5000.0, d) - 5.0).abs() < 1e-9);
        assert_eq!(e.factor_at(6001.0, d), 1.0);
    }

    #[test]
    fn apply_emits_rate_events_only_on_factor_change() {
        let mut apps = vec![
            App { model: "m".into(), slo_ms: None, mode: ArrivalMode::Poisson(10.0) },
            App::closed_loop("m"),
        ];
        let mut events = Vec::new();
        let e = FleetEnvelope::parse("diurnal:low=0.5,high=2,steps=4").unwrap();
        e.apply(&mut apps, &mut events, 8_000.0);
        // Initial Poisson scaled by factor(0) = low.
        assert_eq!(apps[0].mode, ArrivalMode::Poisson(5.0));
        // Closed loop untouched, and no rate events target it.
        assert_eq!(apps[1].mode, ArrivalMode::ClosedLoop);
        assert!(events
            .iter()
            .all(|ev| matches!(ev.kind, EventKind::Rate { session: 0, .. })));
        // 3 interior boundaries, each with a changed factor for session 0.
        assert_eq!(events.len(), 3);
    }

    #[test]
    fn flat_envelope_emits_nothing_and_rescales_by_one() {
        let mut apps = vec![App {
            model: "m".into(),
            slo_ms: Some(50.0),
            mode: ArrivalMode::Periodic(33.0),
        }];
        let mut events = Vec::new();
        let e = FleetEnvelope::parse("diurnal:low=1,high=1,steps=16").unwrap();
        e.apply(&mut apps, &mut events, 5_000.0);
        assert!(events.is_empty(), "flat envelope must add no events");
        assert_eq!(apps[0].mode, ArrivalMode::Periodic(33.0));
    }
}
