//! Seeded random scenario generation: churn mixes for stress testing and
//! property-based fuzzing (`adms scenario gen --seed N`). The same seed
//! always yields the same scenario (byte-identical JSON), so generated
//! scenarios are shareable repro artifacts.

use super::{Scenario, ScenarioEvent, TimedEvent};
use crate::exec::{App, ArrivalMode};
use crate::util::rng::Pcg32;
use crate::workload::STRESS_POOL;

/// Knobs for the generator.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Number of sessions to admit.
    pub sessions: usize,
    /// Scenario horizon: every event lands in `[0, duration_ms)`.
    pub duration_ms: f64,
    /// Probability that a session is stopped before the horizon.
    pub churn: f64,
    /// Probability that a session gets a mid-run rate change.
    pub rate_change: f64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { sessions: 4, duration_ms: 20_000.0, churn: 0.5, rate_change: 0.5 }
    }
}

fn random_mode(rng: &mut Pcg32) -> ArrivalMode {
    match rng.below(4) {
        0 => ArrivalMode::ClosedLoop,
        1 => ArrivalMode::Periodic(rng.range_f64(20.0, 200.0)),
        2 => ArrivalMode::Poisson(rng.range_f64(2.0, 25.0)),
        _ => ArrivalMode::Bursty {
            rate_rps: rng.range_f64(5.0, 20.0),
            burst_factor: rng.range_f64(2.0, 6.0),
            period_ms: rng.range_f64(500.0, 4_000.0),
        },
    }
}

/// Generate a randomized churn scenario from a seed.
pub fn generate(seed: u64, cfg: &GenConfig) -> Scenario {
    let mut rng = Pcg32::new(seed, 0x5ce0_a41a);
    let n = cfg.sessions.max(1);
    let horizon = cfg.duration_ms.max(1.0);
    let mut sc = Scenario::new(&format!("gen-{seed}"));
    for s in 0..n {
        // The first session starts at 0 so the run always has work; later
        // ones join anywhere in the first two-thirds of the horizon.
        let start = if s == 0 { 0.0 } else { rng.range_f64(0.0, horizon * 2.0 / 3.0) };
        let model = *rng.choose(&STRESS_POOL);
        let slo_ms = if rng.next_f64() < 0.4 {
            Some(rng.range_f64(30.0, 400.0))
        } else {
            None
        };
        let app = App { model: model.into(), slo_ms, mode: random_mode(&mut rng) };
        sc.events
            .push(TimedEvent { at_ms: start, event: ScenarioEvent::SessionStart { app } });
        if rng.next_f64() < cfg.rate_change {
            let at = rng.range_f64(start, horizon);
            sc.events.push(TimedEvent {
                at_ms: at,
                event: ScenarioEvent::RateChange { session: s, mode: random_mode(&mut rng) },
            });
        }
        if rng.next_f64() < cfg.churn {
            let at = rng.range_f64(start, horizon);
            sc.events
                .push(TimedEvent { at_ms: at, event: ScenarioEvent::SessionStop { session: s } });
        }
    }
    sc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn generation_is_deterministic_per_seed() {
        let cfg = GenConfig::default();
        let a = generate(7, &cfg);
        let b = generate(7, &cfg);
        assert_eq!(a.to_json_string(), b.to_json_string());
        let c = generate(8, &cfg);
        assert_ne!(a.to_json_string(), c.to_json_string());
    }

    #[test]
    fn generated_scenarios_compile_and_use_known_models() {
        for seed in 0..20 {
            let sc = generate(seed, &GenConfig::default());
            let (apps, _) = sc.compile().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert!(!apps.is_empty());
            for a in &apps {
                assert!(zoo::by_name(&a.model).is_some());
            }
        }
    }

    #[test]
    fn generated_json_roundtrips() {
        let sc = generate(42, &GenConfig::default());
        let back = Scenario::from_json_str(&sc.to_json_string()).unwrap();
        assert_eq!(back.to_json_string(), sc.to_json_string());
    }
}
