//! JSON (de)serialization of scenarios, apps, and arrival modes via
//! [`crate::util::json`], so dynamic workloads are shareable regression
//! artifacts (`adms serve --scenario file.json`).
//!
//! Format:
//!
//! ```json
//! {
//!   "name": "churn_mix",
//!   "events": [
//!     {"at_ms": 0, "type": "session_start",
//!      "app": {"model": "mobilenet_v1", "slo_ms": null,
//!              "arrival": {"mode": "closed_loop"}}},
//!     {"at_ms": 4000, "type": "rate_change", "session": 0,
//!      "arrival": {"mode": "bursty", "rate_rps": 20,
//!                  "burst_factor": 4, "period_ms": 1000}},
//!     {"at_ms": 9000, "type": "session_stop", "session": 0}
//!   ]
//! }
//! ```

use super::{Scenario, ScenarioEvent, TimedEvent};
use crate::exec::{App, ArrivalMode};
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

pub fn mode_to_json(mode: &ArrivalMode) -> Json {
    match mode {
        ArrivalMode::ClosedLoop => Json::obj(vec![("mode", Json::Str("closed_loop".into()))]),
        ArrivalMode::Periodic(p) => Json::obj(vec![
            ("mode", Json::Str("periodic".into())),
            ("period_ms", Json::Num(*p)),
        ]),
        ArrivalMode::Poisson(r) => Json::obj(vec![
            ("mode", Json::Str("poisson".into())),
            ("rate_rps", Json::Num(*r)),
        ]),
        ArrivalMode::Bursty { rate_rps, burst_factor, period_ms } => Json::obj(vec![
            ("mode", Json::Str("bursty".into())),
            ("rate_rps", Json::Num(*rate_rps)),
            ("burst_factor", Json::Num(*burst_factor)),
            ("period_ms", Json::Num(*period_ms)),
        ]),
        ArrivalMode::Replay(times) => Json::obj(vec![
            ("mode", Json::Str("replay".into())),
            ("times_ms", Json::Arr(times.iter().map(|&t| Json::Num(t)).collect())),
        ]),
    }
}

pub fn mode_from_json(v: &Json) -> Result<ArrivalMode> {
    let num = |key: &str| {
        v.get(key)
            .as_f64()
            .ok_or_else(|| anyhow!("arrival: missing numeric '{key}'"))
    };
    match v
        .get("mode")
        .as_str()
        .ok_or_else(|| anyhow!("arrival: missing 'mode'"))?
    {
        "closed_loop" => Ok(ArrivalMode::ClosedLoop),
        "periodic" => Ok(ArrivalMode::Periodic(num("period_ms")?)),
        "poisson" => Ok(ArrivalMode::Poisson(num("rate_rps")?)),
        "bursty" => Ok(ArrivalMode::Bursty {
            rate_rps: num("rate_rps")?,
            burst_factor: num("burst_factor")?,
            period_ms: num("period_ms")?,
        }),
        "replay" => {
            let times = v
                .get("times_ms")
                .as_arr()
                .ok_or_else(|| anyhow!("replay arrival: missing 'times_ms' array"))?
                .iter()
                .map(|t| t.as_f64().ok_or_else(|| anyhow!("replay arrival: non-numeric time")))
                .collect::<Result<Vec<f64>>>()?;
            Ok(ArrivalMode::Replay(Arc::new(times)))
        }
        other => bail!("unknown arrival mode '{other}'"),
    }
}

pub fn app_to_json(app: &App) -> Json {
    Json::obj(vec![
        ("model", Json::Str(app.model.clone())),
        ("slo_ms", app.slo_ms.map(Json::Num).unwrap_or(Json::Null)),
        ("arrival", mode_to_json(&app.mode)),
    ])
}

pub fn app_from_json(v: &Json) -> Result<App> {
    Ok(App {
        model: v
            .get("model")
            .as_str()
            .ok_or_else(|| anyhow!("app: missing 'model'"))?
            .to_string(),
        slo_ms: v.get("slo_ms").as_f64(),
        mode: mode_from_json(v.get("arrival"))?,
    })
}

pub fn scenario_to_json(sc: &Scenario) -> Json {
    let events: Vec<Json> = sc
        .events
        .iter()
        .map(|te| match &te.event {
            ScenarioEvent::SessionStart { app } => Json::obj(vec![
                ("at_ms", Json::Num(te.at_ms)),
                ("type", Json::Str("session_start".into())),
                ("app", app_to_json(app)),
            ]),
            ScenarioEvent::SessionStop { session } => Json::obj(vec![
                ("at_ms", Json::Num(te.at_ms)),
                ("type", Json::Str("session_stop".into())),
                ("session", Json::Num(*session as f64)),
            ]),
            ScenarioEvent::RateChange { session, mode } => Json::obj(vec![
                ("at_ms", Json::Num(te.at_ms)),
                ("type", Json::Str("rate_change".into())),
                ("session", Json::Num(*session as f64)),
                ("arrival", mode_to_json(mode)),
            ]),
            ScenarioEvent::ProcFail { proc, hang } => Json::obj(vec![
                ("at_ms", Json::Num(te.at_ms)),
                ("type", Json::Str("proc_fail".into())),
                ("proc", Json::Num(*proc as f64)),
                ("hang", Json::Bool(*hang)),
            ]),
            ScenarioEvent::ProcRecover { proc } => Json::obj(vec![
                ("at_ms", Json::Num(te.at_ms)),
                ("type", Json::Str("proc_recover".into())),
                ("proc", Json::Num(*proc as f64)),
            ]),
        })
        .collect();
    Json::obj(vec![
        ("name", Json::Str(sc.name.clone())),
        ("events", Json::Arr(events)),
    ])
}

pub fn scenario_from_json(v: &Json) -> Result<Scenario> {
    let name = v.get("name").as_str().unwrap_or("unnamed").to_string();
    let evs = v
        .get("events")
        .as_arr()
        .ok_or_else(|| anyhow!("scenario: missing 'events' array"))?;
    let mut events = Vec::new();
    for (i, e) in evs.iter().enumerate() {
        let at_ms = e
            .get("at_ms")
            .as_f64()
            .ok_or_else(|| anyhow!("event {i}: missing numeric 'at_ms'"))?;
        let session = || {
            e.get("session")
                .as_u64()
                .map(|s| s as usize)
                .ok_or_else(|| anyhow!("event {i}: missing integer 'session'"))
        };
        let event = match e
            .get("type")
            .as_str()
            .ok_or_else(|| anyhow!("event {i}: missing 'type'"))?
        {
            "session_start" => ScenarioEvent::SessionStart { app: app_from_json(e.get("app"))? },
            "session_stop" => ScenarioEvent::SessionStop { session: session()? },
            "rate_change" => ScenarioEvent::RateChange {
                session: session()?,
                mode: mode_from_json(e.get("arrival"))?,
            },
            "proc_fail" => ScenarioEvent::ProcFail {
                proc: e
                    .get("proc")
                    .as_u64()
                    .map(|p| p as usize)
                    .ok_or_else(|| anyhow!("event {i}: missing integer 'proc'"))?,
                // Absent "hang" means a crash — old documents stay valid.
                hang: e.get("hang").as_bool().unwrap_or(false),
            },
            "proc_recover" => ScenarioEvent::ProcRecover {
                proc: e
                    .get("proc")
                    .as_u64()
                    .map(|p| p as usize)
                    .ok_or_else(|| anyhow!("event {i}: missing integer 'proc'"))?,
            },
            other => bail!("event {i}: unknown type '{other}'"),
        };
        events.push(TimedEvent { at_ms, event });
    }
    Ok(Scenario { name, events })
}

impl Scenario {
    /// Serialize as pretty-printed JSON.
    pub fn to_json_string(&self) -> String {
        scenario_to_json(self).to_pretty()
    }

    /// Parse from a JSON document.
    pub fn from_json_str(s: &str) -> Result<Scenario> {
        scenario_from_json(&parse(s).map_err(|e| anyhow!("{e}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{by_name, SCENARIO_NAMES};

    #[test]
    fn named_scenarios_roundtrip_through_json() {
        for n in SCENARIO_NAMES {
            let sc = by_name(n).unwrap();
            let s = sc.to_json_string();
            let back = Scenario::from_json_str(&s).unwrap_or_else(|e| panic!("{n}: {e}"));
            assert_eq!(back.name, sc.name);
            assert_eq!(back.events.len(), sc.events.len());
            // Second serialization is byte-identical (BTreeMap ordering).
            assert_eq!(back.to_json_string(), s, "{n}: unstable serialization");
        }
    }

    #[test]
    fn modes_roundtrip_exactly() {
        let modes = [
            ArrivalMode::ClosedLoop,
            ArrivalMode::Periodic(33.25),
            ArrivalMode::Poisson(12.5),
            ArrivalMode::Bursty { rate_rps: 20.0, burst_factor: 4.0, period_ms: 1000.0 },
            ArrivalMode::Replay(Arc::new(vec![0.0, 1.5, 3.141592653589793, 1e6 + 0.125])),
        ];
        for m in &modes {
            let j = mode_to_json(m);
            let back = mode_from_json(&parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(&back, m, "mode did not roundtrip: {m:?}");
        }
    }

    #[test]
    fn bad_documents_are_rejected() {
        assert!(Scenario::from_json_str("not json").is_err());
        assert!(Scenario::from_json_str("{}").is_err());
        assert!(Scenario::from_json_str(r#"{"events":[{"at_ms":0,"type":"wat"}]}"#).is_err());
    }
}
