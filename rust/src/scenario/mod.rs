//! Scenario engine: open-system workload descriptions.
//!
//! A [`Scenario`] is a timed event list — sessions joining
//! ([`ScenarioEvent::SessionStart`]), leaving
//! ([`ScenarioEvent::SessionStop`]), and switching arrival processes
//! ([`ScenarioEvent::RateChange`], including the phased
//! [`ArrivalMode::Bursty`] process) — the dynamic multi-DNN mixes the
//! paper's evaluation (§4.4–§4.8) and the Puzzle/AdaOper baselines serve,
//! as opposed to a fixed set of closed-loop sessions declared at t = 0.
//!
//! Scenarios compile to the [`crate::exec::SessionEvent`] form the shared
//! [`Driver`](crate::exec::Driver) consumes, run on **both** execution
//! backends, (de)serialize as JSON ([`json`]), can be generated from a
//! seed for randomized mixes ([`gen`]), and every run can be recorded and
//! replayed bit-for-bit on the sim backend ([`trace`]).

pub mod envelope;
pub mod gen;
pub mod json;
pub mod trace;

pub use envelope::{Envelope, FleetEnvelope};
pub use gen::{generate, GenConfig};
pub use trace::RunTrace;

use crate::exec::{App, ArrivalMode, EventKind, SessionEvent};
use anyhow::{bail, Result};

/// One scenario event. Session ids are allocated by `SessionStart`
/// declaration order; `SessionStop`/`RateChange` must reference an
/// already-declared session.
#[derive(Debug, Clone)]
pub enum ScenarioEvent {
    /// Admit a new session at the event time.
    SessionStart { app: App },
    /// Retire session `session`: pending work cancels, stats close.
    SessionStop { session: usize },
    /// Switch session `session` to a new arrival process.
    RateChange { session: usize, mode: ArrivalMode },
    /// Processor `proc` fails (crash aborts its resident work; hang
    /// strands it until the dispatch-timeout sweep). Out-of-range
    /// processors are driver-side no-ops, so a fault scenario written
    /// against a 4-processor SoC stays valid on a 3-processor one.
    ProcFail { proc: usize, hang: bool },
    /// Processor `proc` comes back (health-aware runs quarantine it as
    /// `Degraded` first).
    ProcRecover { proc: usize },
}

/// A [`ScenarioEvent`] with its firing time.
#[derive(Debug, Clone)]
pub struct TimedEvent {
    pub at_ms: f64,
    pub event: ScenarioEvent,
}

/// A dynamic workload: what joins, leaves, and changes, and when.
#[derive(Debug, Clone, Default)]
pub struct Scenario {
    pub name: String,
    pub events: Vec<TimedEvent>,
}

impl Scenario {
    pub fn new(name: &str) -> Self {
        Scenario { name: name.into(), events: Vec::new() }
    }

    /// Admit `app` at `at_ms`. The new session's id is the number of
    /// `start` calls before this one.
    pub fn start(mut self, at_ms: f64, app: App) -> Self {
        self.events.push(TimedEvent { at_ms, event: ScenarioEvent::SessionStart { app } });
        self
    }

    /// Retire session `session` at `at_ms`.
    pub fn stop(mut self, at_ms: f64, session: usize) -> Self {
        self.events
            .push(TimedEvent { at_ms, event: ScenarioEvent::SessionStop { session } });
        self
    }

    /// Switch session `session` to `mode` at `at_ms`.
    pub fn rate(mut self, at_ms: f64, session: usize, mode: ArrivalMode) -> Self {
        self.events
            .push(TimedEvent { at_ms, event: ScenarioEvent::RateChange { session, mode } });
        self
    }

    /// Crash processor `proc` at `at_ms` (resident work aborts).
    pub fn fail(mut self, at_ms: f64, proc: usize) -> Self {
        self.events
            .push(TimedEvent { at_ms, event: ScenarioEvent::ProcFail { proc, hang: false } });
        self
    }

    /// Hang processor `proc` at `at_ms` (resident work strands until the
    /// dispatch-timeout sweep or the end of the run).
    pub fn hang(mut self, at_ms: f64, proc: usize) -> Self {
        self.events
            .push(TimedEvent { at_ms, event: ScenarioEvent::ProcFail { proc, hang: true } });
        self
    }

    /// Recover processor `proc` at `at_ms`.
    pub fn recover(mut self, at_ms: f64, proc: usize) -> Self {
        self.events
            .push(TimedEvent { at_ms, event: ScenarioEvent::ProcRecover { proc } });
        self
    }

    /// Number of sessions the scenario declares.
    pub fn num_sessions(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.event, ScenarioEvent::SessionStart { .. }))
            .count()
    }

    /// Compile to the driver's form: the full session list plus lifecycle
    /// events. Validates session references and event times.
    pub fn compile(&self) -> Result<(Vec<App>, Vec<SessionEvent>)> {
        self.compile_with_base(0)
    }

    /// [`Scenario::compile`] with session ids offset by `base` (used when
    /// appending a scenario after statically-declared sessions).
    pub fn compile_with_base(&self, base: usize) -> Result<(Vec<App>, Vec<SessionEvent>)> {
        let mut apps: Vec<App> = Vec::new();
        let mut start_at: Vec<f64> = Vec::new();
        let mut events: Vec<SessionEvent> = Vec::new();
        for te in &self.events {
            if !te.at_ms.is_finite() || te.at_ms < 0.0 {
                bail!("event time {} must be a finite non-negative ms value", te.at_ms);
            }
            match &te.event {
                ScenarioEvent::SessionStart { app } => {
                    validate_mode(&app.mode)?;
                    let session = base + apps.len();
                    apps.push(app.clone());
                    start_at.push(te.at_ms);
                    events.push(SessionEvent {
                        at_ms: te.at_ms,
                        kind: EventKind::Start { session },
                    });
                }
                ScenarioEvent::SessionStop { session } => {
                    let Some(&s0) = start_at.get(*session) else {
                        bail!("stop references undeclared session {session}");
                    };
                    if te.at_ms < s0 {
                        bail!(
                            "session {session} stops at {} before it starts at {s0}",
                            te.at_ms
                        );
                    }
                    events.push(SessionEvent {
                        at_ms: te.at_ms,
                        kind: EventKind::Stop { session: base + session },
                    });
                }
                ScenarioEvent::RateChange { session, mode } => {
                    if start_at.get(*session).is_none() {
                        bail!("rate change references undeclared session {session}");
                    }
                    validate_mode(mode)?;
                    events.push(SessionEvent {
                        at_ms: te.at_ms,
                        kind: EventKind::Rate { session: base + session, mode: mode.clone() },
                    });
                }
                // Processor ids are deliberately NOT validated here: the
                // SoC is not known at compile time, and the driver treats
                // out-of-range processors as no-ops, so one fault scenario
                // serves every preset.
                ScenarioEvent::ProcFail { proc, hang } => {
                    events.push(SessionEvent {
                        at_ms: te.at_ms,
                        kind: EventKind::ProcFail { proc: *proc, hang: *hang },
                    });
                }
                ScenarioEvent::ProcRecover { proc } => {
                    events.push(SessionEvent {
                        at_ms: te.at_ms,
                        kind: EventKind::ProcRecover { proc: *proc },
                    });
                }
            }
        }
        if apps.is_empty() {
            bail!("scenario '{}' declares no sessions", self.name);
        }
        Ok((apps, events))
    }
}

/// Reject arrival-mode parameters that would wedge the driver: a
/// non-positive period or rate never advances the clock (the run loop
/// would spin at one instant forever), and a replay schedule must be
/// finite, non-negative, and sorted.
fn validate_mode(mode: &ArrivalMode) -> Result<()> {
    let pos = |v: f64, what: &str| -> Result<()> {
        if !v.is_finite() || v <= 0.0 {
            bail!("arrival {what} must be finite and > 0, got {v}");
        }
        Ok(())
    };
    match mode {
        ArrivalMode::ClosedLoop => Ok(()),
        ArrivalMode::Periodic(p) => pos(*p, "period_ms"),
        ArrivalMode::Poisson(r) => pos(*r, "rate_rps"),
        ArrivalMode::Bursty { rate_rps, burst_factor, period_ms } => {
            pos(*rate_rps, "rate_rps")?;
            pos(*burst_factor, "burst_factor")?;
            pos(*period_ms, "period_ms")
        }
        ArrivalMode::Replay(times) => {
            for &t in times.iter() {
                if !t.is_finite() || t < 0.0 {
                    bail!("replay times must be finite and non-negative, got {t}");
                }
            }
            for w in times.windows(2) {
                if w[1] < w[0] {
                    bail!("replay schedule must be sorted ({} after {})", w[1], w[0]);
                }
            }
            Ok(())
        }
    }
}

/// Named dynamic scenarios accepted by `adms serve --scenario`.
pub const SCENARIO_NAMES: [&str; 9] = [
    "frs_burst",
    "churn_mix",
    "phase_shift",
    "model_churn",
    "cold_start_storm",
    "cache_thrash",
    "fault_storm",
    "flaky_dsp",
    "npu_blackout",
];

/// Look up a named scenario.
pub fn by_name(name: &str) -> Option<Scenario> {
    match name {
        "frs_burst" => Some(frs_burst()),
        "churn_mix" => Some(churn_mix()),
        "phase_shift" => Some(phase_shift()),
        "model_churn" => Some(model_churn()),
        "cold_start_storm" => Some(cold_start_storm()),
        "cache_thrash" => Some(cache_thrash()),
        "fault_storm" => Some(fault_storm()),
        "flaky_dsp" => Some(flaky_dsp()),
        "npu_blackout" => Some(npu_blackout()),
        _ => None,
    }
}

/// The full scenario grammar shared by `adms serve --scenario` and fleet
/// arm specs: a named scenario ([`by_name`]), else a path to a scenario
/// JSON file.
pub fn resolve(name: &str) -> Result<Scenario> {
    if let Some(sc) = by_name(name) {
        return Ok(sc);
    }
    let text = std::fs::read_to_string(name).map_err(|e| {
        anyhow::anyhow!(
            "'{name}': not a named scenario ({}) and not a readable file: {e}",
            SCENARIO_NAMES.join(", ")
        )
    })?;
    Scenario::from_json_str(&text)
}

/// One-line description for `adms scenario list`.
pub fn describe(name: &str) -> &'static str {
    match name {
        "frs_burst" => "FRS with bursty identification load and a heavy model joining mid-run",
        "churn_mix" => "sessions of escalating complexity join every few seconds, earlier ones retire",
        "phase_shift" => "camera pipeline shifting 30 fps -> burst -> 10 fps under a closed-loop classifier",
        "model_churn" => "a rotating cast of models joins and retires so delegate weights churn across processors",
        "cold_start_storm" => "six distinct models all admitted within the first two seconds, every shard cold",
        "cache_thrash" => "alternating heavyweight models whose combined weights exceed any residency budget",
        "fault_storm" => "multi-processor crash/hang/recover churn under a steady multi-DNN mix",
        "flaky_dsp" => "the DSP crashes mid-run and recovers, twice, under SLO-bound vision load",
        "npu_blackout" => "the NPU goes dark for a long window while an NPU-friendly mix keeps arriving",
        _ => "",
    }
}

/// FRS (paper §4.4) made dynamic: RetinaFace detection runs continuously;
/// mobile identification alternates burst/calm phases; the heavy
/// identification model joins at 5 s, slows the mobile one to a periodic
/// camera cadence at 10 s, and leaves at 15 s.
pub fn frs_burst() -> Scenario {
    Scenario::new("frs_burst")
        .start(0.0, App::closed_loop("retinaface"))
        .start(
            0.0,
            App {
                model: "arcface_mobile".into(),
                slo_ms: Some(50.0),
                mode: ArrivalMode::Bursty {
                    rate_rps: 15.0,
                    burst_factor: 4.0,
                    period_ms: 2_000.0,
                },
            },
        )
        .start(
            5_000.0,
            App {
                model: "arcface_resnet50".into(),
                slo_ms: None,
                mode: ArrivalMode::Poisson(5.0),
            },
        )
        .rate(10_000.0, 1, ArrivalMode::Periodic(33.0))
        .stop(15_000.0, 2)
}

/// Open-system churn: apps of escalating complexity join every ~2 s while
/// earlier ones retire — the dynamic multi-DNN mix Puzzle and AdaOper
/// evaluate on.
pub fn churn_mix() -> Scenario {
    Scenario::new("churn_mix")
        .start(0.0, App::closed_loop("mobilenet_v1"))
        .start(
            2_000.0,
            App {
                model: "east".into(),
                slo_ms: Some(120.0),
                mode: ArrivalMode::Periodic(60.0),
            },
        )
        .start(
            4_000.0,
            App { model: "efficientnet4".into(), slo_ms: None, mode: ArrivalMode::Poisson(8.0) },
        )
        .stop(6_000.0, 0)
        .start(6_000.0, App::closed_loop("arcface_mobile"))
        .stop(9_000.0, 1)
        .stop(12_000.0, 3)
}

/// Phase shifts on one camera feed: 30 fps steady, then a bursty phase,
/// then a low-power 10 fps phase — against a closed-loop classifier that
/// soaks up whatever capacity is left.
pub fn phase_shift() -> Scenario {
    Scenario::new("phase_shift")
        .start(
            0.0,
            App {
                model: "mobilenet_v2".into(),
                slo_ms: Some(80.0),
                mode: ArrivalMode::Periodic(1000.0 / 30.0),
            },
        )
        .start(0.0, App::closed_loop("inception_v4"))
        .rate(
            4_000.0,
            0,
            ArrivalMode::Bursty { rate_rps: 30.0, burst_factor: 3.0, period_ms: 1_000.0 },
        )
        .rate(8_000.0, 0, ArrivalMode::Periodic(100.0))
}

/// Weight-residency churn (`--mem-budget` scenarios): a rotating cast of
/// models with disjoint weights joins and retires every ~2.5 s, so the
/// processors' residency domains keep turning over. On an unbudgeted run
/// this is just session churn; under a budget it is the eviction-policy
/// workout.
pub fn model_churn() -> Scenario {
    Scenario::new("model_churn")
        .start(0.0, App::closed_loop("mobilenet_v2"))
        .start(0.0, App::closed_loop("retinaface"))
        .start(
            2_500.0,
            App { model: "east".into(), slo_ms: None, mode: ArrivalMode::Poisson(6.0) },
        )
        .stop(5_000.0, 0)
        .start(5_000.0, App::closed_loop("efficientnet4"))
        .stop(7_500.0, 1)
        .start(
            7_500.0,
            App {
                model: "arcface_mobile".into(),
                slo_ms: Some(60.0),
                mode: ArrivalMode::Periodic(40.0),
            },
        )
        .stop(10_000.0, 2)
        .start(10_000.0, App::closed_loop("handlmk"))
        .stop(12_500.0, 3)
}

/// Cold-start storm: six distinct models are all admitted within the
/// first two seconds of the run, so every first dispatch of every unit
/// on every processor is a cold load. The multi-DNN admission spike is
/// where cache-aware placement (ADMS pricing residency misses) separates
/// most sharply from cache-blind baselines.
pub fn cold_start_storm() -> Scenario {
    Scenario::new("cold_start_storm")
        .start(0.0, App::closed_loop("mobilenet_v1"))
        .start(
            400.0,
            App {
                model: "mobilenet_v2".into(),
                slo_ms: Some(50.0),
                mode: ArrivalMode::Periodic(40.0),
            },
        )
        .start(800.0, App::closed_loop("retinaface"))
        .start(
            1_200.0,
            App {
                model: "arcface_mobile".into(),
                slo_ms: Some(60.0),
                mode: ArrivalMode::Periodic(50.0),
            },
        )
        .start(1_600.0, App::closed_loop("handlmk"))
        .start(
            2_000.0,
            App { model: "east".into(), slo_ms: None, mode: ArrivalMode::Poisson(4.0) },
        )
}

/// Cache thrash: heavyweight models (hundreds of MB of fp32 weights
/// between them) running concurrently, with the heaviest joining mid-run
/// — under a constrained budget every domain's working set exceeds its
/// capacity and eviction policy dominates throughput.
pub fn cache_thrash() -> Scenario {
    Scenario::new("cache_thrash")
        .start(0.0, App::closed_loop("inception_v4"))
        .start(0.0, App { model: "east".into(), slo_ms: None, mode: ArrivalMode::Poisson(3.0) })
        .start(3_000.0, App::closed_loop("arcface_resnet50"))
        .stop(9_000.0, 1)
}

/// Fault storm: a steady three-session mix while the accelerators churn —
/// the GPU crashes and recovers, the DSP hangs (stranding its resident
/// work until the dispatch-timeout sweep), the NPU crashes late. Processor
/// order in every SoC preset is 0=CPU, 1=GPU, 2=DSP, 3=NPU; the CPU is
/// spared so the run always has a fallback.
pub fn fault_storm() -> Scenario {
    Scenario::new("fault_storm")
        .start(0.0, App::closed_loop("mobilenet_v1"))
        .start(
            0.0,
            App {
                model: "retinaface".into(),
                slo_ms: Some(80.0),
                mode: ArrivalMode::Periodic(50.0),
            },
        )
        .start(
            500.0,
            App { model: "east".into(), slo_ms: None, mode: ArrivalMode::Poisson(5.0) },
        )
        .fail(2_000.0, 1)
        .recover(4_000.0, 1)
        .hang(5_000.0, 2)
        .recover(8_000.0, 2)
        .fail(9_000.0, 3)
        .fail(10_000.0, 1)
        .recover(12_000.0, 1)
        .recover(13_000.0, 3)
}

/// Flaky DSP: the DSP (proc 2) crashes mid-run and recovers, twice, under
/// an SLO-bound vision mix that would otherwise lean on it. The acceptance
/// workload for retry + health-aware scheduling: a fault-blind run keeps
/// placing work on the dead processor and strands it.
pub fn flaky_dsp() -> Scenario {
    Scenario::new("flaky_dsp")
        .start(0.0, App::closed_loop("mobilenet_v2"))
        .start(
            0.0,
            App {
                model: "arcface_mobile".into(),
                slo_ms: Some(60.0),
                mode: ArrivalMode::Periodic(40.0),
            },
        )
        .fail(1_500.0, 2)
        .recover(4_000.0, 2)
        .fail(6_000.0, 2)
        .recover(8_500.0, 2)
}

/// NPU blackout: the NPU (proc 3) goes dark for most of the run while an
/// NPU-friendly mix keeps arriving — the long-outage case where degraded-
/// mode placement (everything re-planned across CPU/GPU/DSP) matters more
/// than retry. On SoCs without an NPU the fault events are no-ops and this
/// degenerates to the plain mix.
pub fn npu_blackout() -> Scenario {
    Scenario::new("npu_blackout")
        .start(0.0, App::closed_loop("inception_v4"))
        .start(
            0.0,
            App {
                model: "mobilenet_v1".into(),
                slo_ms: Some(50.0),
                mode: ArrivalMode::Periodic(33.0),
            },
        )
        .fail(1_000.0, 3)
        .recover(9_000.0, 3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn named_scenarios_resolve_and_compile() {
        for n in SCENARIO_NAMES {
            let sc = by_name(n).unwrap_or_else(|| panic!("{n} missing"));
            let (apps, events) = sc.compile().unwrap_or_else(|e| panic!("{n}: {e}"));
            assert!(!apps.is_empty());
            assert!(!events.is_empty());
            for a in &apps {
                assert!(zoo::by_name(&a.model).is_some(), "{n}: unknown model {}", a.model);
            }
            assert!(!describe(n).is_empty());
        }
        assert!(by_name("nope").is_none());
        // `resolve` covers names and falls through to (missing) files.
        assert_eq!(resolve("churn_mix").unwrap().name, "churn_mix");
        assert!(resolve("/no/such/scenario.json").is_err());
    }

    #[test]
    fn compile_rejects_bad_references() {
        let sc = Scenario::new("bad").stop(100.0, 0);
        assert!(sc.compile().is_err(), "stop of undeclared session must fail");
        let sc = Scenario::new("bad2")
            .start(1_000.0, App::closed_loop("mobilenet_v1"))
            .stop(500.0, 0);
        assert!(sc.compile().is_err(), "stop before start must fail");
        let sc = Scenario::new("empty");
        assert!(sc.compile().is_err(), "no sessions must fail");
    }

    #[test]
    fn compile_rejects_degenerate_arrival_parameters() {
        use crate::exec::ArrivalMode;
        // A zero period would pin the clock at one instant forever.
        let app = |mode| App { model: "mobilenet_v1".into(), slo_ms: None, mode };
        for bad in [
            ArrivalMode::Periodic(0.0),
            ArrivalMode::Periodic(-5.0),
            ArrivalMode::Periodic(f64::NAN),
            ArrivalMode::Poisson(0.0),
            ArrivalMode::Bursty { rate_rps: 10.0, burst_factor: 4.0, period_ms: 0.0 },
            ArrivalMode::Replay(std::sync::Arc::new(vec![5.0, 1.0])),
        ] {
            let sc = Scenario::new("bad").start(0.0, app(bad.clone()));
            assert!(sc.compile().is_err(), "start with {bad:?} must be rejected");
            let sc = Scenario::new("bad")
                .start(0.0, App::closed_loop("mobilenet_v1"))
                .rate(10.0, 0, bad.clone());
            assert!(sc.compile().is_err(), "rate change to {bad:?} must be rejected");
        }
    }

    #[test]
    fn fault_events_compile_without_session_validation() {
        // Processor ids are runtime-checked by the driver, not compile-time
        // by the scenario — an out-of-range proc must still compile (it is
        // a driver-side no-op), and `compile_with_base` must not offset
        // processor ids the way it offsets session ids.
        let sc = Scenario::new("f")
            .start(0.0, App::closed_loop("mobilenet_v1"))
            .fail(100.0, 2)
            .hang(200.0, 99)
            .recover(300.0, 2);
        let (_, events) = sc.compile_with_base(5).unwrap();
        assert!(matches!(events[1].kind, EventKind::ProcFail { proc: 2, hang: false }));
        assert!(matches!(events[2].kind, EventKind::ProcFail { proc: 99, hang: true }));
        assert!(matches!(events[3].kind, EventKind::ProcRecover { proc: 2 }));
        // Event times are still validated.
        let sc = Scenario::new("bad")
            .start(0.0, App::closed_loop("mobilenet_v1"))
            .fail(-1.0, 2);
        assert!(sc.compile().is_err(), "negative fault time must be rejected");
    }

    #[test]
    fn compile_with_base_offsets_ids() {
        let sc = Scenario::new("s")
            .start(0.0, App::closed_loop("mobilenet_v1"))
            .stop(10.0, 0);
        let (_, events) = sc.compile_with_base(3).unwrap();
        assert!(matches!(events[0].kind, crate::exec::EventKind::Start { session: 3 }));
        assert!(matches!(events[1].kind, crate::exec::EventKind::Stop { session: 3 }));
    }
}
