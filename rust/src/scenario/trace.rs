//! Run-trace record/replay: any run becomes a reproducible regression
//! artifact.
//!
//! A [`RunTrace`] captures a run's workload-side history — per-session
//! model/SLO/lifecycle, every request **arrival**, and every **dispatch**
//! ([`AssignRecord`]) — exactly the inputs and decisions of the
//! scheduling loop. [`RunTrace::to_replay_scenario`] turns it back into a
//! [`Scenario`] whose sessions use [`ArrivalMode::Replay`]: re-running it
//! on the sim backend with the same scheduler and seed reproduces the
//! original assignment trace and per-session latency/SLO metrics
//! bit-for-bit (the sim backend orders same-instant timers after
//! completions/ticks precisely so a replayed arrival lands where the
//! closed-loop arrival it reproduces did).
//!
//! Caveat: same-instant events of *different* sessions replay in session
//! order; scenarios whose distinct-session start/stop events share an
//! identical f64 timestamp may reorder (measure-zero for generated
//! scenarios).

use super::Scenario;
use crate::exec::{App, ArrivalMode, ArrivalRecord, AssignRecord, EventKind, SessionEvent};
use crate::sim::SimReport;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// One session as recorded: identity plus lifecycle window.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSession {
    pub model: String,
    pub slo_ms: Option<f64>,
    pub start_ms: f64,
    pub stop_ms: Option<f64>,
}

/// A recorded run: everything needed to replay it.
#[derive(Debug, Clone, PartialEq)]
pub struct RunTrace {
    pub scheduler: String,
    pub backend: String,
    /// SoC preset name (`soc_by_name`) the run executed on — the cost
    /// model and processor set are run-defining inputs, so a replay must
    /// use the same one.
    pub soc: String,
    pub seed: u64,
    pub duration_ms: f64,
    /// Group-dispatch config the run executed under (`1`/`0.0` =
    /// unbatched — omitted from the JSON so pre-batching traces and
    /// unbatched recordings stay byte-identical).
    pub batch_max: usize,
    pub batch_window_ms: f64,
    pub sessions: Vec<TraceSession>,
    /// Rate-change event times from the recorded scenario, `(session,
    /// at_ms)`. Replays re-fire them (re-arming the replay schedule) so
    /// the replay sees the exact same event → dispatch-round structure —
    /// a missing round would leave queued tasks waiting where the
    /// original dispatched them.
    pub rate_events: Vec<(usize, f64)>,
    /// Scenario-driven processor fault events, `(at_ms, proc, code)` with
    /// code 0 = crash, 1 = hang, 2 = recover. Profile-generated faults are
    /// *not* listed: the driver re-derives them deterministically from the
    /// [`TraceFaults`] knobs at replay time (same profile, SoC, seed, and
    /// duration → byte-identical plan).
    pub fault_events: Vec<(f64, usize, u8)>,
    /// Fault-layer config the run executed under. `None` = fault layer off
    /// — omitted from the JSON so faults-off (and pre-fault) traces keep
    /// their exact bytes.
    pub faults: Option<TraceFaults>,
    /// Adaptive re-partition config (and the switch schedule the run
    /// produced, for audits). `None` = adaptive off — omitted from the
    /// JSON so static traces keep their exact bytes.
    pub adaptive: Option<TraceAdaptive>,
}

/// The fault-layer knobs a replay must restore to reproduce a faulted run:
/// detection/retry config plus the generative profile (if any).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceFaults {
    pub dispatch_timeout_mult: f64,
    pub retry_limit: u32,
    pub retry_backoff_ms: f64,
    pub quarantine_ms: f64,
    pub profile: Option<crate::faults::FaultProfile>,
    pub fault_seed: Option<u64>,
    pub blind: bool,
}

impl TraceFaults {
    /// Copy the recorded knobs onto a replay config.
    pub fn apply_to(&self, cfg: &mut crate::exec::SimConfig) {
        cfg.dispatch_timeout_mult = self.dispatch_timeout_mult;
        cfg.retry_limit = self.retry_limit;
        cfg.retry_backoff_ms = self.retry_backoff_ms;
        cfg.fault_quarantine_ms = self.quarantine_ms;
        cfg.fault_profile = self.profile.clone();
        cfg.fault_seed = self.fault_seed;
        cfg.fault_blind = self.blind;
    }
}

/// The adaptive re-partition knobs a replay must restore, plus the
/// switch events the recorded run applied. The events are *not* replayed
/// as inputs — the controller re-derives every switch deterministically
/// from the same knobs, monitor signal, and seed — they are recorded so
/// replay audits can compare the reproduced schedule bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceAdaptive {
    /// `AdaptivePlan` CLI spelling (`"reactive"`).
    pub mode: String,
    pub cooldown_ms: f64,
    pub threshold: f64,
    /// `(time_ms, session, new_window_size)` per applied switch.
    pub events: Vec<(f64, usize, usize)>,
}

impl TraceAdaptive {
    /// Copy the recorded knobs onto a replay config.
    pub fn apply_to(&self, cfg: &mut crate::exec::SimConfig) {
        if let Some(mode) = crate::exec::AdaptivePlan::parse(&self.mode) {
            cfg.adaptive_plan = mode;
        }
        cfg.replan_cooldown_ms = self.cooldown_ms;
        cfg.replan_threshold = self.threshold;
    }
}

impl RunTrace {
    /// Record a finished run. `soc` is the preset name the run executed
    /// on; `apps` must be the session list the run was built from (it
    /// carries the SLOs, which the report does not) and `events` the
    /// lifecycle events it ran under (empty for static workloads).
    pub fn record(
        soc: &str,
        apps: &[App],
        events: &[SessionEvent],
        report: &SimReport,
        seed: u64,
    ) -> RunTrace {
        let sessions = report
            .sessions
            .iter()
            .enumerate()
            .map(|(i, s)| TraceSession {
                model: s.model.clone(),
                slo_ms: apps.get(i).and_then(|a| a.slo_ms),
                start_ms: s.start_ms,
                stop_ms: s.stop_ms,
            })
            .collect();
        // Only rate changes that actually fired matter (starts/stops are
        // reconstructed from the per-session lifecycle windows above).
        let rate_events = events
            .iter()
            .filter(|e| e.at_ms <= report.duration_ms)
            .filter_map(|e| match e.kind {
                EventKind::Rate { session, .. } => Some((session, e.at_ms)),
                _ => None,
            })
            .collect();
        // Scenario-driven faults replay as scenario events; transients and
        // profile-generated faults are regenerated from the TraceFaults
        // knobs instead (see `with_faults`).
        let fault_events = events
            .iter()
            .filter(|e| e.at_ms <= report.duration_ms)
            .filter_map(|e| match e.kind {
                EventKind::ProcFail { proc, hang } => {
                    Some((e.at_ms, proc, if hang { 1 } else { 0 }))
                }
                EventKind::ProcRecover { proc } => Some((e.at_ms, proc, 2)),
                _ => None,
            })
            .collect();
        RunTrace {
            scheduler: report.scheduler.clone(),
            backend: report.backend.clone(),
            soc: soc.to_string(),
            seed,
            duration_ms: report.duration_ms,
            batch_max: 1,
            batch_window_ms: 0.0,
            sessions,
            rate_events,
            arrivals: report.arrivals.clone(),
            assignments: report.assignments.clone(),
            fault_events,
            faults: None,
            adaptive: None,
        }
    }

    /// Stamp the fault-layer config the run executed under (no-op for a
    /// faults-off run, so faults-off traces keep their exact bytes).
    pub fn with_faults(mut self, cfg: &crate::exec::SimConfig) -> Self {
        if cfg.faults_configured() || !self.fault_events.is_empty() {
            self.faults = Some(TraceFaults {
                dispatch_timeout_mult: cfg.dispatch_timeout_mult,
                retry_limit: cfg.retry_limit,
                retry_backoff_ms: cfg.retry_backoff_ms,
                quarantine_ms: cfg.fault_quarantine_ms,
                profile: cfg.fault_profile.clone(),
                fault_seed: cfg.fault_seed,
                blind: cfg.fault_blind,
            });
        }
        self
    }

    /// Stamp the adaptive re-partition config the run executed under and
    /// the switch schedule it produced (no-op for an adaptive-off run, so
    /// static traces keep their exact bytes).
    pub fn with_adaptive(
        mut self,
        cfg: &crate::exec::SimConfig,
        report: &SimReport,
    ) -> Self {
        if cfg.adaptive_configured() {
            self.adaptive = Some(TraceAdaptive {
                mode: cfg.adaptive_plan.name().to_string(),
                cooldown_ms: cfg.replan_cooldown_ms,
                threshold: cfg.replan_threshold,
                events: report
                    .replans
                    .as_ref()
                    .map(|r| r.events.clone())
                    .unwrap_or_default(),
            });
        }
        self
    }

    /// Stamp the group-dispatch config the run executed under, so a
    /// replay can re-run it batched (a batched trace replayed unbatched
    /// would legitimately diverge).
    pub fn with_batch(mut self, batch_max: usize, batch_window_ms: f64) -> Self {
        self.batch_max = batch_max.max(1);
        self.batch_window_ms = batch_window_ms.max(0.0);
        self
    }

    /// Rebuild the run as a scenario of [`ArrivalMode::Replay`] sessions:
    /// every recorded arrival fires at its recorded time, session
    /// admission/retirement happens at the recorded times, and recorded
    /// rate changes re-fire as `Rate` events that re-arm the same replay
    /// schedule (preserving the dispatch-round structure).
    pub fn to_replay_scenario(&self) -> Scenario {
        let mut times: Vec<Vec<f64>> = vec![Vec::new(); self.sessions.len()];
        for a in &self.arrivals {
            if a.session < times.len() {
                times[a.session].push(a.at);
            }
        }
        let schedules: Vec<Arc<Vec<f64>>> =
            times.into_iter().map(Arc::new).collect();
        let mut sc = Scenario::new("replay");
        for (s, ts) in self.sessions.iter().enumerate() {
            let app = App {
                model: ts.model.clone(),
                slo_ms: ts.slo_ms,
                mode: ArrivalMode::Replay(Arc::clone(&schedules[s])),
            };
            sc = sc.start(ts.start_ms, app);
            if let Some(stop) = ts.stop_ms {
                sc = sc.stop(stop, s);
            }
        }
        for &(s, at) in &self.rate_events {
            if s < schedules.len() {
                sc = sc.rate(at, s, ArrivalMode::Replay(Arc::clone(&schedules[s])));
            }
        }
        for &(at, p, code) in &self.fault_events {
            sc = match code {
                0 => sc.fail(at, p),
                1 => sc.hang(at, p),
                _ => sc.recover(at, p),
            };
        }
        sc
    }

    /// Serialize as pretty-printed JSON (arrivals/assignments as compact
    /// tuples to keep long traces small).
    pub fn to_json_string(&self) -> String {
        let sessions: Vec<Json> = self
            .sessions
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("model", Json::Str(s.model.clone())),
                    ("slo_ms", s.slo_ms.map(Json::Num).unwrap_or(Json::Null)),
                    ("start_ms", Json::Num(s.start_ms)),
                    ("stop_ms", s.stop_ms.map(Json::Num).unwrap_or(Json::Null)),
                ])
            })
            .collect();
        let arrivals: Vec<Json> = self
            .arrivals
            .iter()
            .map(|a| Json::Arr(vec![Json::Num(a.session as f64), Json::Num(a.at)]))
            .collect();
        // Group dispatches use the shared flattened row form
        // (`AssignRecord::to_row`): the member list rides on the classic
        // four-tuple, and single-task records stay exactly the old
        // four-tuple, keeping unbatched traces byte-identical.
        let assignments: Vec<Json> = self
            .assignments
            .iter()
            .map(|a| Json::Arr(a.to_row().into_iter().map(Json::Num).collect()))
            .collect();
        let rate_events: Vec<Json> = self
            .rate_events
            .iter()
            .map(|&(s, at)| Json::Arr(vec![Json::Num(s as f64), Json::Num(at)]))
            .collect();
        let mut fields = vec![
            ("version", Json::Num(1.0)),
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("soc", Json::Str(self.soc.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("duration_ms", Json::Num(self.duration_ms)),
        ];
        // Batch config only when the run was actually batched, so
        // unbatched (and pre-batching) traces keep their exact bytes.
        if self.batch_max > 1 {
            fields.push(("batch_max", Json::Num(self.batch_max as f64)));
            fields.push(("batch_window_ms", Json::Num(self.batch_window_ms)));
        }
        // Fault layer only when it was active — same byte-identity rule.
        let fault_events: Vec<Json> = self
            .fault_events
            .iter()
            .map(|&(at, p, code)| {
                Json::Arr(vec![Json::Num(at), Json::Num(p as f64), Json::Num(code as f64)])
            })
            .collect();
        if !fault_events.is_empty() {
            fields.push(("fault_events", Json::Arr(fault_events)));
        }
        if let Some(f) = &self.faults {
            fields.push((
                "faults",
                Json::obj(vec![
                    ("dispatch_timeout_mult", Json::Num(f.dispatch_timeout_mult)),
                    ("retry_limit", Json::Num(f.retry_limit as f64)),
                    ("retry_backoff_ms", Json::Num(f.retry_backoff_ms)),
                    ("quarantine_ms", Json::Num(f.quarantine_ms)),
                    ("blind", Json::Bool(f.blind)),
                    (
                        "fault_seed",
                        f.fault_seed.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null),
                    ),
                    (
                        "profile",
                        f.profile
                            .as_ref()
                            .map(|p| {
                                Json::obj(vec![
                                    ("name", Json::Str(p.name.clone())),
                                    ("crash_per_s", Json::Num(p.crash_per_s)),
                                    ("hang_per_s", Json::Num(p.hang_per_s)),
                                    ("transient_per_s", Json::Num(p.transient_per_s)),
                                    ("mttr_ms", Json::Num(p.mttr_ms)),
                                ])
                            })
                            .unwrap_or(Json::Null),
                    ),
                ]),
            ));
        }
        // Adaptive re-partitioning only when it was engaged — same
        // byte-identity rule as the batch and fault blocks.
        if let Some(a) = &self.adaptive {
            let events: Vec<Json> = a
                .events
                .iter()
                .map(|&(at, s, ws)| {
                    Json::Arr(vec![
                        Json::Num(at),
                        Json::Num(s as f64),
                        Json::Num(ws as f64),
                    ])
                })
                .collect();
            fields.push((
                "adaptive",
                Json::obj(vec![
                    ("mode", Json::Str(a.mode.clone())),
                    ("cooldown_ms", Json::Num(a.cooldown_ms)),
                    ("threshold", Json::Num(a.threshold)),
                    ("events", Json::Arr(events)),
                ]),
            ));
        }
        fields.extend([
            ("sessions", Json::Arr(sessions)),
            ("rate_events", Json::Arr(rate_events)),
            ("arrivals", Json::Arr(arrivals)),
            ("assignments", Json::Arr(assignments)),
        ]);
        Json::obj(fields).to_pretty()
    }

    pub fn from_json_str(s: &str) -> Result<RunTrace> {
        let v = parse(s).map_err(|e| anyhow!("{e}"))?;
        let sessions = v
            .get("sessions")
            .as_arr()
            .ok_or_else(|| anyhow!("trace: missing 'sessions'"))?
            .iter()
            .map(|s| {
                Ok(TraceSession {
                    model: s
                        .get("model")
                        .as_str()
                        .ok_or_else(|| anyhow!("trace session: missing 'model'"))?
                        .to_string(),
                    slo_ms: s.get("slo_ms").as_f64(),
                    start_ms: s.get("start_ms").as_f64().unwrap_or(0.0),
                    stop_ms: s.get("stop_ms").as_f64(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let tuple = |j: &Json, n: usize, what: &str| -> Result<Vec<f64>> {
            let arr = j
                .as_arr()
                .ok_or_else(|| anyhow!("trace: malformed {what} entry"))?;
            if arr.len() != n {
                bail!("trace: {what} entry has {} fields, expected {n}", arr.len());
            }
            arr.iter()
                .map(|x| x.as_f64().ok_or_else(|| anyhow!("trace: non-numeric {what} field")))
                .collect()
        };
        let rate_events = v
            .get("rate_events")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|a| {
                let t = tuple(a, 2, "rate_event")?;
                Ok((t[0] as usize, t[1]))
            })
            .collect::<Result<Vec<_>>>()?;
        let fault_events = v
            .get("fault_events")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|a| {
                let t = tuple(a, 3, "fault_event")?;
                Ok((t[0], t[1] as usize, t[2] as u8))
            })
            .collect::<Result<Vec<(f64, usize, u8)>>>()?;
        let faults = v.get("faults").as_obj().map(|_| {
            let f = v.get("faults");
            let p = f.get("profile");
            TraceFaults {
                dispatch_timeout_mult: f.get("dispatch_timeout_mult").as_f64().unwrap_or(0.0),
                retry_limit: f.get("retry_limit").as_u64().unwrap_or(0) as u32,
                retry_backoff_ms: f.get("retry_backoff_ms").as_f64().unwrap_or(0.0),
                quarantine_ms: f.get("quarantine_ms").as_f64().unwrap_or(0.0),
                blind: f.get("blind").as_bool().unwrap_or(false),
                fault_seed: f.get("fault_seed").as_u64(),
                profile: p.as_obj().map(|_| crate::faults::FaultProfile {
                    name: p.get("name").as_str().unwrap_or("custom").to_string(),
                    crash_per_s: p.get("crash_per_s").as_f64().unwrap_or(0.0),
                    hang_per_s: p.get("hang_per_s").as_f64().unwrap_or(0.0),
                    transient_per_s: p.get("transient_per_s").as_f64().unwrap_or(0.0),
                    mttr_ms: p.get("mttr_ms").as_f64().unwrap_or(300.0),
                }),
            }
        });
        let adaptive = match v.get("adaptive").as_obj() {
            Some(_) => {
                let a = v.get("adaptive");
                let events = a
                    .get("events")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|e| {
                        let t = tuple(e, 3, "adaptive event")?;
                        Ok((t[0], t[1] as usize, t[2] as usize))
                    })
                    .collect::<Result<Vec<(f64, usize, usize)>>>()?;
                Some(TraceAdaptive {
                    mode: a.get("mode").as_str().unwrap_or("reactive").to_string(),
                    cooldown_ms: a.get("cooldown_ms").as_f64().unwrap_or(0.0),
                    threshold: a.get("threshold").as_f64().unwrap_or(0.5),
                    events,
                })
            }
            None => None,
        };
        let arrivals = v
            .get("arrivals")
            .as_arr()
            .ok_or_else(|| anyhow!("trace: missing 'arrivals'"))?
            .iter()
            .map(|a| {
                let t = tuple(a, 2, "arrival")?;
                Ok(ArrivalRecord { session: t[0] as usize, at: t[1] })
            })
            .collect::<Result<Vec<_>>>()?;
        let assignments = v
            .get("assignments")
            .as_arr()
            .ok_or_else(|| anyhow!("trace: missing 'assignments'"))?
            .iter()
            .map(|a| {
                // The shared flattened row form (`AssignRecord::to_row`):
                // [req, session, unit, proc] plus an even number of
                // (member_req, member_session) pairs.
                let arr =
                    a.as_arr().ok_or_else(|| anyhow!("trace: malformed assignment entry"))?;
                let nums = arr
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .ok_or_else(|| anyhow!("trace: non-numeric assignment field"))
                    })
                    .collect::<Result<Vec<f64>>>()?;
                AssignRecord::from_row(&nums).ok_or_else(|| {
                    anyhow!(
                        "trace: assignment entry has {} fields, expected 4 + 2·members",
                        nums.len()
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RunTrace {
            scheduler: v
                .get("scheduler")
                .as_str()
                .ok_or_else(|| anyhow!("trace: missing 'scheduler'"))?
                .to_string(),
            backend: v
                .get("backend")
                .as_str()
                .ok_or_else(|| anyhow!("trace: missing 'backend'"))?
                .to_string(),
            soc: v
                .get("soc")
                .as_str()
                .ok_or_else(|| anyhow!("trace: missing 'soc'"))?
                .to_string(),
            seed: v
                .get("seed")
                .as_u64()
                .ok_or_else(|| anyhow!("trace: missing integer 'seed'"))?,
            duration_ms: v
                .get("duration_ms")
                .as_f64()
                .ok_or_else(|| anyhow!("trace: missing 'duration_ms'"))?,
            batch_max: v.get("batch_max").as_u64().map(|b| (b as usize).max(1)).unwrap_or(1),
            batch_window_ms: v.get("batch_window_ms").as_f64().unwrap_or(0.0).max(0.0),
            sessions,
            rate_events,
            fault_events,
            faults,
            adaptive,
            arrivals,
            assignments,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> RunTrace {
        RunTrace {
            scheduler: "adms".into(),
            backend: "sim".into(),
            soc: "kirin970".into(),
            seed: 7,
            duration_ms: 1234.5,
            batch_max: 1,
            batch_window_ms: 0.0,
            sessions: vec![
                TraceSession {
                    model: "mobilenet_v1".into(),
                    slo_ms: Some(40.0),
                    start_ms: 0.0,
                    stop_ms: Some(900.25),
                },
                TraceSession {
                    model: "east".into(),
                    slo_ms: None,
                    start_ms: 100.125,
                    stop_ms: None,
                },
            ],
            rate_events: vec![(0, 500.5)],
            fault_events: Vec::new(),
            faults: None,
            adaptive: None,
            arrivals: vec![
                ArrivalRecord { session: 0, at: 0.0 },
                ArrivalRecord { session: 1, at: 100.125 },
                ArrivalRecord { session: 0, at: 33.375 },
            ],
            assignments: vec![AssignRecord::single(0, 0, 0, 3)],
        }
    }

    #[test]
    fn trace_roundtrips_through_json() {
        let t = tiny_trace();
        let s = t.to_json_string();
        // Unbatched traces keep the classic shape: no batch fields, and
        // assignments as plain four-tuples.
        assert!(!s.contains("batch_max"));
        let back = RunTrace::from_json_str(&s).unwrap();
        assert_eq!(back, t);
    }

    /// A batched trace round-trips its batch config and the member lists
    /// of group dispatches.
    #[test]
    fn batched_trace_roundtrips_members_and_config() {
        let mut t = tiny_trace().with_batch(4, 6.5);
        t.assignments = vec![
            AssignRecord::single(0, 0, 0, 3),
            AssignRecord {
                req: 1,
                session: 0,
                unit: 0,
                proc: 3,
                members: vec![(2, 1), (3, 1)],
            },
        ];
        let s = t.to_json_string();
        assert!(s.contains("batch_max"));
        let back = RunTrace::from_json_str(&s).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.assignments[1].group_size(), 3);
    }

    /// A faulted trace round-trips its fault events and knobs; a faults-off
    /// trace serializes without any fault keys (byte-identity with
    /// pre-fault recordings).
    #[test]
    fn faulted_trace_roundtrips_and_off_trace_has_no_fault_keys() {
        let off = tiny_trace().to_json_string();
        assert!(!off.contains("fault_events") && !off.contains("\"faults\""));

        let mut t = tiny_trace();
        t.fault_events = vec![(800.0, 2, 0), (900.0, 3, 1), (1_100.5, 2, 2)];
        t.faults = Some(TraceFaults {
            dispatch_timeout_mult: 4.0,
            retry_limit: 3,
            retry_backoff_ms: 25.0,
            quarantine_ms: 500.0,
            profile: Some(crate::faults::FaultProfile::light()),
            fault_seed: Some(99),
            blind: false,
        });
        let s = t.to_json_string();
        let back = RunTrace::from_json_str(&s).unwrap();
        assert_eq!(back, t);

        // Replay re-fires the recorded fault events as scenario events.
        let (_, events) = t.to_replay_scenario().compile().unwrap();
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, EventKind::ProcFail { proc: 2, hang: false })));
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, EventKind::ProcFail { proc: 3, hang: true })));
        assert!(events.iter().any(|e| matches!(e.kind, EventKind::ProcRecover { proc: 2 })));

        // The knob copier restores the recorded config.
        let mut cfg = crate::exec::SimConfig::default();
        t.faults.as_ref().unwrap().apply_to(&mut cfg);
        assert_eq!(cfg.dispatch_timeout_mult, 4.0);
        assert_eq!(cfg.retry_limit, 3);
        assert_eq!(cfg.fault_seed, Some(99));
        assert_eq!(cfg.fault_profile.as_ref().unwrap().name, "light");
    }

    /// An adaptive trace round-trips its knobs and switch schedule; an
    /// adaptive-off trace serializes without the key (byte-identity with
    /// pre-adaptive recordings).
    #[test]
    fn adaptive_trace_roundtrips_and_off_trace_has_no_adaptive_key() {
        let off = tiny_trace().to_json_string();
        assert!(!off.contains("\"adaptive\""));

        let mut t = tiny_trace();
        t.adaptive = Some(TraceAdaptive {
            mode: "reactive".into(),
            cooldown_ms: 750.0,
            threshold: 0.6,
            events: vec![(1000.0, 0, 4), (2500.0, 1, 1)],
        });
        let s = t.to_json_string();
        let back = RunTrace::from_json_str(&s).unwrap();
        assert_eq!(back, t);

        // The knob copier restores the recorded config.
        let mut cfg = crate::exec::SimConfig::default();
        t.adaptive.as_ref().unwrap().apply_to(&mut cfg);
        assert_eq!(cfg.adaptive_plan, crate::exec::AdaptivePlan::Reactive);
        assert_eq!(cfg.replan_cooldown_ms, 750.0);
        assert_eq!(cfg.replan_threshold, 0.6);
    }

    #[test]
    fn replay_scenario_carries_schedules_and_stops() {
        let t = tiny_trace();
        let sc = t.to_replay_scenario();
        let (apps, events) = sc.compile().unwrap();
        assert_eq!(apps.len(), 2);
        match &apps[0].mode {
            ArrivalMode::Replay(times) => assert_eq!(**times, vec![0.0, 33.375]),
            other => panic!("expected replay mode, got {other:?}"),
        }
        assert_eq!(apps[0].slo_ms, Some(40.0));
        // 2 starts + 1 stop + 1 rate re-fire.
        assert_eq!(events.len(), 4);
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, EventKind::Rate { session: 0, .. }) && e.at_ms == 500.5));
    }

    #[test]
    fn malformed_traces_are_rejected() {
        assert!(RunTrace::from_json_str("[]").is_err());
        assert!(RunTrace::from_json_str(r#"{"sessions":[]}"#).is_err());
    }
}
