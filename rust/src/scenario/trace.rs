//! Run-trace record/replay: any run becomes a reproducible regression
//! artifact.
//!
//! A [`RunTrace`] captures a run's workload-side history — per-session
//! model/SLO/lifecycle, every request **arrival**, and every **dispatch**
//! ([`AssignRecord`]) — exactly the inputs and decisions of the
//! scheduling loop. [`RunTrace::to_replay_scenario`] turns it back into a
//! [`Scenario`] whose sessions use [`ArrivalMode::Replay`]: re-running it
//! on the sim backend with the same scheduler and seed reproduces the
//! original assignment trace and per-session latency/SLO metrics
//! bit-for-bit (the sim backend orders same-instant timers after
//! completions/ticks precisely so a replayed arrival lands where the
//! closed-loop arrival it reproduces did).
//!
//! Caveat: same-instant events of *different* sessions replay in session
//! order; scenarios whose distinct-session start/stop events share an
//! identical f64 timestamp may reorder (measure-zero for generated
//! scenarios).

use super::Scenario;
use crate::exec::{App, ArrivalMode, ArrivalRecord, AssignRecord, EventKind, SessionEvent};
use crate::sim::SimReport;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// One session as recorded: identity plus lifecycle window.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSession {
    pub model: String,
    pub slo_ms: Option<f64>,
    pub start_ms: f64,
    pub stop_ms: Option<f64>,
}

/// A recorded run: everything needed to replay it.
#[derive(Debug, Clone, PartialEq)]
pub struct RunTrace {
    pub scheduler: String,
    pub backend: String,
    /// SoC preset name (`soc_by_name`) the run executed on — the cost
    /// model and processor set are run-defining inputs, so a replay must
    /// use the same one.
    pub soc: String,
    pub seed: u64,
    pub duration_ms: f64,
    /// Group-dispatch config the run executed under (`1`/`0.0` =
    /// unbatched — omitted from the JSON so pre-batching traces and
    /// unbatched recordings stay byte-identical).
    pub batch_max: usize,
    pub batch_window_ms: f64,
    pub sessions: Vec<TraceSession>,
    /// Rate-change event times from the recorded scenario, `(session,
    /// at_ms)`. Replays re-fire them (re-arming the replay schedule) so
    /// the replay sees the exact same event → dispatch-round structure —
    /// a missing round would leave queued tasks waiting where the
    /// original dispatched them.
    pub rate_events: Vec<(usize, f64)>,
    pub arrivals: Vec<ArrivalRecord>,
    pub assignments: Vec<AssignRecord>,
}

impl RunTrace {
    /// Record a finished run. `soc` is the preset name the run executed
    /// on; `apps` must be the session list the run was built from (it
    /// carries the SLOs, which the report does not) and `events` the
    /// lifecycle events it ran under (empty for static workloads).
    pub fn record(
        soc: &str,
        apps: &[App],
        events: &[SessionEvent],
        report: &SimReport,
        seed: u64,
    ) -> RunTrace {
        let sessions = report
            .sessions
            .iter()
            .enumerate()
            .map(|(i, s)| TraceSession {
                model: s.model.clone(),
                slo_ms: apps.get(i).and_then(|a| a.slo_ms),
                start_ms: s.start_ms,
                stop_ms: s.stop_ms,
            })
            .collect();
        // Only rate changes that actually fired matter (starts/stops are
        // reconstructed from the per-session lifecycle windows above).
        let rate_events = events
            .iter()
            .filter(|e| e.at_ms <= report.duration_ms)
            .filter_map(|e| match e.kind {
                EventKind::Rate { session, .. } => Some((session, e.at_ms)),
                _ => None,
            })
            .collect();
        RunTrace {
            scheduler: report.scheduler.clone(),
            backend: report.backend.clone(),
            soc: soc.to_string(),
            seed,
            duration_ms: report.duration_ms,
            batch_max: 1,
            batch_window_ms: 0.0,
            sessions,
            rate_events,
            arrivals: report.arrivals.clone(),
            assignments: report.assignments.clone(),
        }
    }

    /// Stamp the group-dispatch config the run executed under, so a
    /// replay can re-run it batched (a batched trace replayed unbatched
    /// would legitimately diverge).
    pub fn with_batch(mut self, batch_max: usize, batch_window_ms: f64) -> Self {
        self.batch_max = batch_max.max(1);
        self.batch_window_ms = batch_window_ms.max(0.0);
        self
    }

    /// Rebuild the run as a scenario of [`ArrivalMode::Replay`] sessions:
    /// every recorded arrival fires at its recorded time, session
    /// admission/retirement happens at the recorded times, and recorded
    /// rate changes re-fire as `Rate` events that re-arm the same replay
    /// schedule (preserving the dispatch-round structure).
    pub fn to_replay_scenario(&self) -> Scenario {
        let mut times: Vec<Vec<f64>> = vec![Vec::new(); self.sessions.len()];
        for a in &self.arrivals {
            if a.session < times.len() {
                times[a.session].push(a.at);
            }
        }
        let schedules: Vec<Arc<Vec<f64>>> =
            times.into_iter().map(Arc::new).collect();
        let mut sc = Scenario::new("replay");
        for (s, ts) in self.sessions.iter().enumerate() {
            let app = App {
                model: ts.model.clone(),
                slo_ms: ts.slo_ms,
                mode: ArrivalMode::Replay(Arc::clone(&schedules[s])),
            };
            sc = sc.start(ts.start_ms, app);
            if let Some(stop) = ts.stop_ms {
                sc = sc.stop(stop, s);
            }
        }
        for &(s, at) in &self.rate_events {
            if s < schedules.len() {
                sc = sc.rate(at, s, ArrivalMode::Replay(Arc::clone(&schedules[s])));
            }
        }
        sc
    }

    /// Serialize as pretty-printed JSON (arrivals/assignments as compact
    /// tuples to keep long traces small).
    pub fn to_json_string(&self) -> String {
        let sessions: Vec<Json> = self
            .sessions
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("model", Json::Str(s.model.clone())),
                    ("slo_ms", s.slo_ms.map(Json::Num).unwrap_or(Json::Null)),
                    ("start_ms", Json::Num(s.start_ms)),
                    ("stop_ms", s.stop_ms.map(Json::Num).unwrap_or(Json::Null)),
                ])
            })
            .collect();
        let arrivals: Vec<Json> = self
            .arrivals
            .iter()
            .map(|a| Json::Arr(vec![Json::Num(a.session as f64), Json::Num(a.at)]))
            .collect();
        // Group dispatches use the shared flattened row form
        // (`AssignRecord::to_row`): the member list rides on the classic
        // four-tuple, and single-task records stay exactly the old
        // four-tuple, keeping unbatched traces byte-identical.
        let assignments: Vec<Json> = self
            .assignments
            .iter()
            .map(|a| Json::Arr(a.to_row().into_iter().map(Json::Num).collect()))
            .collect();
        let rate_events: Vec<Json> = self
            .rate_events
            .iter()
            .map(|&(s, at)| Json::Arr(vec![Json::Num(s as f64), Json::Num(at)]))
            .collect();
        let mut fields = vec![
            ("version", Json::Num(1.0)),
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("soc", Json::Str(self.soc.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("duration_ms", Json::Num(self.duration_ms)),
        ];
        // Batch config only when the run was actually batched, so
        // unbatched (and pre-batching) traces keep their exact bytes.
        if self.batch_max > 1 {
            fields.push(("batch_max", Json::Num(self.batch_max as f64)));
            fields.push(("batch_window_ms", Json::Num(self.batch_window_ms)));
        }
        fields.extend([
            ("sessions", Json::Arr(sessions)),
            ("rate_events", Json::Arr(rate_events)),
            ("arrivals", Json::Arr(arrivals)),
            ("assignments", Json::Arr(assignments)),
        ]);
        Json::obj(fields).to_pretty()
    }

    pub fn from_json_str(s: &str) -> Result<RunTrace> {
        let v = parse(s).map_err(|e| anyhow!("{e}"))?;
        let sessions = v
            .get("sessions")
            .as_arr()
            .ok_or_else(|| anyhow!("trace: missing 'sessions'"))?
            .iter()
            .map(|s| {
                Ok(TraceSession {
                    model: s
                        .get("model")
                        .as_str()
                        .ok_or_else(|| anyhow!("trace session: missing 'model'"))?
                        .to_string(),
                    slo_ms: s.get("slo_ms").as_f64(),
                    start_ms: s.get("start_ms").as_f64().unwrap_or(0.0),
                    stop_ms: s.get("stop_ms").as_f64(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let tuple = |j: &Json, n: usize, what: &str| -> Result<Vec<f64>> {
            let arr = j
                .as_arr()
                .ok_or_else(|| anyhow!("trace: malformed {what} entry"))?;
            if arr.len() != n {
                bail!("trace: {what} entry has {} fields, expected {n}", arr.len());
            }
            arr.iter()
                .map(|x| x.as_f64().ok_or_else(|| anyhow!("trace: non-numeric {what} field")))
                .collect()
        };
        let rate_events = v
            .get("rate_events")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|a| {
                let t = tuple(a, 2, "rate_event")?;
                Ok((t[0] as usize, t[1]))
            })
            .collect::<Result<Vec<_>>>()?;
        let arrivals = v
            .get("arrivals")
            .as_arr()
            .ok_or_else(|| anyhow!("trace: missing 'arrivals'"))?
            .iter()
            .map(|a| {
                let t = tuple(a, 2, "arrival")?;
                Ok(ArrivalRecord { session: t[0] as usize, at: t[1] })
            })
            .collect::<Result<Vec<_>>>()?;
        let assignments = v
            .get("assignments")
            .as_arr()
            .ok_or_else(|| anyhow!("trace: missing 'assignments'"))?
            .iter()
            .map(|a| {
                // The shared flattened row form (`AssignRecord::to_row`):
                // [req, session, unit, proc] plus an even number of
                // (member_req, member_session) pairs.
                let arr =
                    a.as_arr().ok_or_else(|| anyhow!("trace: malformed assignment entry"))?;
                let nums = arr
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .ok_or_else(|| anyhow!("trace: non-numeric assignment field"))
                    })
                    .collect::<Result<Vec<f64>>>()?;
                AssignRecord::from_row(&nums).ok_or_else(|| {
                    anyhow!(
                        "trace: assignment entry has {} fields, expected 4 + 2·members",
                        nums.len()
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RunTrace {
            scheduler: v
                .get("scheduler")
                .as_str()
                .ok_or_else(|| anyhow!("trace: missing 'scheduler'"))?
                .to_string(),
            backend: v
                .get("backend")
                .as_str()
                .ok_or_else(|| anyhow!("trace: missing 'backend'"))?
                .to_string(),
            soc: v
                .get("soc")
                .as_str()
                .ok_or_else(|| anyhow!("trace: missing 'soc'"))?
                .to_string(),
            seed: v
                .get("seed")
                .as_u64()
                .ok_or_else(|| anyhow!("trace: missing integer 'seed'"))?,
            duration_ms: v
                .get("duration_ms")
                .as_f64()
                .ok_or_else(|| anyhow!("trace: missing 'duration_ms'"))?,
            batch_max: v.get("batch_max").as_u64().map(|b| (b as usize).max(1)).unwrap_or(1),
            batch_window_ms: v.get("batch_window_ms").as_f64().unwrap_or(0.0).max(0.0),
            sessions,
            rate_events,
            arrivals,
            assignments,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> RunTrace {
        RunTrace {
            scheduler: "adms".into(),
            backend: "sim".into(),
            soc: "kirin970".into(),
            seed: 7,
            duration_ms: 1234.5,
            batch_max: 1,
            batch_window_ms: 0.0,
            sessions: vec![
                TraceSession {
                    model: "mobilenet_v1".into(),
                    slo_ms: Some(40.0),
                    start_ms: 0.0,
                    stop_ms: Some(900.25),
                },
                TraceSession {
                    model: "east".into(),
                    slo_ms: None,
                    start_ms: 100.125,
                    stop_ms: None,
                },
            ],
            rate_events: vec![(0, 500.5)],
            arrivals: vec![
                ArrivalRecord { session: 0, at: 0.0 },
                ArrivalRecord { session: 1, at: 100.125 },
                ArrivalRecord { session: 0, at: 33.375 },
            ],
            assignments: vec![AssignRecord::single(0, 0, 0, 3)],
        }
    }

    #[test]
    fn trace_roundtrips_through_json() {
        let t = tiny_trace();
        let s = t.to_json_string();
        // Unbatched traces keep the classic shape: no batch fields, and
        // assignments as plain four-tuples.
        assert!(!s.contains("batch_max"));
        let back = RunTrace::from_json_str(&s).unwrap();
        assert_eq!(back, t);
    }

    /// A batched trace round-trips its batch config and the member lists
    /// of group dispatches.
    #[test]
    fn batched_trace_roundtrips_members_and_config() {
        let mut t = tiny_trace().with_batch(4, 6.5);
        t.assignments = vec![
            AssignRecord::single(0, 0, 0, 3),
            AssignRecord {
                req: 1,
                session: 0,
                unit: 0,
                proc: 3,
                members: vec![(2, 1), (3, 1)],
            },
        ];
        let s = t.to_json_string();
        assert!(s.contains("batch_max"));
        let back = RunTrace::from_json_str(&s).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.assignments[1].group_size(), 3);
    }

    #[test]
    fn replay_scenario_carries_schedules_and_stops() {
        let t = tiny_trace();
        let sc = t.to_replay_scenario();
        let (apps, events) = sc.compile().unwrap();
        assert_eq!(apps.len(), 2);
        match &apps[0].mode {
            ArrivalMode::Replay(times) => assert_eq!(**times, vec![0.0, 33.375]),
            other => panic!("expected replay mode, got {other:?}"),
        }
        assert_eq!(apps[0].slo_ms, Some(40.0));
        // 2 starts + 1 stop + 1 rate re-fire.
        assert_eq!(events.len(), 4);
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, EventKind::Rate { session: 0, .. }) && e.at_ms == 500.5));
    }

    #[test]
    fn malformed_traces_are_rejected() {
        assert!(RunTrace::from_json_str("[]").is_err());
        assert!(RunTrace::from_json_str(r#"{"sessions":[]}"#).is_err());
    }
}
