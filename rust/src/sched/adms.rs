//! The ADMS scheduler (paper §3.4): processor-state-aware, multi-factor
//! priority scheduling.
//!
//! Two separable decisions per dispatch round, over the first
//! `loop_call_size` tasks at the ready-queue head:
//!
//! **Task ordering** uses the paper's priority model (Eqs 1–4), lowest
//! score first:
//! * `S_deadline = γ·(T_SLO − T_latency)` — small slack ⇒ small score ⇒
//!   scheduled sooner (Eq 1);
//! * `S_wait = −α·(T_current − T_enqueue)/T_avg` — long normalized waits
//!   push the score down, preventing starvation of complex tasks (Eq 2);
//! * `S_resource = δ·((2·B_current − B_max)/B_max)·C_remaining` — positive
//!   (deprioritizing) when the task's candidate processor is more than
//!   half loaded, negative when lightly loaded (Eq 3);
//! * `S_priority = S_deadline + S_wait + S_resource` (Eq 4).
//!
//! **Placement** maps the selected task to the processor minimizing its
//! state-aware expected completion: monitored-frequency execution estimate
//! (a throttled GPU is priced at its throttled speed) + backlog + tensor
//! transfers + a thermal-headroom penalty proportional to the task's cost
//! (§3.4: hot processors receive less computationally intensive tasks).

use super::{free_slot_census_into, Assignment, PendingTask, SchedCtx, Scheduler};
use crate::soc::cost;
use crate::TimeMs;

/// Tunable weights (γ, α, δ) and the decision-window size.
#[derive(Debug, Clone)]
pub struct AdmsConfig {
    pub gamma: f64,
    pub alpha: f64,
    pub delta: f64,
    /// How many queue-head tasks each decision round considers (§3.4).
    pub loop_call_size: usize,
    /// Backlog level treated as "full" for Eq 3's `B_max`, in ms.
    pub b_max_ms: f64,
    /// Thermal penalty per °C beyond (throttle − margin), per ms of task.
    pub thermal_penalty: f64,
    /// Headroom margin in °C at which the penalty starts.
    pub thermal_margin_c: f64,
}

impl Default for AdmsConfig {
    fn default() -> Self {
        AdmsConfig {
            gamma: 1.0,
            alpha: 1.0,
            delta: 1.0,
            loop_call_size: 5,
            b_max_ms: 50.0,
            thermal_penalty: 1.0,
            thermal_margin_c: 12.0,
        }
    }
}

#[derive(Debug, Default)]
pub struct Adms {
    pub cfg: AdmsConfig,
    // Per-decision scratch, reused across calls so the dispatch loop's
    // steady state performs no allocations.
    free: Vec<usize>,
    backlog_bump: Vec<TimeMs>,
    taken: Vec<bool>,
}

impl Adms {
    pub fn new(cfg: AdmsConfig) -> Self {
        Adms { cfg, ..Default::default() }
    }

    /// State-aware expected-completion cost of running `t` on `proc`
    /// (`extra_backlog` accounts for same-round commitments). `None` if
    /// the processor is offline or does not support the unit.
    pub fn placement_cost(
        &self,
        ctx: &SchedCtx,
        t: &PendingTask,
        proc: usize,
        extra_backlog: TimeMs,
    ) -> Option<f64> {
        let plan = &ctx.plans[t.session];
        let view = &ctx.procs[proc];
        if view.offline {
            return None;
        }
        // Price at the *monitored* frequency, not nameplate.
        let exec = plan.exec_estimate(t.unit, proc, view.freq_scale.max(0.05))?;
        let xfer: f64 = t
            .dep_procs
            .iter()
            .enumerate()
            .map(|(k, &(dep_unit, dep_proc))| {
                let bytes = plan.xfer_bytes_at(t.unit, k, dep_unit);
                cost::transfer_ms(ctx.soc, dep_proc, proc, bytes)
            })
            .sum();
        // Thermal-headroom penalty: steer heavy work off hot processors.
        let over = (self.cfg.thermal_margin_c - view.headroom_c).max(0.0);
        let s_thermal = self.cfg.thermal_penalty * over * exec;
        Some(view.backlog_ms + extra_backlog + exec + xfer + s_thermal)
    }

    /// Eq 4 priority for task `t` given its candidate completion estimate
    /// on processor `proc`. Lower = dispatched earlier.
    pub fn priority(
        &self,
        ctx: &SchedCtx,
        t: &PendingTask,
        proc: usize,
        t_latency: TimeMs,
    ) -> f64 {
        let plan = &ctx.plans[t.session];
        let view = &ctx.procs[proc];

        // Eq 1: deadline slack. Without an SLO, fall back to 1.5× the
        // plan's end-to-end estimate as the expected response time.
        let t_slo = t.slo_ms.unwrap_or(plan.est_total_ms * 1.5);
        let elapsed = ctx.now - t.req_arrival;
        let s_deadline =
            self.cfg.gamma * ((t_slo - elapsed) - (t_latency + t.remaining_ms));

        // Eq 2: waiting fairness, normalized by average unit time.
        let wait = (ctx.now - t.ready_at).max(0.0);
        let s_wait = -self.cfg.alpha * wait / plan.avg_unit_ms;

        // Eq 3: resource efficiency at the candidate processor.
        let s_resource = self.cfg.delta
            * ((2.0 * view.backlog_ms - self.cfg.b_max_ms) / self.cfg.b_max_ms)
            * t.remaining_ms;

        s_deadline + s_wait + s_resource
    }
}

impl Scheduler for Adms {
    fn name(&self) -> &'static str {
        "adms"
    }

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask], out: &mut Vec<Assignment>) {
        // Scratch is moved out for the duration of the call (placement
        // costing needs `&self`) and restored at the end, so repeated
        // decisions reuse the same buffers.
        let mut free = std::mem::take(&mut self.free);
        let mut backlog_bump = std::mem::take(&mut self.backlog_bump);
        let mut taken = std::mem::take(&mut self.taken);
        free_slot_census_into(ctx, &mut free);
        backlog_bump.clear();
        backlog_bump.resize(ctx.soc.num_processors(), 0.0);
        taken.clear();
        taken.resize(ready.len(), false);
        let window = self.cfg.loop_call_size.max(1);

        // Each round: within the decision window, find each task's best
        // placement, rank tasks by Eq 4, commit the lowest; repeat until
        // no capacity or no candidates remain.
        loop {
            let mut best: Option<(usize, usize, f64)> = None; // (idx, proc, priority)
            let mut considered = 0;
            for (idx, t) in ready.iter().enumerate() {
                if taken[idx] {
                    continue;
                }
                considered += 1;
                if considered > window {
                    break;
                }
                // Best placement for this task.
                let mut placed: Option<(usize, f64)> = None;
                for p in 0..ctx.soc.num_processors() {
                    if free[p] == 0 {
                        continue;
                    }
                    if let Some(c) = self.placement_cost(ctx, t, p, backlog_bump[p]) {
                        if placed.map(|(_, pc)| c < pc).unwrap_or(true) {
                            placed = Some((p, c));
                        }
                    }
                }
                let Some((p, completion)) = placed else { continue };
                let prio = self.priority(ctx, t, p, completion);
                if best.map(|(_, _, b)| prio < b).unwrap_or(true) {
                    best = Some((idx, p, prio));
                }
            }
            match best {
                Some((idx, p, _)) => {
                    taken[idx] = true;
                    free[p] -= 1;
                    let t = &ready[idx];
                    let exec = ctx.plans[t.session]
                        .exec_estimate(t.unit, p, ctx.procs[p].freq_scale.max(0.05))
                        .unwrap_or(0.0);
                    backlog_bump[p] += exec;
                    out.push(Assignment { ready_idx: idx, proc: p });
                }
                None => break,
            }
        }
        self.free = free;
        self.backlog_bump = backlog_bump;
        self.taken = taken;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::ProcView;
    use crate::sched::ModelPlan;
    use crate::soc::dimensity9000;
    use crate::zoo;
    use std::sync::Arc;

    fn views(soc: &crate::soc::SocSpec) -> Vec<ProcView> {
        soc.processors
            .iter()
            .enumerate()
            .map(|(id, p)| ProcView {
                id,
                kind: p.kind,
                temp_c: 30.0,
                freq_mhz: p.max_freq(),
                freq_scale: 1.0,
                offline: false,
                load: 0.0,
                backlog_ms: 0.0,
                active_sessions: 0,
                util: 0.0,
                headroom_c: p.throttle_temp_c - 30.0,
            })
            .collect()
    }

    fn pending(unit: usize, now: f64) -> PendingTask {
        PendingTask {
            req: 0,
            session: 0,
            unit,
            ready_at: now,
            req_arrival: now,
            slo_ms: Some(50.0),
            remaining_ms: 5.0,
            dep_procs: vec![],
        }
    }

    fn run_sched(s: &mut Adms, ctx: &SchedCtx, ready: &[PendingTask]) -> Vec<Assignment> {
        let mut out = Vec::new();
        s.schedule(ctx, ready, &mut out);
        out
    }

    #[test]
    fn assigns_ready_tasks_to_supported_procs() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let v = views(&soc);
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v };
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let a = run_sched(&mut s, &ctx, &ready);
        assert_eq!(a.len(), 1);
        let proc = a[0].proc;
        assert!(plans[0].partition.units[0].supports(proc));
    }

    #[test]
    fn hot_processor_is_avoided_when_alternative_exists() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let mut v = views(&soc);
        // Find the proc ADMS picks when everything is cool…
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v };
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let cool_choice = run_sched(&mut s, &ctx, &ready)[0].proc;
        // …then overheat it and expect a different choice.
        v[cool_choice].temp_c = 67.5;
        v[cool_choice].headroom_c = 0.5;
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v };
        let hot_choice = run_sched(&mut s, &ctx, &ready)[0].proc;
        assert_ne!(hot_choice, cool_choice, "scheduler ignored thermal state");
    }

    #[test]
    fn loaded_processor_is_deprioritized() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let mut v = views(&soc);
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v };
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let first = run_sched(&mut s, &ctx, &ready)[0].proc;
        v[first].backlog_ms = 500.0; // far beyond B_max
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v };
        let second = run_sched(&mut s, &ctx, &ready)[0].proc;
        assert_ne!(second, first, "scheduler ignored backlog");
    }

    #[test]
    fn throttled_frequency_raises_estimates() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let v = views(&soc);
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v };
        let s = Adms::default();
        let t = pending(0, 0.0);
        let base = s.placement_cost(&ctx, &t, 0, 0.0).unwrap();
        let mut v2 = views(&soc);
        v2[0].freq_scale = 0.33;
        let ctx2 = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v2 };
        let slow = s.placement_cost(&ctx2, &t, 0, 0.0).unwrap();
        assert!(slow > base, "throttled estimate not reflected: {slow} vs {base}");
    }

    #[test]
    fn waiting_lowers_priority_score() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let v = views(&soc);
        let s = Adms::default();
        let mut t = pending(0, 0.0);
        let ctx = SchedCtx { now: 100.0, soc: &soc, plans: &plans, procs: &v };
        t.ready_at = 99.0;
        let fresh = s.priority(&ctx, &t, 0, 5.0);
        t.ready_at = 0.0; // has waited 100 ms
        let waited = s.priority(&ctx, &t, 0, 5.0);
        assert!(waited < fresh, "long wait should lower (prioritize) the score");
    }

    #[test]
    fn tighter_deadline_lowers_priority_score() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let v = views(&soc);
        let s = Adms::default();
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v };
        let mut tight = pending(0, 0.0);
        tight.slo_ms = Some(10.0);
        let mut loose = pending(0, 0.0);
        loose.slo_ms = Some(500.0);
        assert!(
            s.priority(&ctx, &tight, 0, 5.0) < s.priority(&ctx, &loose, 0, 5.0),
            "tight deadline must rank first"
        );
    }

    #[test]
    fn offline_processor_never_selected() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let mut v = views(&soc);
        for view in v.iter_mut().skip(1) {
            view.offline = true;
        }
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &v };
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let a = run_sched(&mut s, &ctx, &ready);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].proc, 0, "only the CPU is online");
    }
}
