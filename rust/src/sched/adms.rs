//! The ADMS scheduler (paper §3.4): processor-state-aware, multi-factor
//! priority scheduling.
//!
//! Two separable decisions per dispatch round, over the first
//! `loop_call_size` tasks at the ready-queue head:
//!
//! **Task ordering** uses the paper's priority model (Eqs 1–4), lowest
//! score first:
//! * `S_deadline = γ·(T_SLO − T_latency)` — small slack ⇒ small score ⇒
//!   scheduled sooner (Eq 1);
//! * `S_wait = −α·(T_current − T_enqueue)/T_avg` — long normalized waits
//!   push the score down, preventing starvation of complex tasks (Eq 2);
//! * `S_resource = δ·((2·B_current − B_max)/B_max)·C_remaining` — positive
//!   (deprioritizing) when the task's candidate processor is more than
//!   half loaded, negative when lightly loaded (Eq 3);
//! * `S_priority = S_deadline + S_wait + S_resource` (Eq 4).
//!
//! **Placement** maps the selected task to the processor minimizing its
//! state-aware expected completion: monitored-frequency execution estimate
//! (a throttled GPU is priced at its throttled speed) + backlog + tensor
//! transfers + a thermal-headroom penalty proportional to the task's cost
//! (§3.4: hot processors receive less computationally intensive tasks).

use super::{free_slot_census_into, Assignment, PendingTask, SchedCtx, Scheduler};
use crate::soc::cost;
use crate::TimeMs;

/// Tunable weights (γ, α, δ) and the decision-window size.
#[derive(Debug, Clone)]
pub struct AdmsConfig {
    pub gamma: f64,
    pub alpha: f64,
    pub delta: f64,
    /// How many queue-head tasks each decision round considers (§3.4).
    pub loop_call_size: usize,
    /// Backlog level treated as "full" for Eq 3's `B_max`, in ms.
    pub b_max_ms: f64,
    /// Thermal penalty per °C beyond (throttle − margin), per ms of task.
    pub thermal_penalty: f64,
    /// Headroom margin in °C at which the penalty starts.
    pub thermal_margin_c: f64,
}

impl Default for AdmsConfig {
    fn default() -> Self {
        AdmsConfig {
            gamma: 1.0,
            alpha: 1.0,
            delta: 1.0,
            loop_call_size: 5,
            b_max_ms: 50.0,
            thermal_penalty: 1.0,
            thermal_margin_c: 12.0,
        }
    }
}

#[derive(Debug, Default)]
pub struct Adms {
    pub cfg: AdmsConfig,
    // Per-decision scratch, reused across calls so the dispatch loop's
    // steady state performs no allocations.
    free: Vec<usize>,
    backlog_bump: Vec<TimeMs>,
    taken: Vec<bool>,
    members: Vec<usize>,
}

impl Adms {
    pub fn new(cfg: AdmsConfig) -> Self {
        Adms { cfg, ..Default::default() }
    }

    /// Deadline slack of one task (the Eq 1 budget remaining): SLO — or
    /// the 1.5× end-to-end fallback without one — minus the time already
    /// elapsed since the request arrived.
    fn slack_ms(&self, ctx: &SchedCtx, t: &PendingTask) -> f64 {
        let plan = &ctx.plans[t.session];
        let t_slo = t.slo_ms.unwrap_or(plan.est_total_ms * 1.5);
        t_slo - (ctx.now - t.req_arrival)
    }

    /// State-aware expected-completion cost of running a group of `batch`
    /// fused instances of `t` on `proc` (`extra_backlog` accounts for
    /// same-round commitments; `batch = 1` is the classic single-task
    /// price). The execution term follows the per-processor batch curve
    /// ([`cost::batch_latency_ms`]) at the *monitored* frequency. `None`
    /// if the processor is offline or does not support the unit.
    pub fn placement_cost(
        &self,
        ctx: &SchedCtx,
        t: &PendingTask,
        proc: usize,
        extra_backlog: TimeMs,
        batch: usize,
    ) -> Option<f64> {
        let plan = &ctx.plans[t.session];
        let view = &ctx.procs[proc];
        if view.offline || view.health == crate::monitor::Health::Down {
            return None;
        }
        // Price at the monitored frequency, not nameplate. The batch
        // curve applies to the full-frequency unit cost; `b = 1` reduces
        // to `exec_estimate` bit-exactly.
        let full = cost::batch_latency_ms(
            &ctx.soc.processors[proc],
            plan.exec_ms[t.unit][proc]?,
            batch,
        );
        let exec = full / view.freq_scale.max(crate::sched::ModelPlan::FREQ_FLOOR);
        // The driver charges a group the SUM of every member's transfer
        // costs; members share the lead's unit and dependency structure,
        // so estimate that as batch × the lead's (exact at batch = 1 —
        // `x * 1.0 ≡ x` — and whenever members' dep placements match the
        // lead's).
        let xfer: f64 = t
            .dep_procs
            .iter()
            .enumerate()
            .map(|(k, &(dep_unit, dep_proc))| {
                let bytes = plan.xfer_bytes_at(t.unit, k, dep_unit);
                cost::transfer_ms(ctx.soc, dep_proc, proc, bytes)
            })
            .sum::<f64>()
            * batch as f64;
        // Thermal-headroom penalty: steer heavy work off hot processors.
        let over = (self.cfg.thermal_margin_c - view.headroom_c).max(0.0);
        let s_thermal = self.cfg.thermal_penalty * over * exec;
        // Weight-residency miss price: what the driver will charge to
        // cold-load (or wait on) this unit's shard on `proc`. Exactly
        // 0.0 on unbudgeted runs (`WeightsView::OFF`), keeping this sum
        // bit-identical to the cache-blind cost there. This is what
        // makes ADMS cache-aware: a slower processor whose shard is
        // warm can beat a faster one that must stream weights first.
        let load = ctx.residency_miss_ms(t.session, t.unit, proc);
        // Quarantine re-pricing: a processor that just recovered from a
        // fault is schedulable but not yet trusted — price its execution
        // at double until the driver promotes it back to `Up`, so work
        // probes it only when it still wins at 2×. `Up` adds exactly 0.0,
        // keeping faults-off costs bit-identical.
        let s_health =
            if view.health == crate::monitor::Health::Degraded { exec } else { 0.0 };
        Some(view.backlog_ms + extra_backlog + exec + xfer + s_thermal + load + s_health)
    }

    /// Eq 4 with the deadline term evaluated on an explicit slack — for
    /// a group dispatch the *minimum* slack over its members, so a batch
    /// is never scheduled later than its most urgent request warrants.
    fn priority_with_slack(
        &self,
        ctx: &SchedCtx,
        t: &PendingTask,
        proc: usize,
        t_latency: TimeMs,
        slack_ms: f64,
    ) -> f64 {
        let plan = &ctx.plans[t.session];
        let view = &ctx.procs[proc];

        // Eq 1: deadline slack (see `slack_ms`).
        let s_deadline = self.cfg.gamma * (slack_ms - (t_latency + t.remaining_ms));

        // Eq 2: waiting fairness, normalized by average unit time.
        let wait = (ctx.now - t.ready_at).max(0.0);
        let s_wait = -self.cfg.alpha * wait / plan.avg_unit_ms;

        // Eq 3: resource efficiency at the candidate processor.
        let s_resource = self.cfg.delta
            * ((2.0 * view.backlog_ms - self.cfg.b_max_ms) / self.cfg.b_max_ms)
            * t.remaining_ms;

        s_deadline + s_wait + s_resource
    }

    /// Eq 4 priority for task `t` given its candidate completion estimate
    /// on processor `proc`. Lower = dispatched earlier.
    pub fn priority(
        &self,
        ctx: &SchedCtx,
        t: &PendingTask,
        proc: usize,
        t_latency: TimeMs,
    ) -> f64 {
        self.priority_with_slack(ctx, t, proc, t_latency, self.slack_ms(ctx, t))
    }
}

impl Scheduler for Adms {
    fn name(&self) -> &'static str {
        "adms"
    }

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask], out: &mut Vec<Assignment>) {
        // Scratch is moved out for the duration of the call (placement
        // costing needs `&self`) and restored at the end, so repeated
        // decisions reuse the same buffers.
        let mut free = std::mem::take(&mut self.free);
        let mut backlog_bump = std::mem::take(&mut self.backlog_bump);
        let mut taken = std::mem::take(&mut self.taken);
        let mut members = std::mem::take(&mut self.members);
        free_slot_census_into(ctx, &mut free);
        backlog_bump.clear();
        backlog_bump.resize(ctx.soc.num_processors(), 0.0);
        taken.clear();
        taken.resize(ready.len(), false);
        let window = self.cfg.loop_call_size.max(1);
        let batching = ctx.batch.enabled();

        // Each round: within the decision window, find each task's (or,
        // under batching, each group's) best placement, rank by Eq 4,
        // commit the lowest; repeat until no capacity or no candidates
        // remain. A group occupies ONE slot — the fused execution is a
        // single kernel invocation — priced off the batch curve, and its
        // deadline term uses the minimum slack over its members.
        loop {
            let mut best: Option<(usize, usize, f64, usize)> = None; // (idx, proc, prio, b)
            let mut considered = 0;
            for (idx, t) in ready.iter().enumerate() {
                if taken[idx] {
                    continue;
                }
                considered += 1;
                if considered > window {
                    break;
                }
                let b = if batching { ctx.batch.group_limit(idx, &taken) } else { 1 };
                // Best placement for this task/group.
                let mut placed: Option<(usize, f64)> = None;
                for p in 0..ctx.soc.num_processors() {
                    if free[p] == 0 {
                        continue;
                    }
                    if let Some(c) = self.placement_cost(ctx, t, p, backlog_bump[p], b) {
                        if placed.map(|(_, pc)| c < pc).unwrap_or(true) {
                            placed = Some((p, c));
                        }
                    }
                }
                let Some((p, completion)) = placed else { continue };
                // Group slack: the most urgent member drives Eq 1.
                let mut slack = self.slack_ms(ctx, t);
                if b > 1 {
                    members.clear();
                    ctx.batch.members(idx, b, &taken, &mut members);
                    for &m in &members {
                        slack = slack.min(self.slack_ms(ctx, &ready[m]));
                    }
                }
                let prio = self.priority_with_slack(ctx, t, p, completion, slack);
                if best.map(|(_, _, bp, _)| prio < bp).unwrap_or(true) {
                    best = Some((idx, p, prio, b));
                }
            }
            match best {
                Some((idx, p, _, b)) => {
                    taken[idx] = true;
                    if b > 1 {
                        // Reserve the members so later rounds (and the
                        // driver) see the same group this price assumed.
                        members.clear();
                        ctx.batch.members(idx, b, &taken, &mut members);
                        for &m in &members {
                            taken[m] = true;
                        }
                    }
                    free[p] -= 1;
                    let t = &ready[idx];
                    let view_fs = ctx.procs[p].freq_scale;
                    let exec = ctx.plans[t.session]
                        .exec_ms[t.unit][p]
                        .map(|full| {
                            cost::batch_latency_ms(&ctx.soc.processors[p], full, b)
                                / view_fs.max(crate::sched::ModelPlan::FREQ_FLOOR)
                        })
                        .unwrap_or(0.0);
                    backlog_bump[p] += exec;
                    out.push(Assignment { ready_idx: idx, proc: p, batch: b });
                }
                None => break,
            }
        }
        self.free = free;
        self.backlog_bump = backlog_bump;
        self.taken = taken;
        self.members = members;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::ProcView;
    use crate::sched::ModelPlan;
    use crate::soc::dimensity9000;
    use crate::zoo;
    use std::sync::Arc;

    fn views(soc: &crate::soc::SocSpec) -> Vec<ProcView> {
        soc.processors
            .iter()
            .enumerate()
            .map(|(id, p)| ProcView::nameplate(id, p, 30.0))
            .collect()
    }

    fn mk_ctx<'a>(
        now: f64,
        soc: &'a crate::soc::SocSpec,
        plans: &'a [ModelPlan],
        procs: &'a [ProcView],
    ) -> SchedCtx<'a> {
        SchedCtx {
            now,
            soc,
            plans,
            procs,
            batch: crate::sched::BatchCtx::OFF,
            weights: crate::sched::WeightsView::OFF,
            variants: None,
        }
    }

    fn pending(unit: usize, now: f64) -> PendingTask {
        PendingTask {
            req: 0,
            session: 0,
            unit,
            ready_at: now,
            req_arrival: now,
            slo_ms: Some(50.0),
            remaining_ms: 5.0,
            dep_procs: vec![],
        }
    }

    fn run_sched(s: &mut Adms, ctx: &SchedCtx, ready: &[PendingTask]) -> Vec<Assignment> {
        let mut out = Vec::new();
        s.schedule(ctx, ready, &mut out);
        out
    }

    #[test]
    fn assigns_ready_tasks_to_supported_procs() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let v = views(&soc);
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let a = run_sched(&mut s, &ctx, &ready);
        assert_eq!(a.len(), 1);
        let proc = a[0].proc;
        assert!(plans[0].partition.units[0].supports(proc));
    }

    #[test]
    fn hot_processor_is_avoided_when_alternative_exists() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let mut v = views(&soc);
        // Find the proc ADMS picks when everything is cool…
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let cool_choice = run_sched(&mut s, &ctx, &ready)[0].proc;
        // …then overheat it and expect a different choice.
        v[cool_choice].temp_c = 67.5;
        v[cool_choice].headroom_c = 0.5;
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let hot_choice = run_sched(&mut s, &ctx, &ready)[0].proc;
        assert_ne!(hot_choice, cool_choice, "scheduler ignored thermal state");
    }

    #[test]
    fn loaded_processor_is_deprioritized() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let mut v = views(&soc);
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let first = run_sched(&mut s, &ctx, &ready)[0].proc;
        v[first].backlog_ms = 500.0; // far beyond B_max
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let second = run_sched(&mut s, &ctx, &ready)[0].proc;
        assert_ne!(second, first, "scheduler ignored backlog");
    }

    #[test]
    fn throttled_frequency_raises_estimates() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let v = views(&soc);
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let s = Adms::default();
        let t = pending(0, 0.0);
        let base = s.placement_cost(&ctx, &t, 0, 0.0, 1).unwrap();
        let mut v2 = views(&soc);
        v2[0].freq_scale = 0.33;
        let ctx2 = mk_ctx(0.0, &soc, &plans, &v2);
        let slow = s.placement_cost(&ctx2, &t, 0, 0.0, 1).unwrap();
        assert!(slow > base, "throttled estimate not reflected: {slow} vs {base}");
    }

    #[test]
    fn waiting_lowers_priority_score() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let v = views(&soc);
        let s = Adms::default();
        let mut t = pending(0, 0.0);
        let ctx = mk_ctx(100.0, &soc, &plans, &v);
        t.ready_at = 99.0;
        let fresh = s.priority(&ctx, &t, 0, 5.0);
        t.ready_at = 0.0; // has waited 100 ms
        let waited = s.priority(&ctx, &t, 0, 5.0);
        assert!(waited < fresh, "long wait should lower (prioritize) the score");
    }

    #[test]
    fn tighter_deadline_lowers_priority_score() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let v = views(&soc);
        let s = Adms::default();
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let mut tight = pending(0, 0.0);
        tight.slo_ms = Some(10.0);
        let mut loose = pending(0, 0.0);
        loose.slo_ms = Some(500.0);
        assert!(
            s.priority(&ctx, &tight, 0, 5.0) < s.priority(&ctx, &loose, 0, 5.0),
            "tight deadline must rank first"
        );
    }

    /// Health gating mirrors the offline test: `Down` removes a processor
    /// from placement entirely; `Degraded` re-prices it (2× exec) so a
    /// cool alternative wins ties it used to win.
    #[test]
    fn down_processor_never_selected_and_degraded_repriced() {
        use crate::monitor::Health;
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let mut v = views(&soc);
        for view in v.iter_mut().skip(1) {
            view.health = Health::Down;
        }
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let a = run_sched(&mut s, &ctx, &ready);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].proc, 0, "only the CPU is Up");
        assert!(s.placement_cost(&ctx, &ready[0], 1, 0.0, 1).is_none());
        // Degraded: still placeable, strictly more expensive than Up.
        let mut v2 = views(&soc);
        let t = pending(0, 0.0);
        let ctx_up = mk_ctx(0.0, &soc, &plans, &v2);
        let up_cost = s.placement_cost(&ctx_up, &t, 1, 0.0, 1).unwrap();
        v2[1].health = Health::Degraded;
        let ctx_deg = mk_ctx(0.0, &soc, &plans, &v2);
        let deg_cost = s.placement_cost(&ctx_deg, &t, 1, 0.0, 1).unwrap();
        assert!(deg_cost > up_cost, "Degraded must be re-priced: {deg_cost} vs {up_cost}");
    }

    #[test]
    fn offline_processor_never_selected() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let plans = vec![plan];
        let mut v = views(&soc);
        for view in v.iter_mut().skip(1) {
            view.offline = true;
        }
        let ctx = mk_ctx(0.0, &soc, &plans, &v);
        let mut s = Adms::default();
        let ready = vec![pending(0, 0.0)];
        let a = run_sched(&mut s, &ctx, &ready);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].proc, 0, "only the CPU is online");
    }
}
