//! Band baseline (Jeong et al., MobiSys '22; paper §4.2).
//!
//! Band decomposes models into unit subgraphs (no window-size filtering —
//! its candidate explosion is the paper's Table 3) and greedily maps each
//! ready subgraph to the processor with the shortest expected completion
//! time. It tracks its own dispatched backlog but is *state-blind*: the
//! expected-latency table assumes maximum frequency and ignores
//! temperature, so under throttling its estimates drift and it keeps
//! piling work onto hot processors.

use super::{free_slot_census, Assignment, PendingTask, SchedCtx, Scheduler};
use crate::soc::cost;

#[derive(Debug, Default)]
pub struct Band;

impl Band {
    pub fn new() -> Self {
        Band
    }
}

impl Scheduler for Band {
    fn name(&self) -> &'static str {
        "band"
    }

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask]) -> Vec<Assignment> {
        let mut free = free_slot_census(ctx);
        // Band's own bookkeeping of backlog it has dispatched: approximate
        // with the monitor's backlog figure (its queues are its own, so
        // this much it does know).
        let mut backlog: Vec<f64> = ctx.procs.iter().map(|p| p.backlog_ms).collect();
        let mut out = Vec::new();
        // Greedy shortest-expected-latency, first-come-first-considered.
        for (idx, t) in ready.iter().enumerate() {
            let plan = &ctx.plans[t.session];
            let mut best: Option<(usize, f64)> = None;
            for p in 0..ctx.soc.num_processors() {
                if free[p] == 0 {
                    continue;
                }
                // State-blind: assumes full frequency (scale = 1.0), no
                // thermal awareness.
                let exec = match plan.exec_estimate(t.unit, p, 1.0) {
                    Some(e) => e,
                    None => continue,
                };
                // Transfer costs for dependencies produced elsewhere.
                let xfer: f64 = t
                    .dep_procs
                    .iter()
                    .map(|&(dep_unit, dep_proc)| {
                        let bytes = plan
                            .xfer_bytes[t.unit]
                            .iter()
                            .find(|(d, _)| *d == dep_unit)
                            .map(|(_, b)| *b)
                            .unwrap_or(0);
                        cost::transfer_ms(ctx.soc, dep_proc, p, bytes)
                    })
                    .sum();
                let expected = backlog[p] + exec + xfer;
                if best.map(|(_, b)| expected < b).unwrap_or(true) {
                    best = Some((p, expected));
                }
            }
            if let Some((p, exp)) = best {
                free[p] -= 1;
                backlog[p] += exp;
                out.push(Assignment { ready_idx: idx, proc: p });
            }
        }
        out
    }
}
