//! Band baseline (Jeong et al., MobiSys '22; paper §4.2).
//!
//! Band decomposes models into unit subgraphs (no window-size filtering —
//! its candidate explosion is the paper's Table 3) and greedily maps each
//! ready subgraph to the processor with the shortest expected completion
//! time. It tracks its own dispatched backlog but is *state-blind*: the
//! expected-latency table assumes maximum frequency and ignores
//! temperature, so under throttling its estimates drift and it keeps
//! piling work onto hot processors.

use super::{free_slot_census_into, Assignment, PendingTask, SchedCtx, Scheduler};
use crate::soc::cost;

#[derive(Debug, Default)]
pub struct Band {
    // Per-decision scratch, reused across calls (hot-path: the dispatch
    // loop invokes `schedule` on every event that frees capacity).
    free: Vec<usize>,
    backlog: Vec<f64>,
    taken: Vec<bool>,
    members: Vec<usize>,
}

impl Band {
    pub fn new() -> Self {
        Band::default()
    }
}

impl Scheduler for Band {
    fn name(&self) -> &'static str {
        "band"
    }

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask], out: &mut Vec<Assignment>) {
        let free = &mut self.free;
        free_slot_census_into(ctx, free);
        // Band's own bookkeeping of backlog it has dispatched: approximate
        // with the monitor's backlog figure (its queues are its own, so
        // this much it does know).
        let backlog = &mut self.backlog;
        backlog.clear();
        backlog.extend(ctx.procs.iter().map(|p| p.backlog_ms));
        let batching = ctx.batch.enabled();
        let taken = &mut self.taken;
        taken.clear();
        taken.resize(ready.len(), false);
        // Greedy shortest-expected-latency, first-come-first-considered.
        // Under batching a task already reserved as a group member is
        // skipped, and each lead fuses its same-(model, unit) peers into
        // one slot priced off the batch curve.
        for (idx, t) in ready.iter().enumerate() {
            if taken[idx] {
                continue;
            }
            let plan = &ctx.plans[t.session];
            let b = if batching { ctx.batch.group_limit(idx, taken) } else { 1 };
            let mut best: Option<(usize, f64)> = None;
            for p in 0..ctx.soc.num_processors() {
                if free[p] == 0 {
                    continue;
                }
                // State-blind: assumes full frequency (scale = 1.0), no
                // thermal awareness.
                let exec = match plan.exec_ms[t.unit][p] {
                    Some(e) => cost::batch_latency_ms(&ctx.soc.processors[p], e, b),
                    None => continue,
                };
                // Transfer costs for dependencies produced elsewhere
                // (`dep_procs` rows align with `deps[unit]` — positional).
                // The driver charges a group every member's transfers;
                // estimate as b × the lead's (exact at b = 1).
                let xfer: f64 = t
                    .dep_procs
                    .iter()
                    .enumerate()
                    .map(|(k, &(dep_unit, dep_proc))| {
                        let bytes = plan.xfer_bytes_at(t.unit, k, dep_unit);
                        cost::transfer_ms(ctx.soc, dep_proc, p, bytes)
                    })
                    .sum::<f64>()
                    * b as f64;
                // Band's runtime does see delegate weight residency (its
                // model pool prepares per-worker contexts), so its
                // estimate includes the cold-load price — 0.0 exactly on
                // unbudgeted runs, keeping the sum bit-identical there.
                let load = ctx.residency_miss_ms(t.session, t.unit, p);
                // Band is state-blind to temperature/frequency, but a
                // crashed-and-recovered delegate is a runtime signal its
                // model pool does see (the worker context was torn down):
                // price a quarantined (Degraded) processor's execution at
                // 2× until the driver trusts it again. `Up` adds exactly
                // 0.0, keeping faults-off estimates bit-identical; `Down`
                // never reaches here (zero free slots).
                let health = if ctx.procs[p].health == crate::monitor::Health::Degraded {
                    exec
                } else {
                    0.0
                };
                let expected = backlog[p] + exec + xfer + load + health;
                if best.map(|(_, b)| expected < b).unwrap_or(true) {
                    best = Some((p, expected));
                }
            }
            if let Some((p, exp)) = best {
                taken[idx] = true;
                if b > 1 {
                    self.members.clear();
                    ctx.batch.members(idx, b, taken, &mut self.members);
                    for &m in &self.members {
                        taken[m] = true;
                    }
                }
                free[p] -= 1;
                backlog[p] += exp;
                out.push(Assignment { ready_idx: idx, proc: p, batch: b });
            }
        }
    }
}
