//! Sim-in-the-loop lookahead scheduling: a wrapper policy that refines a
//! base policy's placements with forked what-if rollouts.
//!
//! The wrapper itself makes no placement decisions. [`Lookahead`]
//! delegates `schedule` (and every cost hook) to its base policy
//! unchanged; what it adds is [`Scheduler::rollout_params`], which tells
//! the [`Driver`](crate::exec::Driver) to evaluate up to `beam` candidate
//! processors for each accepted assignment on a
//! [forked](crate::exec::SimBackend::fork) simulation before committing —
//! OmniBoost's estimator-in-the-scheduler idea (PAPERS.md) on this repo's
//! calibrated discrete-event model. Rollout scoring and candidate
//! enumeration live in the driver (`exec/driver.rs`), next to the pricing
//! they must agree with.
//!
//! Honesty note: rollouts are charged *zero* in-model decision overhead —
//! `decision_overhead_ms` delegates to the base policy, so simulated
//! lookahead wins are net of placement quality only, not of the (real)
//! cost of running k·beam forked simulations per decision. The bench
//! suite's `lookahead` row tracks that wall-clock cost instead. To keep
//! that cost flat at fleet scale, the driver does not deep-clone the
//! backend per candidate: it keeps one persistent scratch snapshot per
//! run and recycles it with
//! [`fork_into`](crate::exec::ExecutionBackend::fork_into) (an in-place
//! [`restore`](crate::exec::SimBackend::restore) when the slot already
//! holds a sim backend), so a decision's k·beam rollouts reuse one
//! allocation instead of minting k·beam deep copies.

use super::{Assignment, ModelPlan, PendingTask, SchedCtx, Scheduler};
use crate::soc::{ProcId, SocSpec};
use crate::TimeMs;

/// Rollout depth/width handed to the driver by
/// [`Scheduler::rollout_params`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RolloutParams {
    /// Completions to observe on each forked rollout before scoring
    /// (`--horizon`). `0` never reaches the driver: the server builds the
    /// bare base policy instead (the no-op-by-construction guarantee).
    pub horizon: u32,
    /// Candidate processors evaluated per decision (`--beam`; `<= 1`
    /// likewise degenerates at build time).
    pub beam: u32,
}

/// Which existing policy a [`Lookahead`] refines (`--base`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BasePolicy {
    Vanilla,
    Band,
    Adms,
    Pinned,
}

impl BasePolicy {
    pub const ALL: [BasePolicy; 4] =
        [BasePolicy::Vanilla, BasePolicy::Band, BasePolicy::Adms, BasePolicy::Pinned];

    pub fn name(self) -> &'static str {
        match self {
            BasePolicy::Vanilla => "vanilla",
            BasePolicy::Band => "band",
            BasePolicy::Adms => "adms",
            BasePolicy::Pinned => "pinned",
        }
    }

    /// Parse a CLI spelling (the same names `--sched` accepts for the
    /// bare policies).
    pub fn parse(s: &str) -> Option<BasePolicy> {
        Some(match s {
            "vanilla" | "tflite" => BasePolicy::Vanilla,
            "band" => BasePolicy::Band,
            "adms" => BasePolicy::Adms,
            "pinned" => BasePolicy::Pinned,
            _ => None?,
        })
    }

    /// Build the base policy exactly as
    /// [`scheduler_by_name`](crate::exec::scheduler_by_name) would.
    pub fn build(self, soc: &SocSpec, sessions: usize) -> Box<dyn Scheduler> {
        match self {
            BasePolicy::Vanilla => {
                Box::new(super::VanillaTflite::default_for(soc, sessions))
            }
            BasePolicy::Band => Box::new(super::Band::new()),
            BasePolicy::Adms => Box::<super::Adms>::default(),
            BasePolicy::Pinned => {
                let target = soc.best_accelerator().unwrap_or_else(|| soc.cpu_id());
                Box::new(super::Pinned::new(target, soc.cpu_id()))
            }
        }
    }
}

/// The fifth scheduler arm: a base policy plus driver-side rollouts.
pub struct Lookahead {
    base: Box<dyn Scheduler>,
    params: RolloutParams,
}

impl Lookahead {
    /// Wrap `base`. Callers (the server) must only construct this with
    /// `horizon > 0 && beam > 1` — degenerate configurations return the
    /// bare base policy instead, keeping `--horizon 0` a no-op by
    /// construction rather than by code path.
    pub fn new(base: Box<dyn Scheduler>, params: RolloutParams) -> Self {
        Lookahead { base, params }
    }
}

impl Scheduler for Lookahead {
    fn name(&self) -> &'static str {
        "lookahead"
    }

    /// Window tuning keys on the base policy: lookahead-over-adms must
    /// partition with the same tuned windows bare adms uses, or the
    /// placement comparison would be confounded by partitioning.
    fn tuning_name(&self) -> &'static str {
        self.base.name()
    }

    fn rollout_params(&self) -> Option<RolloutParams> {
        Some(self.params)
    }

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask], out: &mut Vec<Assignment>) {
        self.base.schedule(ctx, ready, out);
    }

    fn decision_overhead_ms(&self, plan: &ModelPlan) -> TimeMs {
        self.base.decision_overhead_ms(plan)
    }

    fn serializes_sessions(&self) -> bool {
        self.base.serializes_sessions()
    }

    fn transfer_cost_ms(&self, soc: &SocSpec, from: ProcId, to: ProcId, bytes: u64) -> TimeMs {
        self.base.transfer_cost_ms(soc, from, to, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::dimensity9000;

    /// The wrapper is a pure pass-through around its base: same
    /// serialization contract, same overheads, base-keyed tuning — only
    /// the name and the rollout advertisement differ.
    #[test]
    fn lookahead_delegates_everything_but_name() {
        let soc = dimensity9000();
        for policy in BasePolicy::ALL {
            let base = policy.build(&soc, 2);
            let serializes = base.serializes_sessions();
            let la = Lookahead::new(
                policy.build(&soc, 2),
                RolloutParams { horizon: 2, beam: 3 },
            );
            assert_eq!(la.name(), "lookahead");
            assert_eq!(la.tuning_name(), policy.name());
            assert_eq!(la.serializes_sessions(), serializes);
            assert_eq!(
                la.rollout_params(),
                Some(RolloutParams { horizon: 2, beam: 3 })
            );
            assert!(base.rollout_params().is_none());
        }
    }

    #[test]
    fn base_policy_names_round_trip() {
        for policy in BasePolicy::ALL {
            assert_eq!(BasePolicy::parse(policy.name()), Some(policy));
        }
        assert_eq!(BasePolicy::parse("tflite"), Some(BasePolicy::Vanilla));
        assert_eq!(BasePolicy::parse("lookahead"), None);
    }
}
