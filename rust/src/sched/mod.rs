//! Schedulers (paper §3.4) and the execution-plan / task model they share.
//!
//! Three policies, matching the paper's evaluation arms:
//!
//! * [`vanilla::VanillaTflite`] — TFLite's behaviour: each model is pinned
//!   to one delegate (the "best" accelerator); unsupported ops fall back
//!   to the CPU; execution is model-level (one subgraph chain at a time).
//! * [`band::Band`] — unit-subgraph scheduling with a shortest-expected-
//!   latency greedy over its (ws = 1) candidate explosion; state-blind:
//!   it tracks its own queue backlog but ignores temperature/frequency.
//! * [`adms::Adms`] — the paper's contribution: window-size-filtered
//!   partitions plus the multi-factor priority model of Eqs 1–4
//!   (deadline, fairness, resource) with processor-state awareness from
//!   the [`HardwareMonitor`](crate::monitor::HardwareMonitor).

pub mod plan;
pub mod vanilla;
pub mod band;
pub mod adms;
pub mod pinned;

pub use adms::Adms;
pub use band::Band;
pub use pinned::Pinned;
pub use plan::ModelPlan;
pub use vanilla::VanillaTflite;

use crate::monitor::ProcView;
use crate::soc::{ProcId, SocSpec};
use crate::TimeMs;

/// Request identifier (unique across a simulation run).
pub type ReqId = u64;
/// Session = one concurrently-running application/model instance.
pub type SessId = usize;

/// A schedulable unit-subgraph instance awaiting dispatch.
#[derive(Debug)]
pub struct PendingTask {
    pub req: ReqId,
    pub session: SessId,
    /// Unit index within the session's [`ModelPlan`].
    pub unit: usize,
    /// When the task became ready (deps satisfied).
    pub ready_at: TimeMs,
    /// When the request arrived (for deadline slack).
    pub req_arrival: TimeMs,
    /// Request SLO, if any.
    pub slo_ms: Option<f64>,
    /// Estimated remaining work for the whole request after this task, ms
    /// (the `C_remaining` of Eq 3).
    pub remaining_ms: f64,
    /// Processor each completed dependency ran on (for transfer pricing).
    /// Entries are ordered to match `ModelPlan::deps[unit]`, which is what
    /// lets transfer bytes be looked up positionally (no linear search).
    pub dep_procs: Vec<(usize, ProcId)>,
}

impl Clone for PendingTask {
    fn clone(&self) -> Self {
        PendingTask {
            req: self.req,
            session: self.session,
            unit: self.unit,
            ready_at: self.ready_at,
            req_arrival: self.req_arrival,
            slo_ms: self.slo_ms,
            remaining_ms: self.remaining_ms,
            dep_procs: self.dep_procs.clone(),
        }
    }

    /// Reuses `self.dep_procs`' allocation — the dispatch loop clones
    /// serialized-session exposures into scratch buffers on every
    /// decision round, and this keeps that clone allocation-free.
    fn clone_from(&mut self, source: &Self) {
        self.req = source.req;
        self.session = source.session;
        self.unit = source.unit;
        self.ready_at = source.ready_at;
        self.req_arrival = source.req_arrival;
        self.slo_ms = source.slo_ms;
        self.remaining_ms = source.remaining_ms;
        self.dep_procs.clone_from(&source.dep_procs);
    }
}

/// What the scheduler sees when asked for a decision.
pub struct SchedCtx<'a> {
    pub now: TimeMs,
    pub soc: &'a SocSpec,
    /// One plan per session (index = session id).
    pub plans: &'a [ModelPlan],
    /// Monitor snapshot — possibly stale, per the monitor cache interval.
    pub procs: &'a [ProcView],
}

impl<'a> SchedCtx<'a> {
    /// Free execution slots on one processor view. This is the single
    /// source of truth for capacity: [`SchedCtx::available_procs`] and
    /// [`free_slot_census`] both derive from it, so a processor is
    /// "available" exactly when the census says it has ≥ 1 free slot
    /// (they used to disagree: `load < 1.0` called a 4-slot processor at
    /// load 0.9 available while the census rounded its free slots to 0).
    pub fn free_slots(&self, v: &ProcView) -> usize {
        if v.offline {
            0
        } else {
            let total = self.soc.processors[v.id].parallel_slots.max(1) as f64;
            ((1.0 - v.load) * total).round().max(0.0) as usize
        }
    }

    /// Processors currently able to accept a task (online, ≥ 1 free slot).
    pub fn available_procs(&self) -> Vec<ProcId> {
        self.procs
            .iter()
            .filter(|p| self.free_slots(p) > 0)
            .map(|p| p.id)
            .collect()
    }
}

/// Free execution slots per processor, derived from the monitor view
/// (schedulers use this to avoid double-booking within one decision).
pub fn free_slot_census(ctx: &SchedCtx) -> Vec<usize> {
    ctx.procs.iter().map(|v| ctx.free_slots(v)).collect()
}

/// [`free_slot_census`] into a reusable buffer — the per-decision scratch
/// form every scheduler uses on the hot path.
pub fn free_slot_census_into(ctx: &SchedCtx, out: &mut Vec<usize>) {
    out.clear();
    out.extend(ctx.procs.iter().map(|v| ctx.free_slots(v)));
}

/// An assignment decision: ready-queue index → processor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub ready_idx: usize,
    pub proc: ProcId,
}

/// Scheduling policy interface. The engine calls [`Scheduler::schedule`]
/// whenever new tasks become ready or a processor frees a slot; the
/// scheduler appends any number of assignments to `out` (the engine
/// validates support/capacity and ignores invalid ones defensively).
///
/// `out` is a caller-owned scratch buffer, cleared by the caller before
/// the call — schedulers must only append. This keeps the steady-state
/// dispatch loop free of per-decision allocations; policies keep their
/// own intermediate state (slot censuses, backlog bumps) in reusable
/// member scratch for the same reason.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask], out: &mut Vec<Assignment>);

    /// Per-dispatch scheduling/management overhead in ms, given the
    /// session's plan (candidate-set size drives it — see
    /// [`crate::analyzer::tuner::management_overhead_ms`]).
    fn decision_overhead_ms(&self, plan: &ModelPlan) -> TimeMs {
        crate::analyzer::tuner::management_overhead_ms(plan.partition.total_subgraphs)
    }

    /// True if this policy executes each session's tasks strictly in
    /// order, one at a time (TFLite's model-level execution). The engine
    /// then exposes only each session's earliest ready task.
    fn serializes_sessions(&self) -> bool {
        false
    }

    /// Cost of moving a tensor between processors under this runtime.
    /// Band and ADMS implement shared zero-copy buffers (DMA over the
    /// memory bus); TFLite's NNAPI path pays a driver round-trip per
    /// partition handoff — override accordingly.
    fn transfer_cost_ms(
        &self,
        soc: &SocSpec,
        from: ProcId,
        to: ProcId,
        bytes: u64,
    ) -> TimeMs {
        crate::soc::cost::transfer_ms(soc, from, to, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::ProcView;
    use crate::soc::{dimensity9000, ProcKind};

    pub(crate) fn mk_views(soc: &SocSpec) -> Vec<ProcView> {
        soc.processors
            .iter()
            .enumerate()
            .map(|(id, p)| ProcView {
                id,
                kind: p.kind,
                temp_c: 30.0,
                freq_mhz: p.max_freq(),
                freq_scale: 1.0,
                offline: false,
                load: 0.0,
                backlog_ms: 0.0,
                active_sessions: 0,
                util: 0.0,
                headroom_c: p.throttle_temp_c - 30.0,
            })
            .collect()
    }

    #[test]
    fn available_procs_excludes_offline_and_full() {
        let soc = dimensity9000();
        let mut views = mk_views(&soc);
        views[1].offline = true;
        views[2].load = 1.0;
        let plans: Vec<ModelPlan> = vec![];
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &views };
        let avail = ctx.available_procs();
        assert!(!avail.contains(&1));
        assert!(!avail.contains(&2));
        assert!(avail.contains(&0));
        assert_eq!(soc.processors[0].kind, ProcKind::Cpu);
    }

    /// Regression: `available_procs` must agree with `free_slot_census`
    /// on multi-slot processors. A 4-slot processor at load 0.9 has
    /// 0.4 free slots → census rounds to 0 → it must NOT be available,
    /// even though `load < 1.0`.
    #[test]
    fn available_procs_agrees_with_free_slot_census() {
        let soc = dimensity9000();
        let mut views = mk_views(&soc);
        assert!(
            soc.processors[0].parallel_slots >= 2,
            "test needs a multi-slot processor"
        );
        views[0].load = 0.9; // rounds to 0 free slots on a 4-slot proc
        if views.len() > 1 {
            views[1].load = 0.7; // ≥ 1 free slot → available
        }
        let plans: Vec<ModelPlan> = vec![];
        let ctx = SchedCtx { now: 0.0, soc: &soc, plans: &plans, procs: &views };
        let census = free_slot_census(&ctx);
        let avail = ctx.available_procs();
        for (id, &free) in census.iter().enumerate() {
            assert_eq!(
                avail.contains(&id),
                free > 0,
                "proc {id}: available={} but census says {free} free slots",
                avail.contains(&id)
            );
        }
        assert!(!avail.contains(&0), "0.4 free slots must round to unavailable");
        assert!(avail.contains(&1));
    }
}
