//! Schedulers (paper §3.4) and the execution-plan / task model they share.
//!
//! Three policies, matching the paper's evaluation arms:
//!
//! * [`vanilla::VanillaTflite`] — TFLite's behaviour: each model is pinned
//!   to one delegate (the "best" accelerator); unsupported ops fall back
//!   to the CPU; execution is model-level (one subgraph chain at a time).
//! * [`band::Band`] — unit-subgraph scheduling with a shortest-expected-
//!   latency greedy over its (ws = 1) candidate explosion; state-blind:
//!   it tracks its own queue backlog but ignores temperature/frequency.
//! * [`adms::Adms`] — the paper's contribution: window-size-filtered
//!   partitions plus the multi-factor priority model of Eqs 1–4
//!   (deadline, fairness, resource) with processor-state awareness from
//!   the [`HardwareMonitor`](crate::monitor::HardwareMonitor).

pub mod plan;
pub mod vanilla;
pub mod band;
pub mod adms;
pub mod pinned;
pub mod lookahead;

pub use adms::Adms;
pub use band::Band;
pub use lookahead::{BasePolicy, Lookahead, RolloutParams};
pub use pinned::Pinned;
pub use plan::{plan_cache_len, ModelPlan, PlanSet};
pub use vanilla::VanillaTflite;

use crate::monitor::ProcView;
use crate::soc::{ProcId, SocSpec};
use crate::TimeMs;

/// Request identifier (unique across a simulation run).
pub type ReqId = u64;
/// Session = one concurrently-running application/model instance.
pub type SessId = usize;

/// A schedulable unit-subgraph instance awaiting dispatch.
#[derive(Debug)]
pub struct PendingTask {
    pub req: ReqId,
    pub session: SessId,
    /// Unit index within the session's [`ModelPlan`].
    pub unit: usize,
    /// When the task became ready (deps satisfied).
    pub ready_at: TimeMs,
    /// When the request arrived (for deadline slack).
    pub req_arrival: TimeMs,
    /// Request SLO, if any.
    pub slo_ms: Option<f64>,
    /// Estimated remaining work for the whole request after this task, ms
    /// (the `C_remaining` of Eq 3).
    pub remaining_ms: f64,
    /// Processor each completed dependency ran on (for transfer pricing).
    /// Entries are ordered to match `ModelPlan::deps[unit]`, which is what
    /// lets transfer bytes be looked up positionally (no linear search).
    pub dep_procs: Vec<(usize, ProcId)>,
}

impl Clone for PendingTask {
    fn clone(&self) -> Self {
        PendingTask {
            req: self.req,
            session: self.session,
            unit: self.unit,
            ready_at: self.ready_at,
            req_arrival: self.req_arrival,
            slo_ms: self.slo_ms,
            remaining_ms: self.remaining_ms,
            dep_procs: self.dep_procs.clone(),
        }
    }

    /// Reuses `self.dep_procs`' allocation — the dispatch loop clones
    /// serialized-session exposures into scratch buffers on every
    /// decision round, and this keeps that clone allocation-free.
    fn clone_from(&mut self, source: &Self) {
        self.req = source.req;
        self.session = source.session;
        self.unit = source.unit;
        self.ready_at = source.ready_at;
        self.req_arrival = source.req_arrival;
        self.slo_ms = source.slo_ms;
        self.remaining_ms = source.remaining_ms;
        self.dep_procs.clone_from(&source.dep_procs);
    }
}

/// Batching context the driver hands the scheduler: the per-dispatch
/// group-size cap and the coalescing key of every task it is shown.
///
/// Two ready tasks are *batchable* — fusable into one group dispatch that
/// occupies a single processor slot — exactly when their coalescing keys
/// are equal: same model structure (graph fingerprint) and same unit
/// index, so the fused execution shares weights, plan, and kernel. The
/// driver and every policy resolve group members through
/// [`BatchCtx::members`], so the scheduler's pricing and the driver's
/// dispatch can never disagree about which tasks a group contains.
#[derive(Debug, Clone, Copy)]
pub struct BatchCtx<'a> {
    /// Largest group one dispatch may fuse (`1` = batching disabled).
    pub max: usize,
    /// Coalescing key per shown task, aligned with the `ready` slice
    /// (empty when batching is disabled).
    pub kinds: &'a [u64],
}

impl BatchCtx<'_> {
    /// The disabled context (the pre-batching scheduler contract).
    pub const OFF: BatchCtx<'static> = BatchCtx { max: 1, kinds: &[] };

    /// Whether group dispatch is on for this decision round.
    pub fn enabled(&self) -> bool {
        self.max > 1 && !self.kinds.is_empty()
    }

    /// Largest group task `lead` could head right now: itself plus every
    /// not-yet-taken same-key task, capped at `max`. `taken[i]` marks
    /// tasks already committed this round (may be shorter than the ready
    /// slice; missing entries count as free).
    pub fn group_limit(&self, lead: usize, taken: &[bool]) -> usize {
        if !self.enabled() || lead >= self.kinds.len() {
            return 1;
        }
        let key = self.kinds[lead];
        let mut n = 1;
        for (i, &k) in self.kinds.iter().enumerate() {
            if i != lead && k == key && !taken.get(i).copied().unwrap_or(false) {
                n += 1;
                if n == self.max {
                    break;
                }
            }
        }
        n
    }

    /// Append the member indices of a group of size `b` led by `lead`
    /// (the lead itself is *not* appended): the first `b − 1` not-taken
    /// same-key tasks in ascending index order. This is the canonical
    /// member-resolution rule — deterministic, and shared by the pricing
    /// (scheduler) and dispatch (driver) sides.
    pub fn members(&self, lead: usize, b: usize, taken: &[bool], out: &mut Vec<usize>) {
        if !self.enabled() || b <= 1 || lead >= self.kinds.len() {
            return;
        }
        let key = self.kinds[lead];
        let mut need = b - 1;
        for (i, &k) in self.kinds.iter().enumerate() {
            if need == 0 {
                break;
            }
            if i != lead && k == key && !taken.get(i).copied().unwrap_or(false) {
                out.push(i);
                need -= 1;
            }
        }
    }
}

/// Weight-residency context the driver hands the scheduler: a read-only
/// view of the [`WeightCache`](crate::weights::WeightCache), present only
/// on memory-budgeted runs ([`WeightsView::OFF`] otherwise).
#[derive(Clone, Copy)]
pub struct WeightsView<'a> {
    pub cache: Option<&'a crate::weights::WeightCache>,
}

impl WeightsView<'_> {
    /// The disabled context (the pre-residency scheduler contract).
    pub const OFF: WeightsView<'static> = WeightsView { cache: None };
}

/// Plan-granularity context the driver hands the scheduler on adaptive
/// runs: the per-session variant ladder and which rung is active. Absent
/// (`SchedCtx::variants == None`) on static runs — the pre-PlanSet
/// scheduler contract.
#[derive(Clone, Copy)]
pub struct VariantsView<'a> {
    /// One granularity ladder per session (index = session id).
    pub sets: &'a [PlanSet],
    /// Active rung per session, indexing into the ladder.
    pub active: &'a [usize],
}

/// What the scheduler sees when asked for a decision.
pub struct SchedCtx<'a> {
    pub now: TimeMs,
    pub soc: &'a SocSpec,
    /// One plan per session (index = session id).
    pub plans: &'a [ModelPlan],
    /// Monitor snapshot — possibly stale, per the monitor cache interval.
    pub procs: &'a [ProcView],
    /// Group-dispatch context ([`BatchCtx::OFF`] when batching is off).
    pub batch: BatchCtx<'a>,
    /// Per-processor weight residency ([`WeightsView::OFF`] when the run
    /// has no memory budget).
    pub weights: WeightsView<'a>,
    /// Granularity ladders on adaptive runs (`None` on static runs —
    /// `plans[s]` is then the session's one and only plan). When present,
    /// `plans[s]` still IS the active variant: the driver swaps it on a
    /// switch, so policies that ignore this field automatically price the
    /// active granularity.
    pub variants: Option<VariantsView<'a>>,
}

impl<'a> SchedCtx<'a> {
    /// Free execution slots on one processor view. This is the single
    /// source of truth for capacity: [`SchedCtx::available_procs`] and
    /// [`free_slot_census`] both derive from it, so a processor is
    /// "available" exactly when the census says it has ≥ 1 free slot
    /// (they used to disagree: `load < 1.0` called a 4-slot processor at
    /// load 0.9 available while the census rounded its free slots to 0).
    pub fn free_slots(&self, v: &ProcView) -> usize {
        if v.offline || v.health == crate::monitor::Health::Down {
            0
        } else {
            let total = self.soc.processors[v.id].parallel_slots.max(1) as f64;
            ((1.0 - v.load) * total).round().max(0.0) as usize
        }
    }

    /// Processors currently able to accept a task (online, healthy
    /// enough to try — `Down` reports 0 free slots — and ≥ 1 free slot).
    pub fn available_procs(&self) -> Vec<ProcId> {
        self.procs
            .iter()
            .filter(|p| self.free_slots(p) > 0)
            .map(|p| p.id)
            .collect()
    }

    /// Cold-load delay that dispatching `(session, unit)` on `proc` right
    /// now would incur — 0.0 when the shard is already warm (or warming
    /// ahead of `now`), and exactly 0.0 on unbudgeted runs. Cache-aware
    /// policies (ADMS, Band) add this to their placement cost; vanilla
    /// and pinned stay cache-blind as baselines.
    pub fn residency_miss_ms(&self, session: SessId, unit: usize, proc: ProcId) -> TimeMs {
        match self.weights.cache {
            Some(c) => c.price(self.soc, self.now, session, unit, proc),
            None => 0.0,
        }
    }

    /// Window size of the session's active plan (the one `plans[s]` holds
    /// — valid on static and adaptive runs alike).
    pub fn active_window_size(&self, session: SessId) -> usize {
        self.plans[session].partition.window_size
    }

    /// The granularity rungs the controller could switch `session` to
    /// (window sizes other than the active one). Empty on static runs.
    pub fn switch_candidates(&self, session: SessId) -> Vec<usize> {
        match &self.variants {
            Some(v) => {
                let active = v.active[session];
                v.sets[session]
                    .window_sizes
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != active)
                    .map(|(_, &w)| w)
                    .collect()
            }
            None => Vec::new(),
        }
    }
}

/// Free execution slots per processor, derived from the monitor view
/// (schedulers use this to avoid double-booking within one decision).
pub fn free_slot_census(ctx: &SchedCtx) -> Vec<usize> {
    ctx.procs.iter().map(|v| ctx.free_slots(v)).collect()
}

/// [`free_slot_census`] into a reusable buffer — the per-decision scratch
/// form every scheduler uses on the hot path.
pub fn free_slot_census_into(ctx: &SchedCtx, out: &mut Vec<usize>) {
    out.clear();
    out.extend(ctx.procs.iter().map(|v| ctx.free_slots(v)));
}

/// One scheduling decision: a *group* of ready tasks → processor. The
/// dispatch unit grew from a single task to a task group (ISSUE 5): the
/// group's lead is `ready_idx`, and `batch − 1` further members are
/// resolved by the canonical [`BatchCtx::members`] rule. `batch = 1` is
/// the classic single-task assignment and the only value schedulers emit
/// when batching is off.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub ready_idx: usize,
    pub proc: ProcId,
    /// Group size to fuse into this dispatch (≥ 1; the driver clamps to
    /// the configured `batch_max` and the actually-available peers).
    pub batch: usize,
}

impl Assignment {
    /// A single-task (unbatched) assignment.
    pub fn single(ready_idx: usize, proc: ProcId) -> Self {
        Assignment { ready_idx, proc, batch: 1 }
    }
}

/// Scheduling policy interface. The engine calls [`Scheduler::schedule`]
/// whenever new tasks become ready or a processor frees a slot; the
/// scheduler appends any number of assignments to `out` (the engine
/// validates support/capacity and ignores invalid ones defensively).
///
/// `out` is a caller-owned scratch buffer, cleared by the caller before
/// the call — schedulers must only append. This keeps the steady-state
/// dispatch loop free of per-decision allocations; policies keep their
/// own intermediate state (slot censuses, backlog bumps) in reusable
/// member scratch for the same reason.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask], out: &mut Vec<Assignment>);

    /// Per-dispatch scheduling/management overhead in ms, given the
    /// session's plan (candidate-set size drives it — see
    /// [`crate::analyzer::tuner::management_overhead_ms`]).
    fn decision_overhead_ms(&self, plan: &ModelPlan) -> TimeMs {
        crate::analyzer::tuner::management_overhead_ms(plan.partition.total_subgraphs)
    }

    /// True if this policy executes each session's tasks strictly in
    /// order, one at a time (TFLite's model-level execution). The engine
    /// then exposes only each session's earliest ready task.
    fn serializes_sessions(&self) -> bool {
        false
    }

    /// Cost of moving a tensor between processors under this runtime.
    /// Band and ADMS implement shared zero-copy buffers (DMA over the
    /// memory bus); TFLite's NNAPI path pays a driver round-trip per
    /// partition handoff — override accordingly.
    fn transfer_cost_ms(
        &self,
        soc: &SocSpec,
        from: ProcId,
        to: ProcId,
        bytes: u64,
    ) -> TimeMs {
        crate::soc::cost::transfer_ms(soc, from, to, bytes)
    }

    /// Rollout parameters when this policy wants the driver to refine its
    /// placements with forked what-if rollouts ([`Lookahead`] overrides;
    /// `None` keeps the classic dispatch path byte-exact).
    fn rollout_params(&self) -> Option<RolloutParams> {
        None
    }

    /// The name window-size tuning keys on. [`Lookahead`] reports its
    /// *base* policy here so lookahead-over-adms gets the same tuned
    /// windows bare adms does; everyone else tunes under their own name.
    fn tuning_name(&self) -> &'static str {
        self.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::ProcView;
    use crate::soc::{dimensity9000, ProcKind};

    pub(crate) fn mk_views(soc: &SocSpec) -> Vec<ProcView> {
        soc.processors
            .iter()
            .enumerate()
            .map(|(id, p)| ProcView::nameplate(id, p, 30.0))
            .collect()
    }

    #[test]
    fn available_procs_excludes_offline_and_full() {
        let soc = dimensity9000();
        let mut views = mk_views(&soc);
        views[1].offline = true;
        views[2].load = 1.0;
        let plans: Vec<ModelPlan> = vec![];
        let ctx = SchedCtx {
            now: 0.0,
            soc: &soc,
            plans: &plans,
            procs: &views,
            batch: BatchCtx::OFF,
            weights: WeightsView::OFF,
            variants: None,
        };
        let avail = ctx.available_procs();
        assert!(!avail.contains(&1));
        assert!(!avail.contains(&2));
        assert!(avail.contains(&0));
        assert_eq!(soc.processors[0].kind, ProcKind::Cpu);
    }

    /// A `Down` processor is masked exactly like an offline one: zero
    /// free slots, absent from `available_procs`; `Degraded` stays
    /// schedulable (policies re-price it instead).
    #[test]
    fn down_health_masks_processor_like_offline() {
        use crate::monitor::Health;
        let soc = dimensity9000();
        let mut views = mk_views(&soc);
        views[1].health = Health::Down;
        views[2].health = Health::Degraded;
        let plans: Vec<ModelPlan> = vec![];
        let ctx = SchedCtx {
            now: 0.0,
            soc: &soc,
            plans: &plans,
            procs: &views,
            batch: BatchCtx::OFF,
            weights: WeightsView::OFF,
            variants: None,
        };
        assert_eq!(ctx.free_slots(&views[1]), 0);
        let census = free_slot_census(&ctx);
        assert_eq!(census[1], 0);
        assert!(census[2] > 0, "Degraded must stay schedulable");
        let avail = ctx.available_procs();
        assert!(!avail.contains(&1));
        assert!(avail.contains(&2));
    }

    /// Regression: `available_procs` must agree with `free_slot_census`
    /// on multi-slot processors. A 4-slot processor at load 0.9 has
    /// 0.4 free slots → census rounds to 0 → it must NOT be available,
    /// even though `load < 1.0`.
    #[test]
    fn available_procs_agrees_with_free_slot_census() {
        let soc = dimensity9000();
        let mut views = mk_views(&soc);
        assert!(
            soc.processors[0].parallel_slots >= 2,
            "test needs a multi-slot processor"
        );
        views[0].load = 0.9; // rounds to 0 free slots on a 4-slot proc
        if views.len() > 1 {
            views[1].load = 0.7; // ≥ 1 free slot → available
        }
        let plans: Vec<ModelPlan> = vec![];
        let ctx = SchedCtx {
            now: 0.0,
            soc: &soc,
            plans: &plans,
            procs: &views,
            batch: BatchCtx::OFF,
            weights: WeightsView::OFF,
            variants: None,
        };
        let census = free_slot_census(&ctx);
        let avail = ctx.available_procs();
        for (id, &free) in census.iter().enumerate() {
            assert_eq!(
                avail.contains(&id),
                free > 0,
                "proc {id}: available={} but census says {free} free slots",
                avail.contains(&id)
            );
        }
        assert!(!avail.contains(&0), "0.4 free slots must round to unavailable");
        assert!(avail.contains(&1));
    }

    /// The canonical group rules: `group_limit` counts untaken same-key
    /// tasks capped at `max`, and `members` resolves the first `b − 1` of
    /// them in ascending index order — the shared contract between
    /// scheduler pricing and driver dispatch.
    #[test]
    fn batch_ctx_group_limit_and_members_agree() {
        let kinds = [7u64, 3, 7, 7, 3, 7];
        let b = BatchCtx { max: 3, kinds: &kinds };
        assert!(b.enabled());
        let free = vec![false; kinds.len()];
        // Key 7 has 4 tasks; the cap clips the group at 3.
        assert_eq!(b.group_limit(0, &free), 3);
        assert_eq!(b.group_limit(1, &free), 2);
        let mut m = Vec::new();
        b.members(0, 3, &free, &mut m);
        assert_eq!(m, vec![2, 3]);
        // Taken peers are skipped, shrinking the group.
        let mut taken = free.clone();
        taken[2] = true;
        assert_eq!(b.group_limit(0, &taken), 3); // 0, 3, 5 still free
        m.clear();
        b.members(0, 3, &taken, &mut m);
        assert_eq!(m, vec![3, 5]);
        // Disabled contexts never group.
        assert!(!BatchCtx::OFF.enabled());
        assert_eq!(BatchCtx::OFF.group_limit(0, &free), 1);
        m.clear();
        BatchCtx::OFF.members(0, 4, &free, &mut m);
        assert!(m.is_empty());
    }
}
