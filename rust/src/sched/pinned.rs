//! Pinned scheduler: every task of every session goes to one processor
//! (CPU fallback for unsupported units). Used by the Table 2 concurrency
//! experiment ("average latency for MobileNetV1 on various processors")
//! and the Fig 3 single-processor measurements.

use super::{free_slot_census_into, Assignment, PendingTask, SchedCtx, Scheduler};
use crate::soc::ProcId;

#[derive(Debug)]
pub struct Pinned {
    target: ProcId,
    cpu: ProcId,
    /// Per-decision slot-census scratch, reused across calls.
    free: Vec<usize>,
    taken: Vec<bool>,
    members: Vec<usize>,
}

impl Pinned {
    pub fn new(target: ProcId, cpu: ProcId) -> Self {
        Pinned { target, cpu, free: Vec::new(), taken: Vec::new(), members: Vec::new() }
    }
}

impl Scheduler for Pinned {
    fn name(&self) -> &'static str {
        "pinned"
    }

    fn serializes_sessions(&self) -> bool {
        true
    }

    fn decision_overhead_ms(&self, _plan: &super::ModelPlan) -> crate::TimeMs {
        0.02 // fixed-placement interpreter, same as vanilla TFLite
    }

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask], out: &mut Vec<Assignment>) {
        let free = &mut self.free;
        free_slot_census_into(ctx, free);
        let batching = ctx.batch.enabled();
        let taken = &mut self.taken;
        taken.clear();
        taken.resize(ready.len(), false);
        for (idx, t) in ready.iter().enumerate() {
            if taken[idx] {
                continue;
            }
            let plan = &ctx.plans[t.session];
            let target = if plan.partition.units[t.unit].supports(self.target) {
                self.target
            } else {
                self.cpu
            };
            // A Down target blocks the pinned session outright (census
            // reports 0 free slots; the explicit check keeps the rule
            // visible next to the offline one).
            if ctx.procs[target].offline
                || ctx.procs[target].health == crate::monitor::Health::Down
                || free[target] == 0
            {
                continue;
            }
            // Same-(model, unit) tasks of concurrent sessions fuse into
            // one pinned-processor slot when batching is enabled.
            let b = if batching { ctx.batch.group_limit(idx, taken) } else { 1 };
            taken[idx] = true;
            if b > 1 {
                self.members.clear();
                ctx.batch.members(idx, b, taken, &mut self.members);
                for &m in &self.members {
                    taken[m] = true;
                }
            }
            free[target] -= 1;
            out.push(Assignment { ready_idx: idx, proc: target, batch: b });
        }
    }
}
