//! Execution plans: a model's partition priced for one SoC.
//!
//! Built once per (model, SoC, window-size) — the paper stores these in a
//! configuration file after first analysis (§3.4: "the generated
//! subgraphs are stored in a configuration file for future use").

use crate::analyzer::{self, Partition};
use crate::graph::Graph;
use crate::soc::{cost, ProcId, SocSpec};
use crate::util::memo::Memo;
use crate::util::rng::splitmix64;
use crate::TimeMs;
use std::sync::Arc;

/// The process-wide plan memo (see [`ModelPlan::build_cached`]). Module
/// scope so `adms bench` can report its occupancy via
/// [`plan_cache_len`].
static PLAN_CACHE: Memo<(String, u64, String, u64, usize), ModelPlan> = Memo::new();

/// Entries currently resident in the plan memo — with PlanSets the
/// window-size axis multiplies, so growth here is worth watching.
pub fn plan_cache_len() -> usize {
    PLAN_CACHE.len()
}

/// A partitioned, cost-annotated model ready for scheduling.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub graph: Arc<Graph>,
    pub partition: Partition,
    /// `deps[u]` = units that must finish before unit `u`.
    pub deps: Vec<Vec<usize>>,
    /// `consumers[u]` = units waiting on `u`.
    pub consumers: Vec<Vec<usize>>,
    /// `exec_ms[u][p]` = unit latency on processor `p` at max frequency
    /// (`None` = unsupported there).
    pub exec_ms: Vec<Vec<Option<TimeMs>>>,
    /// Dense per-(unit, dep) transfer table: `xfer_bytes[u][k]` is the
    /// boundary bytes of unit `u`'s `k`-th dependency, with rows aligned
    /// index-for-index with `deps[u]`. Consumers that carry dependency
    /// lists in `deps` order (`PendingTask::dep_procs` does, by
    /// construction) read it positionally in O(1) via
    /// [`ModelPlan::xfer_bytes_at`] — the old `(dep, bytes)` pair rows
    /// needed a linear `find` per dependency on every pricing call.
    pub xfer_bytes: Vec<Vec<u64>>,
    /// Best-case single-model latency estimate (placement DP).
    pub est_total_ms: TimeMs,
    /// Mean unit execution time on the fastest processor (the `T_avg`
    /// normalizer of Eq 2).
    pub avg_unit_ms: TimeMs,
}

impl ModelPlan {
    /// Floor applied to frequency scales in every execution estimate —
    /// the single source of truth for the "deep throttle still prices
    /// finite" clamp (call sites used to repeat `.max(0.05)` and could
    /// drift apart).
    pub const FREQ_FLOOR: f64 = 0.05;

    pub fn build(graph: Arc<Graph>, soc: &SocSpec, window_size: usize) -> Self {
        let partition = analyzer::partition(&graph, soc, window_size);
        let units = &partition.units;
        let deps = analyzer::unit_deps(&graph, units);
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); units.len()];
        for (u, ds) in deps.iter().enumerate() {
            for &d in ds {
                consumers[d].push(u);
            }
        }
        let np = soc.num_processors();
        let exec_ms: Vec<Vec<Option<TimeMs>>> = units
            .iter()
            .map(|u| {
                (0..np)
                    .map(|p| {
                        if u.supports(p) {
                            cost::subgraph_latency_ms(&graph, &u.ops, &soc.processors[p], 1.0)
                        } else {
                            None
                        }
                    })
                    .collect()
            })
            .collect();
        let xfer_bytes: Vec<Vec<u64>> = (0..units.len())
            .map(|u| {
                deps[u]
                    .iter()
                    .map(|&d| analyzer::inter_unit_bytes(&graph, units, d, u))
                    .collect()
            })
            .collect();
        let est_total_ms = analyzer::estimate_chain_latency_ms(&graph, soc, &partition);
        let best_units: f64 = exec_ms
            .iter()
            .map(|per_proc| {
                per_proc
                    .iter()
                    .flatten()
                    .copied()
                    .fold(f64::INFINITY, f64::min)
            })
            .sum();
        let avg_unit_ms = (best_units / units.len().max(1) as f64).max(1e-3);
        ModelPlan {
            graph,
            partition,
            deps,
            consumers,
            exec_ms,
            xfer_bytes,
            est_total_ms,
            avg_unit_ms,
        }
    }

    /// Memoized [`ModelPlan::build`]: partitioning and cost annotation are
    /// pure functions of (model, SoC, window size), and serving paths
    /// rebuild the same plans on every run — the cache turns that into a
    /// table clone. Keyed by `(graph.name, graph.fingerprint(), soc.name,
    /// soc.fingerprint(), window_size)`: structural fingerprints on BOTH
    /// sides, so neither two same-name graphs with different op/edge
    /// content nor two same-name SoCs with different processor/support/
    /// thermal definitions can ever share a cached plan.
    pub fn build_cached(graph: Arc<Graph>, soc: &SocSpec, window_size: usize) -> Self {
        let key = (
            graph.name.clone(),
            graph.fingerprint(),
            soc.name.clone(),
            soc.fingerprint(),
            window_size,
        );
        PLAN_CACHE.get_or_insert_with(key, || ModelPlan::build(graph, soc, window_size))
    }

    /// Batching coalescing identity of this plan: the graph's structural
    /// fingerprint mixed with the partition's window size. Two sessions
    /// may fuse group dispatches only when BOTH coincide — unit indices
    /// shift across granularity variants, so same-model sessions on
    /// different variants must never coalesce (unit 3 of a fine plan and
    /// unit 3 of a coarse plan are different subgraphs). On static runs
    /// this partitions sessions exactly like the bare graph fingerprint
    /// did, because same-model sessions always share one window size
    /// there.
    pub fn coalesce_kind(&self) -> u64 {
        splitmix64(
            self.graph.fingerprint()
                ^ (self.partition.window_size as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
        )
    }

    pub fn num_units(&self) -> usize {
        self.partition.units.len()
    }

    /// Boundary bytes of unit `unit`'s `k`-th dependency (positional —
    /// rows align with `deps[unit]`). `dep` re-states the dependency's
    /// unit id purely as a debug cross-check of that alignment.
    #[inline]
    pub fn xfer_bytes_at(&self, unit: usize, k: usize, dep: usize) -> u64 {
        debug_assert_eq!(self.deps[unit][k], dep, "dep_procs misaligned with deps");
        self.xfer_bytes[unit][k]
    }

    /// Execution estimate for a unit on a processor at a frequency scale.
    /// The scale is floored at 0.05 here — the single authoritative clamp
    /// (deep-throttle estimates stay finite); call sites used to repeat
    /// `.max(0.05)` themselves and could drift apart.
    pub fn exec_estimate(&self, unit: usize, proc: ProcId, freq_scale: f64) -> Option<TimeMs> {
        self.exec_ms[unit][proc].map(|t| t / freq_scale.max(Self::FREQ_FLOOR))
    }

    /// Remaining-work estimate: sum of best-case unit costs for the given
    /// set of unfinished units.
    pub fn remaining_ms(&self, unfinished: impl Iterator<Item = usize>) -> TimeMs {
        unfinished
            .map(|u| {
                self.exec_ms[u]
                    .iter()
                    .flatten()
                    .copied()
                    .fold(f64::INFINITY, f64::min)
            })
            .filter(|t| t.is_finite())
            .sum()
    }
}

/// A per-model ladder of granularity variants (adaptive re-partitioning,
/// DESIGN.md §3h): the same graph partitioned at several window sizes,
/// finest first. Each variant is built through [`ModelPlan::build_cached`],
/// so variants stay fingerprint-keyed in the process-wide memo and two
/// sessions (or two PlanSets) of the same model share one plan per rung.
#[derive(Debug, Clone)]
pub struct PlanSet {
    /// Window sizes, ascending — index 0 is the finest partition (most
    /// units, most spread), the last index the coarsest. Deduped.
    pub window_sizes: Vec<usize>,
    /// One plan per window size, aligned with `window_sizes`.
    pub variants: Vec<ModelPlan>,
}

impl PlanSet {
    /// Build one variant per requested window size (clamped ≥ 1, sorted
    /// ascending, deduped) through the shared plan memo.
    pub fn build_cached(graph: Arc<Graph>, soc: &SocSpec, window_sizes: &[usize]) -> Self {
        let mut ws: Vec<usize> = window_sizes.iter().map(|&w| w.max(1)).collect();
        ws.sort_unstable();
        ws.dedup();
        let variants = ws
            .iter()
            .map(|&w| ModelPlan::build_cached(Arc::clone(&graph), soc, w))
            .collect();
        PlanSet { window_sizes: ws, variants }
    }

    pub fn len(&self) -> usize {
        self.variants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.variants.is_empty()
    }

    /// Ladder index of a window size, if present.
    pub fn position(&self, window_size: usize) -> Option<usize> {
        self.window_sizes.iter().position(|&w| w == window_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::dimensity9000;
    use crate::zoo;

    #[test]
    fn plan_invariants_hold_for_all_models() {
        let soc = dimensity9000();
        for g in zoo::all_models() {
            let plan = ModelPlan::build(Arc::new(g), &soc, 5);
            assert!(plan.num_units() >= 1);
            assert!(plan.est_total_ms > 0.0);
            assert!(plan.avg_unit_ms > 0.0);
            for (u, per_proc) in plan.exec_ms.iter().enumerate() {
                // Every unit must be runnable somewhere (CPU at minimum).
                assert!(
                    per_proc.iter().any(|e| e.is_some()),
                    "{} unit {u} unrunnable",
                    plan.graph.name
                );
            }
            // consumers is the inverse of deps.
            for (u, ds) in plan.deps.iter().enumerate() {
                for &d in ds {
                    assert!(plan.consumers[d].contains(&u));
                }
            }
        }
    }

    #[test]
    fn exec_estimate_scales_with_frequency() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::mobilenet_v1()), &soc, 5);
        let full = plan.exec_estimate(0, 0, 1.0).unwrap();
        let half = plan.exec_estimate(0, 0, 0.5).unwrap();
        assert!((half - full * 2.0).abs() < 1e-9);
    }

    #[test]
    fn xfer_rows_align_with_deps() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::deeplab_v3()), &soc, 3);
        for (u, ds) in plan.deps.iter().enumerate() {
            assert_eq!(plan.xfer_bytes[u].len(), ds.len(), "row {u} misaligned");
            for (k, &d) in ds.iter().enumerate() {
                // Positional read; debug-asserts the id alignment.
                let _ = plan.xfer_bytes_at(u, k, d);
            }
        }
    }

    #[test]
    fn build_cached_matches_build() {
        let soc = dimensity9000();
        let g = Arc::new(zoo::mobilenet_v1());
        let a = ModelPlan::build(Arc::clone(&g), &soc, 4);
        let b = ModelPlan::build_cached(Arc::clone(&g), &soc, 4);
        let c = ModelPlan::build_cached(g, &soc, 4); // cache hit
        for p in [&b, &c] {
            assert_eq!(a.num_units(), p.num_units());
            assert_eq!(a.deps, p.deps);
            assert_eq!(a.xfer_bytes, p.xfer_bytes);
            assert_eq!(a.est_total_ms, p.est_total_ms);
            assert_eq!(a.avg_unit_ms, p.avg_unit_ms);
        }
    }

    /// Two structurally different graphs carrying the *same* name must
    /// not share a cached plan — the memo key includes the structural
    /// fingerprint precisely so a name collision cannot serve one model
    /// the other's partition.
    #[test]
    fn build_cached_distinguishes_same_name_different_structure() {
        let soc = dimensity9000();
        let mut a = zoo::mobilenet_v1();
        let mut b = zoo::east();
        a.name = "fingerprint_collision_probe".into();
        b.name = "fingerprint_collision_probe".into();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let pa = ModelPlan::build_cached(Arc::new(a.clone()), &soc, 3);
        let pb = ModelPlan::build_cached(Arc::new(b.clone()), &soc, 3);
        // Under the old name-only key the second lookup would have
        // returned mobilenet's plan for east's graph.
        assert_eq!(pa.num_units(), ModelPlan::build(Arc::new(a), &soc, 3).num_units());
        assert_eq!(pb.num_units(), ModelPlan::build(Arc::new(b), &soc, 3).num_units());
        assert_ne!(
            (pa.num_units(), pa.est_total_ms),
            (pb.num_units(), pb.est_total_ms),
            "same-name structural variants shared a cached plan"
        );
    }

    /// Two structurally different *SoCs* carrying the same name must not
    /// share a cached plan — the documented memo-collision gap: the old
    /// key carried `soc.name` with no structural fingerprint, so a custom
    /// SoC definition reusing a preset's name would be served the
    /// preset's partitioning. Mirrors the graph-fingerprint collision
    /// test above.
    #[test]
    fn build_cached_distinguishes_same_name_different_socs() {
        let g = Arc::new(zoo::mobilenet_v1());
        let mut a = dimensity9000();
        let mut b = crate::soc::kirin970();
        a.name = "soc_collision_probe".into();
        b.name = "soc_collision_probe".into();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let pa = ModelPlan::build_cached(Arc::clone(&g), &a, 3);
        let pb = ModelPlan::build_cached(Arc::clone(&g), &b, 3);
        // Each cached plan must match a fresh build against its own SoC
        // (under the old name-only key the second lookup would have
        // returned the dimensity partitioning for the kirin).
        let fa = ModelPlan::build(Arc::clone(&g), &a, 3);
        let fb = ModelPlan::build(Arc::clone(&g), &b, 3);
        assert_eq!(pa.num_units(), fa.num_units());
        assert_eq!(pa.est_total_ms, fa.est_total_ms);
        assert_eq!(pb.num_units(), fb.num_units());
        assert_eq!(pb.est_total_ms, fb.est_total_ms);
        assert_ne!(
            (pa.num_units(), pa.est_total_ms),
            (pb.num_units(), pb.est_total_ms),
            "same-name SoC variants shared a cached plan"
        );
    }

    #[test]
    fn plan_set_sorts_dedupes_and_shares_the_memo() {
        let soc = dimensity9000();
        let g = Arc::new(zoo::mobilenet_v1());
        let set = PlanSet::build_cached(Arc::clone(&g), &soc, &[6, 1, 3, 6, 0]);
        // 0 clamps to 1; duplicates collapse; order is fine → coarse.
        assert_eq!(set.window_sizes, vec![1, 3, 6]);
        assert_eq!(set.len(), 3);
        assert_eq!(set.position(3), Some(1));
        assert_eq!(set.position(4), None);
        // Finer rungs never have fewer units than coarser ones.
        for w in set.variants.windows(2) {
            assert!(w[0].num_units() >= w[1].num_units());
        }
        // Each rung is the same artifact the single-plan path builds.
        let lone = ModelPlan::build_cached(Arc::clone(&g), &soc, 3);
        assert_eq!(set.variants[1].num_units(), lone.num_units());
        assert_eq!(set.variants[1].est_total_ms, lone.est_total_ms);
    }

    #[test]
    fn coalesce_kind_separates_variants_of_one_model() {
        let soc = dimensity9000();
        let g = Arc::new(zoo::mobilenet_v1());
        let fine = ModelPlan::build_cached(Arc::clone(&g), &soc, 1);
        let coarse = ModelPlan::build_cached(Arc::clone(&g), &soc, 6);
        // Same model, different granularity → must never coalesce.
        assert_ne!(fine.coalesce_kind(), coarse.coalesce_kind());
        // Same model, same granularity → same kind (sessions may fuse).
        let fine2 = ModelPlan::build_cached(Arc::clone(&g), &soc, 1);
        assert_eq!(fine.coalesce_kind(), fine2.coalesce_kind());
        // Different models at the same granularity stay apart.
        let other = ModelPlan::build_cached(Arc::new(zoo::east()), &soc, 1);
        assert_ne!(fine.coalesce_kind(), other.coalesce_kind());
    }

    #[test]
    fn remaining_ms_decreases_as_units_finish() {
        let soc = dimensity9000();
        let plan = ModelPlan::build(Arc::new(zoo::deeplab_v3()), &soc, 5);
        let all = plan.remaining_ms(0..plan.num_units());
        let tail = plan.remaining_ms(1..plan.num_units());
        assert!(all > tail);
        assert_eq!(plan.remaining_ms(std::iter::empty()), 0.0);
    }
}
