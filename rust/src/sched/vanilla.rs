//! Vanilla TFLite baseline (paper §4.2 "Vanilla").
//!
//! TFLite executes one model per interpreter: ops supported by the chosen
//! delegate run there, everything else falls back to the CPU, and the
//! whole model executes as a serial chain (model-level scheduling). The
//! delegate is fixed per session at creation time — by default the SoC's
//! highest-peak accelerator, matching TFLite's delegate priority.

use super::{Assignment, PendingTask, SchedCtx, Scheduler};
use crate::soc::ProcId;

/// NNAPI driver round-trip per delegate↔CPU partition handoff, ms.
pub const NNAPI_SYNC_MS: f64 = 1.2;

/// The TFLite-like policy. `delegates[s]` pins session `s`'s accelerator.
#[derive(Debug)]
pub struct VanillaTflite {
    delegates: Vec<ProcId>,
    cpu: ProcId,
    /// Per-decision slot-census scratch, reused across calls.
    free: Vec<usize>,
    taken: Vec<bool>,
    members: Vec<usize>,
}

impl VanillaTflite {
    /// `delegates` must provide one entry per session.
    pub fn new(delegates: Vec<ProcId>, cpu: ProcId) -> Self {
        VanillaTflite {
            delegates,
            cpu,
            free: Vec::new(),
            taken: Vec::new(),
            members: Vec::new(),
        }
    }

    /// Vanilla TFLite 2.16 (the paper's baseline version): the NNAPI
    /// delegate is deprecated and no delegate is enabled by default, so
    /// every model runs on the XNNPACK CPU path. This matches both the
    /// paper's magnitudes (FRS collapses to ~11 FPS — CPU speed for
    /// ArcFace-ResNet50) and its §1 observation that "the majority of DL
    /// inference tasks are performed on CPUs".
    pub fn default_for(soc: &crate::soc::SocSpec, sessions: usize) -> Self {
        VanillaTflite::new(vec![soc.cpu_id(); sessions], soc.cpu_id())
    }

    /// TFLite with an explicitly enabled NNAPI/accelerator delegate
    /// (NPU > DSP > GPU preference) — the configuration of the paper's
    /// §2.2 measurement study (Fig 3's "multi-processor" arm).
    pub fn best_accelerator(soc: &crate::soc::SocSpec, sessions: usize) -> Self {
        use crate::soc::ProcKind;
        let acc = soc
            .proc_by_kind(ProcKind::Npu)
            .or_else(|| soc.proc_by_kind(ProcKind::Dsp))
            .or_else(|| soc.proc_by_kind(ProcKind::Gpu))
            .unwrap_or_else(|| soc.cpu_id());
        VanillaTflite::new(vec![acc; sessions], soc.cpu_id())
    }

    /// Round-robin sessions over the given delegate list (used by the
    /// Fig 10 model-level experiment: model 1 on the GPU, model 2 on the
    /// DSP, etc.).
    pub fn round_robin(procs: &[ProcId], sessions: usize, cpu: ProcId) -> Self {
        let delegates = (0..sessions).map(|s| procs[s % procs.len()]).collect();
        VanillaTflite::new(delegates, cpu)
    }
}

impl Scheduler for VanillaTflite {
    fn name(&self) -> &'static str {
        "tflite"
    }

    fn serializes_sessions(&self) -> bool {
        true // model-level execution: one subgraph of a model at a time
    }

    fn decision_overhead_ms(&self, _plan: &super::ModelPlan) -> crate::TimeMs {
        // TFLite does no dynamic candidate management: the interpreter
        // walks a fixed delegate plan. Only the interpreter-invoke cost.
        0.02
    }

    fn transfer_cost_ms(
        &self,
        soc: &crate::soc::SocSpec,
        from: ProcId,
        to: ProcId,
        bytes: u64,
    ) -> crate::TimeMs {
        // NNAPI partition handoff: an ANeuralNetworksExecution round-trip
        // through the vendor driver plus a staged (non-zero-copy) tensor
        // copy. This is the paper's §2.2 "massive tensor transfer costs"
        // on fallback ops; Band/ADMS avoid it with shared buffers.
        if from == to {
            0.0
        } else {
            NNAPI_SYNC_MS + crate::soc::cost::transfer_ms(soc, from, to, 2 * bytes)
        }
    }

    fn schedule(&mut self, ctx: &SchedCtx, ready: &[PendingTask], out: &mut Vec<Assignment>) {
        let free = &mut self.free;
        super::free_slot_census_into(ctx, free);
        let batching = ctx.batch.enabled();
        let taken = &mut self.taken;
        taken.clear();
        taken.resize(ready.len(), false);
        for (idx, t) in ready.iter().enumerate() {
            if taken[idx] {
                continue;
            }
            let plan = &ctx.plans[t.session];
            let delegate = self.delegates.get(t.session).copied().unwrap_or(self.cpu);
            // Delegate if the unit is supported there, else CPU fallback.
            let target = if plan.partition.units[t.unit].supports(delegate) {
                delegate
            } else {
                self.cpu
            };
            // TFLite blocks until its processor has capacity; it never
            // migrates work elsewhere. A Down delegate blocks it the same
            // way a wedged NNAPI driver blocks real TFLite (the census
            // already reports 0 free slots for Down — the explicit check
            // keeps the rule visible next to the offline one).
            if ctx.procs[target].offline
                || ctx.procs[target].health == crate::monitor::Health::Down
                || free[target] == 0
            {
                continue;
            }
            // Group dispatch models a multi-instance interpreter invoke:
            // concurrent sessions of the same model on the same delegate
            // fuse into one slot (models batched NNAPI executions).
            let b = if batching { ctx.batch.group_limit(idx, taken) } else { 1 };
            taken[idx] = true;
            if b > 1 {
                self.members.clear();
                ctx.batch.members(idx, b, taken, &mut self.members);
                for &m in &self.members {
                    taken[m] = true;
                }
            }
            free[target] -= 1;
            out.push(Assignment { ready_idx: idx, proc: target, batch: b });
        }
    }
}
