//! The discrete-event evaluation entry point.
//!
//! The actual scheduling loop lives in [`crate::exec`]: [`Engine`] is the
//! historical front door that binds the shared [`Driver`] to the
//! calibrated [`SimBackend`] (virtual clock, thermal/DVFS dynamics,
//! contention model). New code should prefer [`crate::exec::Server`],
//! which exposes the same machinery behind a builder and can also run the
//! workload wall-clock on the thread-pool backend.

use crate::exec::{Driver, SimBackend};
use crate::sched::{ModelPlan, Scheduler};
use crate::sim::report::SimReport;
use crate::soc::SocSpec;
use std::sync::Arc;

// Historical homes of these types; they now live in the shared core.
pub use crate::exec::{proc_slots, App, ArrivalMode, SimConfig};

/// The simulation engine. Construct, then [`Engine::run`].
pub struct Engine {
    soc: SocSpec,
    cfg: SimConfig,
    apps: Vec<App>,
    plans: Vec<ModelPlan>,
    scheduler: Box<dyn Scheduler>,
}

impl Engine {
    /// `window_size` selects the partitioning granularity used to build
    /// each app's plan (1 = Band-style, tuned value for ADMS).
    pub fn new(
        soc: SocSpec,
        cfg: SimConfig,
        apps: Vec<App>,
        scheduler: Box<dyn Scheduler>,
        window_size: &dyn Fn(&crate::graph::Graph) -> usize,
    ) -> anyhow::Result<Self> {
        let mut plans = Vec::new();
        for app in &apps {
            let g = crate::zoo::by_name(&app.model)
                .ok_or_else(|| anyhow::anyhow!("unknown model '{}'", app.model))?;
            let ws = window_size(&g);
            plans.push(ModelPlan::build_cached(Arc::new(g), &soc, ws));
        }
        Ok(Engine { soc, cfg, apps, plans, scheduler })
    }

    pub fn run(self) -> SimReport {
        let backend = Box::new(SimBackend::new(self.soc, self.cfg.clone()));
        Driver::new(self.cfg, self.apps, self.plans, self.scheduler, backend).run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::BOARD_BASELINE_W;
    use crate::sched::{Adms, Band, Pinned, VanillaTflite};
    use crate::soc::{dimensity9000, ProcKind};

    fn quick_cfg(ms: f64) -> SimConfig {
        SimConfig { duration_ms: ms, ..SimConfig::default() }
    }

    #[test]
    fn single_session_completes_requests() {
        let soc = dimensity9000();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(2_000.0),
            vec![App::closed_loop("mobilenet_v1")],
            Box::new(Adms::default()),
            &|_| 5,
        )
        .unwrap();
        let r = eng.run();
        assert!(r.total_completed() > 10, "completed={}", r.total_completed());
        assert_eq!(r.total_failed(), 0);
        assert!(r.sessions[0].latency.mean() > 0.0);
        assert!(r.energy_j > 0.0);
        assert!(!r.timeline.is_empty());
        // The refactored engine reports its substrate and decision trace.
        assert_eq!(r.backend, "sim");
        assert!(!r.assignments.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let soc = dimensity9000();
        let mk = || {
            Engine::new(
                soc.clone(),
                quick_cfg(1_000.0),
                vec![App::closed_loop("mobilenet_v2"), App::closed_loop("east")],
                Box::new(Band::new()),
                &|_| 1,
            )
            .unwrap()
            .run()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.total_completed(), b.total_completed());
        assert_eq!(a.sessions[0].fps, b.sessions[0].fps);
        assert!((a.energy_j - b.energy_j).abs() < 1e-9);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn pinned_on_npu_matches_cost_model_calibration() {
        // Single MobileNetV1 closed-loop on the Dimensity 9000 NPU: the
        // measured request latency must sit near the Table 2 value
        // (1.88 ms) plus transfer/management overheads.
        let soc = dimensity9000();
        let npu = soc.proc_by_kind(ProcKind::Npu).unwrap();
        let cpu = soc.cpu_id();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(2_000.0),
            vec![App::closed_loop("mobilenet_v1_quant")],
            Box::new(Pinned::new(npu, cpu)),
            &|_| 1,
        )
        .unwrap();
        let r = eng.run();
        let mean = r.sessions[0].latency.mean();
        assert!((1.2..5.0).contains(&mean), "latency {mean} ms");
    }

    #[test]
    fn concurrency_inflates_latency_per_contention_model() {
        let soc = dimensity9000();
        let npu = soc.proc_by_kind(ProcKind::Npu).unwrap();
        let cpu = soc.cpu_id();
        let run = |n: usize| {
            let apps = vec![App::closed_loop("mobilenet_v1_quant"); n];
            Engine::new(
                soc.clone(),
                quick_cfg(2_000.0),
                apps,
                Box::new(Pinned::new(npu, cpu)),
                &|_| 1,
            )
            .unwrap()
            .run()
            .sessions
            .iter()
            .map(|s| s.latency.mean())
            .sum::<f64>()
                / n as f64
        };
        let l1 = run(1);
        let l4 = run(4);
        let ratio = l4 / l1;
        // Table 2 NPU: ×1.27 at 4 concurrent models (tolerance for queueing).
        assert!((1.1..1.9).contains(&ratio), "l1={l1:.2} l4={l4:.2} ratio={ratio:.2}");
    }

    #[test]
    fn tflite_serializes_vanilla_sessions() {
        let soc = dimensity9000();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(1_000.0),
            vec![App::closed_loop("mobilenet_v1")],
            Box::new(VanillaTflite::best_accelerator(&soc, 1)),
            &|_| 1,
        )
        .unwrap();
        let r = eng.run();
        assert!(r.total_completed() > 5);
        // Model-level: at most one unit of the session in flight at once —
        // timeline events for the session must not overlap.
        let mut evs = r.timeline.clone();
        evs.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in evs.windows(2) {
            assert!(
                w[1].start >= w[0].end - 1e-9,
                "overlapping intervals for a serialized session"
            );
        }
    }

    #[test]
    fn sustained_load_heats_processors() {
        let soc = dimensity9000();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(120_000.0),
            vec![
                App::closed_loop("yolo_v3"),
                App::closed_loop("deeplab_v3"),
                App::closed_loop("inception_v4"),
            ],
            Box::new(VanillaTflite::best_accelerator(&soc, 3)),
            &|_| 1,
        )
        .unwrap();
        let r = eng.run();
        let max_temp = r
            .procs
            .iter()
            .map(|p| p.temp.max())
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(max_temp > 40.0, "nothing heated up: max {max_temp} °C");
        assert!(r.avg_power_w() > BOARD_BASELINE_W);
    }

    /// Regression: under sustained overload (tiny failure budget, slow
    /// processors), aborted closed-loop requests must re-arm exactly once
    /// — a double re-arm snowballs into an exponential request storm
    /// (observed as 1M+ events in a 300 s stress sim before the fix).
    #[test]
    fn overload_does_not_storm_requests() {
        let soc = dimensity9000();
        let cfg = SimConfig {
            duration_ms: 30_000.0,
            fail_mult: 0.5, // budget far below achievable latency
            ..SimConfig::default()
        };
        let eng = Engine::new(
            soc.clone(),
            cfg,
            vec![App::closed_loop("east"), App::closed_loop("deeplab_v3")],
            Box::new(VanillaTflite::default_for(&soc, 2)),
            &|_| 1,
        )
        .unwrap();
        let r = eng.run();
        // With one outstanding request per session, total issued requests
        // over 30 s is bounded by completions + aborts at tick cadence.
        let issued = r.total_completed() + r.total_failed();
        assert!(issued < 5_000, "request storm: {issued} requests issued");
        assert!(r.total_failed() > 0, "budget was supposed to be tight");
    }

    #[test]
    fn slo_satisfaction_reported() {
        let soc = dimensity9000();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(2_000.0),
            vec![App::with_slo("mobilenet_v1", 100.0)],
            Box::new(Adms::default()),
            &|_| 5,
        )
        .unwrap();
        let r = eng.run();
        let slo = r.sessions[0].slo_satisfaction.unwrap();
        assert!(slo > 0.9, "generous SLO should be satisfied, got {slo}");
    }
}
