//! The discrete-event engine.

use crate::monitor::{HardwareMonitor, ProcView, REFRESH_CPU_MS};
use crate::power::{processor_power_w, BOARD_BASELINE_W, EnergyMeter};
use crate::sched::{ModelPlan, PendingTask, ReqId, SchedCtx, Scheduler, SessId};
use crate::sim::report::{ProcStats, SessionStats, SimReport, TimelineEvent};
use crate::soc::{ProcessorSpec, SocSpec};
use crate::thermal::ThermalState;
use crate::util::rng::Pcg32;
use crate::util::stats::{Summary, TimeSeries};
use crate::TimeMs;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// Execution slots of a processor (re-exported helper for schedulers).
pub fn proc_slots(spec: &ProcessorSpec) -> usize {
    spec.parallel_slots.max(1)
}

/// How a session issues requests.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalMode {
    /// Re-request as soon as the previous inference finishes (continuous
    /// video processing — the paper's FPS workloads).
    ClosedLoop,
    /// Fixed inter-arrival period, ms.
    Periodic(f64),
    /// Poisson arrivals with the given rate (requests/second).
    Poisson(f64),
}

/// One concurrently-running application.
#[derive(Debug, Clone)]
pub struct App {
    pub model: String,
    pub slo_ms: Option<f64>,
    pub mode: ArrivalMode,
}

impl App {
    pub fn closed_loop(model: &str) -> Self {
        App { model: model.into(), slo_ms: None, mode: ArrivalMode::ClosedLoop }
    }
    pub fn with_slo(model: &str, slo_ms: f64) -> Self {
        App { model: model.into(), slo_ms: Some(slo_ms), mode: ArrivalMode::ClosedLoop }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub duration_ms: TimeMs,
    /// Governor/thermal/power tick, ms.
    pub tick_ms: f64,
    /// Monitor cache interval (staleness bound of the scheduler's view).
    pub monitor_cache_ms: f64,
    pub seed: u64,
    /// A request fails (is aborted) once its age exceeds
    /// `fail_mult × SLO` (or `fail_mult × 3 × est` without an SLO).
    pub fail_mult: f64,
    /// Ambient temperature override (35 °C for the thermal stress test).
    pub ambient_c: Option<f64>,
    /// Cap on recorded timeline events (Gantt data for Fig 10).
    pub timeline_cap: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            duration_ms: 60_000.0,
            tick_ms: 100.0,
            monitor_cache_ms: 50.0,
            seed: 42,
            fail_mult: 10.0,
            ambient_c: None,
            timeline_cap: 20_000,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN event time")
    }
}

#[derive(Debug)]
enum Ev {
    Arrival(SessId),
    Complete { proc: usize, run_id: u64 },
    Tick,
}

/// Heap entry ordered by (time, sequence); the payload is not compared.
#[derive(Debug)]
struct QEv {
    t: OrdF64,
    seq: u64,
    ev: Ev,
}
impl PartialEq for QEv {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for QEv {}
impl PartialOrd for QEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// A task currently resident on a processor slot.
#[derive(Debug, Clone)]
struct Running {
    run_id: u64,
    req: ReqId,
    session: SessId,
    unit: usize,
    start: TimeMs,
    end: TimeMs,
}

/// Per-request bookkeeping.
#[derive(Debug)]
struct ReqState {
    session: SessId,
    arrival: TimeMs,
    slo_ms: Option<f64>,
    deps_remaining: Vec<usize>,
    unit_proc: Vec<Option<usize>>,
    units_left: usize,
    failed: bool,
}

/// Dynamic per-processor state.
struct ProcState {
    thermal: ThermalState,
    running: Vec<Running>,
    /// Estimated ms of work resident (running remainder + committed).
    backlog_ms: f64,
    /// Sessions that recently touched this processor: (session, time).
    recent_sessions: Vec<(SessId, TimeMs)>,
    busy_ms: f64,       // wall time with ≥1 task, total
    slot_ms: f64,       // Σ per-slot occupied time, total
    tick_busy_ms: f64,  // within current tick (for power/util)
    tick_slot_ms: f64,
    dispatches: u64,
    temp_series: TimeSeries,
    freq_series: TimeSeries,
}

/// The simulation engine. Construct, then [`Engine::run`].
pub struct Engine {
    soc: SocSpec,
    cfg: SimConfig,
    apps: Vec<App>,
    plans: Vec<ModelPlan>,
    scheduler: Box<dyn Scheduler>,
}

const SESSION_WINDOW_MS: f64 = 100.0;

impl Engine {
    /// `window_size` selects the partitioning granularity used to build
    /// each app's plan (1 = Band-style, tuned value for ADMS).
    pub fn new(
        soc: SocSpec,
        cfg: SimConfig,
        apps: Vec<App>,
        scheduler: Box<dyn Scheduler>,
        window_size: &dyn Fn(&crate::graph::Graph) -> usize,
    ) -> anyhow::Result<Self> {
        let mut plans = Vec::new();
        for app in &apps {
            let g = crate::zoo::by_name(&app.model)
                .ok_or_else(|| anyhow::anyhow!("unknown model '{}'", app.model))?;
            let ws = window_size(&g);
            plans.push(ModelPlan::build(Arc::new(g), &soc, ws));
        }
        Ok(Engine { soc, cfg, apps, plans, scheduler })
    }

    pub fn run(mut self) -> SimReport {
        let ambient = self.cfg.ambient_c.unwrap_or(self.soc.ambient_c);
        let np = self.soc.num_processors();
        let mut procs: Vec<ProcState> = (0..np)
            .map(|_| ProcState {
                thermal: ThermalState::new(ambient),
                running: Vec::new(),
                backlog_ms: 0.0,
                recent_sessions: Vec::new(),
                busy_ms: 0.0,
                slot_ms: 0.0,
                tick_busy_ms: 0.0,
                tick_slot_ms: 0.0,
                dispatches: 0,
                temp_series: TimeSeries::default(),
                freq_series: TimeSeries::default(),
            })
            .collect();
        let mut rng = Pcg32::seeded(self.cfg.seed);
        let mut monitor = HardwareMonitor::new(self.cfg.monitor_cache_ms);
        let mut heap: BinaryHeap<Reverse<QEv>> = BinaryHeap::new();
        let mut seq: u64 = 0;
        let push = |heap: &mut BinaryHeap<Reverse<QEv>>, seq: &mut u64, t: f64, ev: Ev| {
            *seq += 1;
            heap.push(Reverse(QEv { t: OrdF64(t), seq: *seq, ev }));
        };

        // Session stats.
        let mut completed = vec![0u64; self.apps.len()];
        let mut failed = vec![0u64; self.apps.len()];
        let mut lat: Vec<Summary> = (0..self.apps.len()).map(|_| Summary::new()).collect();
        let mut slo_ok = vec![0u64; self.apps.len()];
        let mut slo_n = vec![0u64; self.apps.len()];

        // Request state.
        let mut reqs: std::collections::HashMap<ReqId, ReqState> = Default::default();
        let mut next_req: ReqId = 0;
        let mut ready: Vec<PendingTask> = Vec::new();
        let mut run_seq: u64 = 0;

        let mut energy = EnergyMeter::new();
        let mut power_series = TimeSeries::default();
        let mut timeline: Vec<TimelineEvent> = Vec::new();
        let mut last_event_t: TimeMs = 0.0;
        let mut monitor_cpu_ms = 0.0;

        // Prime arrivals and the governor tick.
        for s in 0..self.apps.len() {
            push(&mut heap, &mut seq, 0.0, Ev::Arrival(s));
        }
        push(&mut heap, &mut seq, self.cfg.tick_ms, Ev::Tick);

        let debug = std::env::var_os("ADMS_SIM_DEBUG").is_some();
        let mut n_events: u64 = 0;
        let mut n_dispatch_rounds: u64 = 0;
        while let Some(Reverse(QEv { t: OrdF64(now), ev, .. })) = heap.pop() {
            if now > self.cfg.duration_ms {
                break;
            }
            n_events += 1;
            if debug && n_events % 2_000 == 0 {
                eprintln!(
                    "t={now:.0} events={n_events} rounds={n_dispatch_rounds} heap={} ready={} reqs={}",
                    heap.len(), ready.len(), reqs.len()
                );
            }
            // Accumulate busy time since the previous event.
            let dt = now - last_event_t;
            if dt > 0.0 {
                for p in procs.iter_mut() {
                    if !p.running.is_empty() {
                        p.busy_ms += dt;
                        p.tick_busy_ms += dt;
                        let n = p.running.len() as f64;
                        p.slot_ms += dt * n;
                        p.tick_slot_ms += dt * n;
                    }
                }
            }
            last_event_t = now;

            match ev {
                Ev::Arrival(s) => {
                    let id = next_req;
                    next_req += 1;
                    let plan = &self.plans[s];
                    let nu = plan.num_units();
                    let st = ReqState {
                        session: s,
                        arrival: now,
                        slo_ms: self.apps[s].slo_ms,
                        deps_remaining: plan.deps.iter().map(|d| d.len()).collect(),
                        unit_proc: vec![None; nu],
                        units_left: nu,
                        failed: false,
                    };
                    // Enqueue units with no dependencies.
                    for u in 0..nu {
                        if st.deps_remaining[u] == 0 {
                            ready.push(PendingTask {
                                req: id,
                                session: s,
                                unit: u,
                                ready_at: now,
                                req_arrival: now,
                                slo_ms: st.slo_ms,
                                remaining_ms: plan.remaining_ms((0..nu).filter(|&x| x != u)),
                                dep_procs: vec![],
                            });
                        }
                    }
                    reqs.insert(id, st);
                    // Open-loop arrivals re-arm immediately.
                    match self.apps[s].mode {
                        ArrivalMode::Periodic(p) => push(&mut heap, &mut seq, now + p, Ev::Arrival(s)),
                        ArrivalMode::Poisson(rate) => {
                            let gap = rng.exp(rate / 1e3);
                            push(&mut heap, &mut seq, now + gap, Ev::Arrival(s));
                        }
                        ArrivalMode::ClosedLoop => {}
                    }
                }
                Ev::Complete { proc, run_id } => {
                    let Some(pos) = procs[proc].running.iter().position(|r| r.run_id == run_id)
                    else {
                        continue;
                    };
                    let done = procs[proc].running.remove(pos);
                    procs[proc].backlog_ms =
                        (procs[proc].backlog_ms - (done.end - done.start)).max(0.0);
                    if timeline.len() < self.cfg.timeline_cap {
                        timeline.push(TimelineEvent {
                            proc,
                            session: done.session,
                            req: done.req,
                            unit: done.unit,
                            start: done.start,
                            end: done.end,
                        });
                    }
                    let finished = {
                        let Some(st) = reqs.get_mut(&done.req) else { continue };
                        if st.failed {
                            // Aborted while running; drop silently.
                            st.units_left -= 1;
                            st.units_left == 0
                        } else {
                            st.unit_proc[done.unit] = Some(proc);
                            st.units_left -= 1;
                            let plan = &self.plans[done.session];
                            // Unlock consumers.
                            for &c in &plan.consumers[done.unit] {
                                st.deps_remaining[c] -= 1;
                                if st.deps_remaining[c] == 0 {
                                    let unfinished: Vec<usize> = (0..plan.num_units())
                                        .filter(|&u| {
                                            u != c && st.unit_proc[u].is_none()
                                        })
                                        .collect();
                                    ready.push(PendingTask {
                                        req: done.req,
                                        session: done.session,
                                        unit: c,
                                        ready_at: now,
                                        req_arrival: st.arrival,
                                        slo_ms: st.slo_ms,
                                        remaining_ms: plan
                                            .remaining_ms(unfinished.into_iter()),
                                        dep_procs: plan.deps[c]
                                            .iter()
                                            .map(|&d| (d, st.unit_proc[d].unwrap_or(proc)))
                                            .collect(),
                                    });
                                }
                            }
                            st.units_left == 0
                        }
                    };
                    if finished {
                        let st = reqs.remove(&done.req).unwrap();
                        let s = st.session;
                        if !st.failed {
                            let latency = now - st.arrival;
                            completed[s] += 1;
                            lat[s].add(latency);
                            if let Some(slo) = st.slo_ms {
                                slo_n[s] += 1;
                                if latency <= slo {
                                    slo_ok[s] += 1;
                                }
                            }
                            // Failed requests already re-armed their
                            // session at abort time — re-arming here too
                            // would double the closed loop and snowball
                            // under sustained overload.
                            if matches!(self.apps[s].mode, ArrivalMode::ClosedLoop) {
                                push(&mut heap, &mut seq, now, Ev::Arrival(s));
                            }
                        }
                    }
                }
                Ev::Tick => {
                    // Thermal integration + governor + power sample.
                    let mut total_w = BOARD_BASELINE_W;
                    for (i, p) in procs.iter_mut().enumerate() {
                        let spec = &self.soc.processors[i];
                        let util_power = (p.tick_busy_ms / self.cfg.tick_ms).clamp(0.0, 1.0);
                        let fs = p.thermal.freq_scale(spec);
                        let w = processor_power_w(spec, util_power, if p.thermal.offline { 0.2 } else { fs });
                        p.thermal.integrate(spec, ambient, w, self.cfg.tick_ms);
                        p.thermal.govern(spec, now);
                        total_w += w;
                        p.temp_series.push(now, p.thermal.temp_c);
                        p.freq_series.push(now, p.thermal.freq_mhz(spec));
                        p.tick_busy_ms = 0.0;
                        p.tick_slot_ms = 0.0;
                    }
                    energy.accumulate(total_w, self.cfg.tick_ms);
                    power_series.push(now, total_w);

                    // Failure sweep: abort requests far past their budget.
                    let mut aborted: Vec<ReqId> = Vec::new();
                    for (&id, st) in reqs.iter_mut() {
                        if st.failed {
                            continue;
                        }
                        let budget = st
                            .slo_ms
                            .unwrap_or(self.plans[st.session].est_total_ms * 3.0)
                            * self.cfg.fail_mult;
                        if now - st.arrival > budget {
                            st.failed = true;
                            failed[st.session] += 1;
                            if st.slo_ms.is_some() {
                                slo_n[st.session] += 1;
                            }
                            aborted.push(id);
                        }
                    }
                    if !aborted.is_empty() {
                        ready.retain(|t| !aborted.contains(&t.req));
                        // Closed-loop sessions re-arm after an abort.
                        for id in aborted {
                            let st = &reqs[&id];
                            let s = st.session;
                            let pending_units =
                                st.units_left > self.running_units(&procs, id);
                            if matches!(self.apps[s].mode, ArrivalMode::ClosedLoop) {
                                push(&mut heap, &mut seq, now, Ev::Arrival(s));
                            }
                            if pending_units {
                                // Unscheduled units will never run; account
                                // them as done so the request can retire.
                                let left = self.running_units(&procs, id);
                                if let Some(stm) = reqs.get_mut(&id) {
                                    stm.units_left = left.max(0) as usize;
                                    if stm.units_left == 0 {
                                        reqs.remove(&id);
                                    }
                                }
                            }
                        }
                    }
                    push(&mut heap, &mut seq, now + self.cfg.tick_ms, Ev::Tick);
                }
            }

            // Dispatch loop: keep asking the scheduler while it makes
            // progress and capacity remains.
            loop {
                n_dispatch_rounds += 1;
                if ready.is_empty() {
                    break;
                }
                // Build monitor views (respecting the cache interval).
                let views_needed = monitor.staleness(now) >= self.cfg.monitor_cache_ms;
                if views_needed {
                    monitor_cpu_ms += REFRESH_CPU_MS;
                }
                let views: Vec<ProcView> = {
                    let soc = &self.soc;
                    let cfg_tick = self.cfg.tick_ms;
                    monitor
                        .sample(now, || {
                            procs
                                .iter()
                                .enumerate()
                                .map(|(i, p)| {
                                    let spec = &soc.processors[i];
                                    ProcView {
                                        id: i,
                                        kind: spec.kind,
                                        temp_c: p.thermal.temp_c,
                                        freq_mhz: p.thermal.freq_mhz(spec),
                                        freq_scale: p.thermal.freq_scale(spec),
                                        offline: p.thermal.offline,
                                        load: p.running.len() as f64
                                            / proc_slots(spec) as f64,
                                        backlog_ms: p.backlog_ms,
                                        active_sessions: active_sessions(p, now),
                                        util: (p.tick_busy_ms / cfg_tick).min(1.0),
                                        headroom_c: p.thermal.headroom_c(spec),
                                    }
                                })
                                .collect()
                        })
                        .to_vec()
                };
                // Expose ready tasks (serialized policies see only each
                // session's earliest ready unit).
                // Serialized policies see only each session's earliest ready
                // unit; other policies see the queue directly (no copy —
                // this loop is the simulation's hot path).
                let exposed: Option<Vec<usize>> = if self.scheduler.serializes_sessions() {
                    let mut first: std::collections::BTreeMap<SessId, (usize, usize)> =
                        Default::default();
                    for (i, t) in ready.iter().enumerate() {
                        let e = first.entry(t.session).or_insert((i, t.unit));
                        if t.unit < e.1 {
                            *e = (i, t.unit);
                        }
                    }
                    Some(first.values().map(|&(i, _)| i).collect())
                } else {
                    None
                };
                let ctx = SchedCtx { now, soc: &self.soc, plans: &self.plans, procs: &views };
                let assignments = match &exposed {
                    Some(idx) => {
                        let exposed_tasks: Vec<PendingTask> =
                            idx.iter().map(|&i| ready[i].clone()).collect();
                        self.scheduler.schedule(&ctx, &exposed_tasks)
                    }
                    None => self.scheduler.schedule(&ctx, &ready),
                };
                if assignments.is_empty() {
                    break;
                }
                // Apply (validate defensively), collecting indices to drop.
                let mut dispatched: Vec<usize> = Vec::new();
                for a in assignments {
                    let ridx = match &exposed {
                        Some(idx) => match idx.get(a.ready_idx) {
                            Some(&r) => r,
                            None => continue,
                        },
                        None => {
                            if a.ready_idx >= ready.len() {
                                continue;
                            }
                            a.ready_idx
                        }
                    };
                    if dispatched.contains(&ridx) {
                        continue;
                    }
                    let t = &ready[ridx];
                    let plan = &self.plans[t.session];
                    let spec = &self.soc.processors[a.proc];
                    let pstate = &procs[a.proc];
                    if pstate.thermal.offline
                        || pstate.running.len() >= proc_slots(spec)
                        || !plan.partition.units[t.unit].supports(a.proc)
                    {
                        continue;
                    }
                    // Service time: exec at current frequency × contention
                    // + transfers + per-dispatch management overhead.
                    let fs = pstate.thermal.freq_scale(spec).max(0.05);
                    let exec = match plan.exec_estimate(t.unit, a.proc, fs) {
                        Some(e) => e,
                        None => continue,
                    };
                    // Distinct sessions resident on this processor,
                    // counting the dispatching task's session exactly once.
                    let nsess = active_sessions_with(pstate, now, t.session)
                        .max(pstate.running.len() + 1);
                    let mult = spec.contention_mult(nsess);
                    let xfer: f64 = t
                        .dep_procs
                        .iter()
                        .map(|&(du, dp)| {
                            let bytes = plan.xfer_bytes[t.unit]
                                .iter()
                                .find(|(d, _)| *d == du)
                                .map(|(_, b)| *b)
                                .unwrap_or(0);
                            self.scheduler.transfer_cost_ms(&self.soc, dp, a.proc, bytes)
                        })
                        .sum();
                    let mgmt = self.scheduler.decision_overhead_ms(plan);
                    let service = exec * mult + xfer + mgmt;
                    run_seq += 1;
                    let run = Running {
                        run_id: run_seq,
                        req: t.req,
                        session: t.session,
                        unit: t.unit,
                        start: now,
                        end: now + service,
                    };
                    push(&mut heap, &mut seq, run.end, Ev::Complete { proc: a.proc, run_id: run_seq });
                    let p = &mut procs[a.proc];
                    p.backlog_ms += service;
                    p.dispatches += 1;
                    touch_session(p, t.session, now);
                    p.running.push(run);
                    dispatched.push(ridx);
                }
                if dispatched.is_empty() {
                    break;
                }
                dispatched.sort_unstable_by(|a, b| b.cmp(a));
                for i in dispatched {
                    ready.swap_remove(i);
                }
            }
        }

        // Assemble the report.
        let duration = self.cfg.duration_ms;
        let sessions: Vec<SessionStats> = (0..self.apps.len())
            .map(|s| SessionStats {
                model: self.apps[s].model.clone(),
                completed: completed[s],
                failed: failed[s],
                latency: lat[s].clone(),
                fps: completed[s] as f64 / (duration / 1e3),
                slo_satisfaction: if slo_n[s] > 0 {
                    Some(slo_ok[s] as f64 / slo_n[s] as f64)
                } else {
                    None
                },
            })
            .collect();
        let procs_stats: Vec<ProcStats> = procs
            .into_iter()
            .enumerate()
            .map(|(i, p)| ProcStats {
                name: self.soc.processors[i].name.clone(),
                busy_frac: p.busy_ms / duration,
                avg_load: p.slot_ms / (duration * proc_slots(&self.soc.processors[i]) as f64),
                temp: p.temp_series,
                freq: p.freq_series,
                throttle_events: p.thermal.throttle_events,
                first_throttle_ms: p.thermal.first_throttle_ms,
                dispatches: p.dispatches,
            })
            .collect();
        let _ = monitor_cpu_ms; // charged implicitly via monitor refresh count
        SimReport {
            scheduler: self.scheduler.name().to_string(),
            duration_ms: duration,
            sessions,
            procs: procs_stats,
            power: power_series,
            energy_j: energy.joules(),
            timeline,
            monitor_refreshes: monitor.refresh_count(),
        }
    }

    fn running_units(&self, procs: &[ProcState], req: ReqId) -> usize {
        procs
            .iter()
            .map(|p| p.running.iter().filter(|r| r.req == req).count())
            .sum()
    }
}

fn active_sessions(p: &ProcState, now: TimeMs) -> usize {
    let mut sessions: Vec<SessId> =
        p.running.iter().map(|r| r.session).collect();
    for &(s, t) in &p.recent_sessions {
        if now - t <= SESSION_WINDOW_MS {
            sessions.push(s);
        }
    }
    sessions.sort_unstable();
    sessions.dedup();
    sessions.len()
}

/// `active_sessions` with `extra` included exactly once (the session of a
/// task being dispatched must not double-count against its own recent
/// residency).
fn active_sessions_with(p: &ProcState, now: TimeMs, extra: SessId) -> usize {
    let mut sessions: Vec<SessId> =
        p.running.iter().map(|r| r.session).collect();
    for &(s, t) in &p.recent_sessions {
        if now - t <= SESSION_WINDOW_MS {
            sessions.push(s);
        }
    }
    sessions.push(extra);
    sessions.sort_unstable();
    sessions.dedup();
    sessions.len()
}

fn touch_session(p: &mut ProcState, s: SessId, now: TimeMs) {
    p.recent_sessions.retain(|&(ss, t)| ss != s && now - t <= SESSION_WINDOW_MS);
    p.recent_sessions.push((s, now));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{Adms, Band, Pinned, VanillaTflite};
    use crate::soc::{dimensity9000, ProcKind};

    fn quick_cfg(ms: f64) -> SimConfig {
        SimConfig { duration_ms: ms, ..SimConfig::default() }
    }

    #[test]
    fn single_session_completes_requests() {
        let soc = dimensity9000();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(2_000.0),
            vec![App::closed_loop("mobilenet_v1")],
            Box::new(Adms::default()),
            &|_| 5,
        )
        .unwrap();
        let r = eng.run();
        assert!(r.total_completed() > 10, "completed={}", r.total_completed());
        assert_eq!(r.total_failed(), 0);
        assert!(r.sessions[0].latency.mean() > 0.0);
        assert!(r.energy_j > 0.0);
        assert!(!r.timeline.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let soc = dimensity9000();
        let mk = || {
            Engine::new(
                soc.clone(),
                quick_cfg(1_000.0),
                vec![App::closed_loop("mobilenet_v2"), App::closed_loop("east")],
                Box::new(Band::new()),
                &|_| 1,
            )
            .unwrap()
            .run()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.total_completed(), b.total_completed());
        assert_eq!(a.sessions[0].fps, b.sessions[0].fps);
        assert!((a.energy_j - b.energy_j).abs() < 1e-9);
    }

    #[test]
    fn pinned_on_npu_matches_cost_model_calibration() {
        // Single MobileNetV1 closed-loop on the Dimensity 9000 NPU: the
        // measured request latency must sit near the Table 2 value
        // (1.88 ms) plus transfer/management overheads.
        let soc = dimensity9000();
        let npu = soc.proc_by_kind(ProcKind::Npu).unwrap();
        let cpu = soc.cpu_id();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(2_000.0),
            vec![App::closed_loop("mobilenet_v1_quant")],
            Box::new(Pinned::new(npu, cpu)),
            &|_| 1,
        )
        .unwrap();
        let r = eng.run();
        let mean = r.sessions[0].latency.mean();
        assert!((1.2..5.0).contains(&mean), "latency {mean} ms");
    }

    #[test]
    fn concurrency_inflates_latency_per_contention_model() {
        let soc = dimensity9000();
        let npu = soc.proc_by_kind(ProcKind::Npu).unwrap();
        let cpu = soc.cpu_id();
        let run = |n: usize| {
            let apps = vec![App::closed_loop("mobilenet_v1_quant"); n];
            Engine::new(
                soc.clone(),
                quick_cfg(2_000.0),
                apps,
                Box::new(Pinned::new(npu, cpu)),
                &|_| 1,
            )
            .unwrap()
            .run()
            .sessions
            .iter()
            .map(|s| s.latency.mean())
            .sum::<f64>()
                / n as f64
        };
        let l1 = run(1);
        let l4 = run(4);
        let ratio = l4 / l1;
        // Table 2 NPU: ×1.27 at 4 concurrent models (tolerance for queueing).
        assert!((1.1..1.9).contains(&ratio), "l1={l1:.2} l4={l4:.2} ratio={ratio:.2}");
    }

    #[test]
    fn tflite_serializes_vanilla_sessions() {
        let soc = dimensity9000();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(1_000.0),
            vec![App::closed_loop("mobilenet_v1")],
            Box::new(VanillaTflite::best_accelerator(&soc, 1)),
            &|_| 1,
        )
        .unwrap();
        let r = eng.run();
        assert!(r.total_completed() > 5);
        // Model-level: at most one unit of the session in flight at once —
        // timeline events for the session must not overlap.
        let mut evs = r.timeline.clone();
        evs.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in evs.windows(2) {
            assert!(
                w[1].start >= w[0].end - 1e-9,
                "overlapping intervals for a serialized session"
            );
        }
    }

    #[test]
    fn sustained_load_heats_processors() {
        let soc = dimensity9000();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(120_000.0),
            vec![
                App::closed_loop("yolo_v3"),
                App::closed_loop("deeplab_v3"),
                App::closed_loop("inception_v4"),
            ],
            Box::new(VanillaTflite::best_accelerator(&soc, 3)),
            &|_| 1,
        )
        .unwrap();
        let r = eng.run();
        let max_temp = r
            .procs
            .iter()
            .map(|p| p.temp.max())
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(max_temp > 40.0, "nothing heated up: max {max_temp} °C");
        assert!(r.avg_power_w() > BOARD_BASELINE_W);
    }

    /// Regression: under sustained overload (tiny failure budget, slow
    /// processors), aborted closed-loop requests must re-arm exactly once
    /// — a double re-arm snowballs into an exponential request storm
    /// (observed as 1M+ events in a 300 s stress sim before the fix).
    #[test]
    fn overload_does_not_storm_requests() {
        let soc = dimensity9000();
        let cfg = SimConfig {
            duration_ms: 30_000.0,
            fail_mult: 0.5, // budget far below achievable latency
            ..SimConfig::default()
        };
        let eng = Engine::new(
            soc.clone(),
            cfg,
            vec![App::closed_loop("east"), App::closed_loop("deeplab_v3")],
            Box::new(VanillaTflite::default_for(&soc, 2)),
            &|_| 1,
        )
        .unwrap();
        let r = eng.run();
        // With one outstanding request per session, total issued requests
        // over 30 s is bounded by completions + aborts at tick cadence.
        let issued = r.total_completed() + r.total_failed();
        assert!(issued < 5_000, "request storm: {issued} requests issued");
        assert!(r.total_failed() > 0, "budget was supposed to be tight");
    }

    #[test]
    fn slo_satisfaction_reported() {
        let soc = dimensity9000();
        let eng = Engine::new(
            soc.clone(),
            quick_cfg(2_000.0),
            vec![App::with_slo("mobilenet_v1", 100.0)],
            Box::new(Adms::default()),
            &|_| 5,
        )
        .unwrap();
        let r = eng.run();
        let slo = r.sessions[0].slo_satisfaction.unwrap();
        assert!(slo > 0.9, "generous SLO should be satisfied, got {slo}");
    }
}
