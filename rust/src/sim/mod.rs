//! Discrete-event simulation of multi-DNN serving on a mobile SoC.
//!
//! The shared [`Driver`](crate::exec::Driver) drives a
//! [`Scheduler`](crate::sched::Scheduler) against the calibrated SoC
//! model ([`crate::exec::SimBackend`]): request arrivals become per-unit
//! tasks, the scheduler places ready tasks on processors, service times
//! come from the roofline cost model adjusted for DVFS state and session
//! contention, and a periodic governor tick integrates the thermal model,
//! applies throttling, and samples power — producing every signal the
//! paper's evaluation reports (latency, FPS, SLO satisfaction, power
//! traces, temperature/frequency dynamics, failure counts). [`Engine`] is
//! the evaluation front door; the same loop serves wall-clock through
//! [`crate::exec::Server`].

pub mod engine;
pub mod report;

pub use engine::{App, ArrivalMode, Engine, SimConfig};
pub use report::{SimReport, TimelineEvent};
