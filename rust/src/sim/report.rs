//! Simulation outputs: everything the paper's tables and figures need.

use crate::util::stats::{Summary, TimeSeries};
use crate::TimeMs;

/// One executed task interval (Fig 10's Gantt rows).
#[derive(Debug, Clone)]
pub struct TimelineEvent {
    pub proc: usize,
    pub session: usize,
    pub req: u64,
    pub unit: usize,
    pub start: TimeMs,
    pub end: TimeMs,
}

/// Per-session (application) results.
#[derive(Debug, Clone)]
pub struct SessionStats {
    pub model: String,
    /// Requests issued by the arrival process (conservation:
    /// `issued == completed + failed + cancelled`, always).
    pub issued: u64,
    pub completed: u64,
    pub failed: u64,
    /// Requests cancelled by the workload itself — a `SessionStop` event
    /// retiring the session, or the run ending with the request open.
    /// Unlike `failed`, these are not the system's fault and do not count
    /// against SLO satisfaction.
    pub cancelled: u64,
    pub latency: Summary,
    /// Completed requests per second of the session's *active* window
    /// (admission to retirement; the full run for static sessions).
    pub fps: f64,
    /// Fraction of requests finishing within their SLO (failures count
    /// as misses). `None` when the session has no SLO.
    pub slo_satisfaction: Option<f64>,
    /// Requests that finished within their SLO (numerator of
    /// `slo_satisfaction`). Raw counts so aggregation layers (the fleet
    /// digest) can merge SLO attainment exactly instead of averaging
    /// per-session ratios.
    pub slo_ok: u64,
    /// SLO-scored retirements (completions + failures of SLO-carrying
    /// requests — the denominator).
    pub slo_n: u64,
    /// When the session was admitted (0 for static workloads).
    pub start_ms: TimeMs,
    /// When a `SessionStop` event retired it (`None` = ran to the end).
    pub stop_ms: Option<TimeMs>,
    /// Active window the rate metrics are normalized by.
    pub active_ms: TimeMs,
}

/// Per-processor results.
#[derive(Debug, Clone)]
pub struct ProcStats {
    pub name: String,
    /// Fraction of wall time with ≥ 1 resident task.
    pub busy_frac: f64,
    /// Time-averaged occupied slots / total slots.
    pub avg_load: f64,
    pub temp: TimeSeries,
    pub freq: TimeSeries,
    pub throttle_events: u64,
    pub first_throttle_ms: Option<TimeMs>,
    pub dispatches: u64,
}

/// Full execution report — produced identically by the discrete-event
/// simulator and the wall-clock thread-pool backend (where thermal/power
/// signals are zero: real hardware counters are a future backend concern).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub scheduler: String,
    /// Which [`ExecutionBackend`](crate::exec::ExecutionBackend) produced
    /// this report (`"sim"` or `"threadpool"`).
    pub backend: String,
    pub duration_ms: TimeMs,
    pub sessions: Vec<SessionStats>,
    pub procs: Vec<ProcStats>,
    /// Total device power over time (W), sampled on the governor tick.
    pub power: TimeSeries,
    pub energy_j: f64,
    pub timeline: Vec<TimelineEvent>,
    pub monitor_refreshes: u64,
    /// Payload execution errors (thread-pool backend).
    pub exec_errors: u64,
    /// Scheduling decisions in dispatch order — the cross-backend
    /// determinism witness.
    pub assignments: Vec<crate::exec::AssignRecord>,
    /// Request arrivals in arrival order; with `assignments` this makes
    /// the run replayable (`scenario::trace::RunTrace`).
    pub arrivals: Vec<crate::exec::ArrivalRecord>,
    /// Backend events processed by the driver loop (timers, completions,
    /// ticks). With wall time this gives the `adms bench` events/sec
    /// figure — the scheduler-loop throughput the perf gate tracks.
    pub events: u64,
}

impl SimReport {
    /// Aggregate frames per second across all sessions (the paper's
    /// Fig 8 headline metric).
    pub fn total_fps(&self) -> f64 {
        self.sessions.iter().map(|s| s.fps).sum()
    }

    /// System frame rate for cascade workloads (FRS/ROS): a video frame
    /// is complete only when *every* model in the scenario has processed
    /// it, so under stage pipelining the sustained frame rate is the
    /// minimum per-session throughput. This is the quantity the paper's
    /// Fig 8 / Table 6 report.
    pub fn pipeline_fps(&self) -> f64 {
        self.sessions
            .iter()
            .map(|s| s.fps)
            .fold(f64::INFINITY, f64::min)
            .min(self.total_fps()) // empty-session guard
    }

    /// Cascade frames per joule (Table 6's metric over pipeline frames).
    pub fn pipeline_frames_per_joule(&self) -> f64 {
        if self.energy_j == 0.0 {
            0.0
        } else {
            self.pipeline_fps() * (self.duration_ms / 1e3) / self.energy_j
        }
    }

    pub fn total_completed(&self) -> u64 {
        self.sessions.iter().map(|s| s.completed).sum()
    }

    pub fn total_failed(&self) -> u64 {
        self.sessions.iter().map(|s| s.failed).sum()
    }

    pub fn total_issued(&self) -> u64 {
        self.sessions.iter().map(|s| s.issued).sum()
    }

    pub fn total_cancelled(&self) -> u64 {
        self.sessions.iter().map(|s| s.cancelled).sum()
    }

    /// True when any session's latency percentiles come from a reservoir
    /// subsample rather than the full population (million-request runs) —
    /// reports should label p50/p95 accordingly.
    pub fn latency_subsampled(&self) -> bool {
        self.sessions.iter().any(|s| s.latency.is_subsampled())
    }

    /// Failure rate over all *retired* requests — completed + failed
    /// (Table 7). Cancellations are workload-initiated (session stop /
    /// run end), not the system's fault, so they sit in neither the
    /// numerator nor the denominator; use `total_issued()` for the full
    /// open-system denominator.
    pub fn failure_rate(&self) -> f64 {
        let total = self.total_completed() + self.total_failed();
        if total == 0 {
            0.0
        } else {
            self.total_failed() as f64 / total as f64
        }
    }

    pub fn avg_power_w(&self) -> f64 {
        self.power.mean()
    }

    /// Frames per joule (Table 6's energy-efficiency metric).
    pub fn frames_per_joule(&self) -> f64 {
        if self.energy_j == 0.0 {
            0.0
        } else {
            self.total_completed() as f64 / self.energy_j
        }
    }

    /// Mean request latency across sessions, weighted by request count.
    pub fn mean_latency_ms(&self) -> f64 {
        let n: u64 = self.sessions.iter().map(|s| s.latency.count()).sum();
        if n == 0 {
            return f64::NAN;
        }
        self.sessions
            .iter()
            .map(|s| s.latency.mean() * s.latency.count() as f64)
            .sum::<f64>()
            / n as f64
    }

    /// Overall hardware utilization: busy-fraction averaged over
    /// processors (the paper's Fig 10 discussion: TFLite ~50 % vs ADMS
    /// ~95 % on the active processors).
    pub fn avg_busy_frac(&self) -> f64 {
        if self.procs.is_empty() {
            return 0.0;
        }
        self.procs.iter().map(|p| p.busy_frac).sum::<f64>() / self.procs.len() as f64
    }

    /// Earliest throttle onset across processors (Table 7's "time to
    /// thermal throttling").
    pub fn first_throttle_ms(&self) -> Option<TimeMs> {
        self.procs
            .iter()
            .filter_map(|p| p.first_throttle_ms)
            .fold(None, |acc, t| Some(acc.map_or(t, |a: f64| a.min(t))))
    }
}
