//! Simulation outputs: everything the paper's tables and figures need.

use crate::util::stats::{Summary, TimeSeries};
use crate::TimeMs;

/// One executed task interval (Fig 10's Gantt rows).
#[derive(Debug, Clone)]
pub struct TimelineEvent {
    pub proc: usize,
    pub session: usize,
    pub req: u64,
    pub unit: usize,
    pub start: TimeMs,
    pub end: TimeMs,
}

/// Per-session (application) results.
#[derive(Debug, Clone)]
pub struct SessionStats {
    pub model: String,
    /// Requests issued by the arrival process (conservation:
    /// `issued == completed + failed + cancelled`, always).
    pub issued: u64,
    pub completed: u64,
    pub failed: u64,
    /// Requests cancelled by the workload itself — a `SessionStop` event
    /// retiring the session, or the run ending with the request open.
    /// Unlike `failed`, these are not the system's fault and do not count
    /// against SLO satisfaction.
    pub cancelled: u64,
    /// Failure-reason split (fault layer): the four partition `failed`
    /// exactly — `failed == failed_budget + failed_exec + faulted +
    /// retries_exhausted` on every run (the chaos conservation property
    /// pins it). Serialized only when the fault layer was active, so
    /// faults-off reports stay byte-identical to pre-fault-layer ones.
    pub failed_budget: u64,
    /// Genuine payload execution errors (never retried).
    pub failed_exec: u64,
    /// Fault/timeout aborts with no retry machinery available
    /// (fault-blind or `retry_limit = 0`).
    pub faulted: u64,
    /// Fault/timeout aborts after the retry budget ran out.
    pub retries_exhausted: u64,
    /// Fault/timeout retries granted — audited separately from `issued`
    /// (a retried unit re-runs the same request).
    pub retries: u64,
    pub latency: Summary,
    /// Completed requests per second of the session's *active* window
    /// (admission to retirement; the full run for static sessions).
    pub fps: f64,
    /// Fraction of requests finishing within their SLO (failures count
    /// as misses). `None` when the session has no SLO.
    pub slo_satisfaction: Option<f64>,
    /// Requests that finished within their SLO (numerator of
    /// `slo_satisfaction`). Raw counts so aggregation layers (the fleet
    /// digest) can merge SLO attainment exactly instead of averaging
    /// per-session ratios.
    pub slo_ok: u64,
    /// SLO-scored retirements (completions + failures of SLO-carrying
    /// requests — the denominator).
    pub slo_n: u64,
    /// When the session was admitted (0 for static workloads).
    pub start_ms: TimeMs,
    /// When a `SessionStop` event retired it (`None` = ran to the end).
    pub stop_ms: Option<TimeMs>,
    /// Active window the rate metrics are normalized by.
    pub active_ms: TimeMs,
}

/// Per-processor results.
#[derive(Debug, Clone)]
pub struct ProcStats {
    pub name: String,
    /// Fraction of wall time with ≥ 1 resident task.
    pub busy_frac: f64,
    /// Time-averaged occupied slots / total slots.
    pub avg_load: f64,
    pub temp: TimeSeries,
    pub freq: TimeSeries,
    pub throttle_events: u64,
    pub first_throttle_ms: Option<TimeMs>,
    pub dispatches: u64,
    /// Dispatches that paid a weight cold-load on this processor
    /// (always 0 on unbudgeted runs).
    pub cold_loads: u64,
}

/// Fault-layer counters (`None` when the fault layer never engaged —
/// which is how faults-off reports serialize byte-identically to
/// pre-fault-layer ones).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// `ProcFail` events applied (crashes + hangs on in-range processors).
    pub proc_fails: u64,
    /// `ProcRecover` events applied.
    pub proc_recovers: u64,
    /// Groups aborted by the dispatch-deadline sweep.
    pub timeouts: u64,
}

/// Adaptive re-partition counters (`None` when the controller never
/// engaged — `--adaptive-plan off` reports serialize byte-identically
/// to pre-adaptive ones, the same idiom as [`FaultStats`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplanStats {
    /// Granularity switches applied (finer + coarser).
    pub replans: u64,
    /// Switches toward a finer partition (more units).
    pub finer: u64,
    /// Switches toward a coarser partition (fewer units).
    pub coarser: u64,
    /// Every switch: `(time_ms, session, new_window_size)` — recorded so
    /// traces can carry the switch schedule for bit-exact replay audits.
    pub events: Vec<(TimeMs, usize, usize)>,
}

/// Full execution report — produced identically by the discrete-event
/// simulator and the wall-clock thread-pool backend (where thermal/power
/// signals are zero: real hardware counters are a future backend concern).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub scheduler: String,
    /// Which [`ExecutionBackend`](crate::exec::ExecutionBackend) produced
    /// this report (`"sim"` or `"threadpool"`).
    pub backend: String,
    pub duration_ms: TimeMs,
    pub sessions: Vec<SessionStats>,
    pub procs: Vec<ProcStats>,
    /// Total device power over time (W), sampled on the governor tick.
    pub power: TimeSeries,
    pub energy_j: f64,
    pub timeline: Vec<TimelineEvent>,
    pub monitor_refreshes: u64,
    /// Payload execution errors (thread-pool backend).
    pub exec_errors: u64,
    /// Fault-layer counters; `Some` exactly when the run had the fault
    /// layer active (fault events in the scenario, a fault profile, or
    /// the dispatch-timeout sweep).
    pub faults: Option<FaultStats>,
    /// Weight-residency counters (`--mem-budget`). All-zero on
    /// unbudgeted runs — the cache is never constructed — so the report
    /// (and its JSON form) is identical to pre-residency builds there.
    pub cache: crate::weights::CacheStats,
    /// Adaptive re-partition counters; `Some` exactly when the
    /// controller was constructed (`--adaptive-plan reactive` with
    /// granularity ladders attached).
    pub replans: Option<ReplanStats>,
    /// Scheduling decisions in dispatch order — the cross-backend
    /// determinism witness.
    pub assignments: Vec<crate::exec::AssignRecord>,
    /// Request arrivals in arrival order; with `assignments` this makes
    /// the run replayable (`scenario::trace::RunTrace`).
    pub arrivals: Vec<crate::exec::ArrivalRecord>,
    /// Backend events processed by the driver loop (timers, completions,
    /// ticks). With wall time this gives the `adms bench` events/sec
    /// figure — the scheduler-loop throughput the perf gate tracks.
    pub events: u64,
}

impl SimReport {
    /// Aggregate frames per second across all sessions (the paper's
    /// Fig 8 headline metric).
    pub fn total_fps(&self) -> f64 {
        self.sessions.iter().map(|s| s.fps).sum()
    }

    /// System frame rate for cascade workloads (FRS/ROS): a video frame
    /// is complete only when *every* model in the scenario has processed
    /// it, so under stage pipelining the sustained frame rate is the
    /// minimum per-session throughput. This is the quantity the paper's
    /// Fig 8 / Table 6 report.
    pub fn pipeline_fps(&self) -> f64 {
        self.sessions
            .iter()
            .map(|s| s.fps)
            .fold(f64::INFINITY, f64::min)
            .min(self.total_fps()) // empty-session guard
    }

    /// Cascade frames per joule (Table 6's metric over pipeline frames).
    pub fn pipeline_frames_per_joule(&self) -> f64 {
        if self.energy_j == 0.0 {
            0.0
        } else {
            self.pipeline_fps() * (self.duration_ms / 1e3) / self.energy_j
        }
    }

    pub fn total_completed(&self) -> u64 {
        self.sessions.iter().map(|s| s.completed).sum()
    }

    pub fn total_failed(&self) -> u64 {
        self.sessions.iter().map(|s| s.failed).sum()
    }

    pub fn total_issued(&self) -> u64 {
        self.sessions.iter().map(|s| s.issued).sum()
    }

    pub fn total_cancelled(&self) -> u64 {
        self.sessions.iter().map(|s| s.cancelled).sum()
    }

    /// True when any session's latency percentiles come from a reservoir
    /// subsample rather than the full population (million-request runs) —
    /// reports should label p50/p95 accordingly.
    pub fn latency_subsampled(&self) -> bool {
        self.sessions.iter().any(|s| s.latency.is_subsampled())
    }

    /// Failure rate over all *retired* requests — completed + failed
    /// (Table 7). Cancellations are workload-initiated (session stop /
    /// run end), not the system's fault, so they sit in neither the
    /// numerator nor the denominator; use `total_issued()` for the full
    /// open-system denominator.
    pub fn failure_rate(&self) -> f64 {
        let total = self.total_completed() + self.total_failed();
        if total == 0 {
            0.0
        } else {
            self.total_failed() as f64 / total as f64
        }
    }

    pub fn avg_power_w(&self) -> f64 {
        self.power.mean()
    }

    /// Frames per joule (Table 6's energy-efficiency metric).
    pub fn frames_per_joule(&self) -> f64 {
        if self.energy_j == 0.0 {
            0.0
        } else {
            self.total_completed() as f64 / self.energy_j
        }
    }

    /// Mean request latency across sessions, weighted by request count.
    pub fn mean_latency_ms(&self) -> f64 {
        let n: u64 = self.sessions.iter().map(|s| s.latency.count()).sum();
        if n == 0 {
            return f64::NAN;
        }
        self.sessions
            .iter()
            .map(|s| s.latency.mean() * s.latency.count() as f64)
            .sum::<f64>()
            / n as f64
    }

    /// Overall hardware utilization: busy-fraction averaged over
    /// processors (the paper's Fig 10 discussion: TFLite ~50 % vs ADMS
    /// ~95 % on the active processors).
    pub fn avg_busy_frac(&self) -> f64 {
        if self.procs.is_empty() {
            return 0.0;
        }
        self.procs.iter().map(|p| p.busy_frac).sum::<f64>() / self.procs.len() as f64
    }

    /// Earliest throttle onset across processors (Table 7's "time to
    /// thermal throttling").
    pub fn first_throttle_ms(&self) -> Option<TimeMs> {
        self.procs
            .iter()
            .filter_map(|p| p.first_throttle_ms)
            .fold(None, |acc, t| Some(acc.map_or(t, |a: f64| a.min(t))))
    }

    /// Serialize every run observable — per-session conservation counters
    /// and latency statistics, per-processor accounting, energy, the full
    /// assignment trace (with group member lists) and arrival trace, the
    /// timeline, and the driver event census. Byte-equality of
    /// `to_json().to_pretty()` between two runs is bit-equality of the
    /// report — this is the witness the `--batch-max 1` golden-
    /// equivalence property compares.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        // The failure-reason split and fault block follow the
        // conditional-emission idiom (`batch_max = 1`, unbudgeted cache):
        // they appear only when the fault layer was active, so a
        // faults-off report is byte-identical to a pre-fault-layer one.
        let fault_layer = self.faults.is_some();
        let sessions: Vec<Json> = self
            .sessions
            .iter()
            .map(|s| {
                let mut fields = vec![
                    ("model", Json::Str(s.model.clone())),
                    ("issued", Json::Num(s.issued as f64)),
                    ("completed", Json::Num(s.completed as f64)),
                    ("failed", Json::Num(s.failed as f64)),
                ];
                if fault_layer {
                    fields.push(("failed_budget", Json::Num(s.failed_budget as f64)));
                    fields.push(("failed_exec", Json::Num(s.failed_exec as f64)));
                    fields.push(("faulted", Json::Num(s.faulted as f64)));
                    fields.push((
                        "retries_exhausted",
                        Json::Num(s.retries_exhausted as f64),
                    ));
                    fields.push(("retries", Json::Num(s.retries as f64)));
                }
                fields.extend(vec![
                    ("cancelled", Json::Num(s.cancelled as f64)),
                    ("lat_count", Json::Num(s.latency.count() as f64)),
                    ("lat_mean", Json::Num(s.latency.mean())),
                    ("lat_p50", Json::Num(s.latency.p50())),
                    ("lat_p95", Json::Num(s.latency.p95())),
                    ("lat_p99", Json::Num(s.latency.p99())),
                    ("lat_max", Json::Num(s.latency.max())),
                    ("lat_subsampled", Json::Bool(s.latency.is_subsampled())),
                    ("fps", Json::Num(s.fps)),
                    (
                        "slo_satisfaction",
                        s.slo_satisfaction.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    ("slo_ok", Json::Num(s.slo_ok as f64)),
                    ("slo_n", Json::Num(s.slo_n as f64)),
                    ("start_ms", Json::Num(s.start_ms)),
                    ("stop_ms", s.stop_ms.map(Json::Num).unwrap_or(Json::Null)),
                    ("active_ms", Json::Num(s.active_ms)),
                ]);
                Json::obj(fields)
            })
            .collect();
        let procs: Vec<Json> = self
            .procs
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("name", Json::Str(p.name.clone())),
                    ("busy_frac", Json::Num(p.busy_frac)),
                    ("avg_load", Json::Num(p.avg_load)),
                    ("dispatches", Json::Num(p.dispatches as f64)),
                    ("cold_loads", Json::Num(p.cold_loads as f64)),
                    ("throttle_events", Json::Num(p.throttle_events as f64)),
                    (
                        "first_throttle_ms",
                        p.first_throttle_ms.map(Json::Num).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        // Assignments in the shared flattened row form (see
        // `AssignRecord::to_row` — single-task records are the classic
        // four-tuple, bit-for-bit).
        let assignments: Vec<Json> = self
            .assignments
            .iter()
            .map(|a| Json::Arr(a.to_row().into_iter().map(Json::Num).collect()))
            .collect();
        let arrivals: Vec<Json> = self
            .arrivals
            .iter()
            .map(|a| Json::Arr(vec![Json::Num(a.session as f64), Json::Num(a.at)]))
            .collect();
        let timeline: Vec<Json> = self
            .timeline
            .iter()
            .map(|e| {
                Json::Arr(vec![
                    Json::Num(e.proc as f64),
                    Json::Num(e.session as f64),
                    Json::Num(e.req as f64),
                    Json::Num(e.unit as f64),
                    Json::Num(e.start),
                    Json::Num(e.end),
                ])
            })
            .collect();
        let mut top = vec![
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("duration_ms", Json::Num(self.duration_ms)),
            ("sessions", Json::Arr(sessions)),
            ("procs", Json::Arr(procs)),
            ("power_samples", Json::Num(self.power.len() as f64)),
            ("power_mean_w", Json::Num(self.power.mean())),
            ("energy_j", Json::Num(self.energy_j)),
            ("monitor_refreshes", Json::Num(self.monitor_refreshes as f64)),
            ("exec_errors", Json::Num(self.exec_errors as f64)),
        ];
        if let Some(f) = &self.faults {
            top.push((
                "faults",
                Json::obj(vec![
                    ("proc_fails", Json::Num(f.proc_fails as f64)),
                    ("proc_recovers", Json::Num(f.proc_recovers as f64)),
                    ("timeouts", Json::Num(f.timeouts as f64)),
                ]),
            ));
        }
        if let Some(r) = &self.replans {
            let events: Vec<Json> = r
                .events
                .iter()
                .map(|&(at, s, ws)| {
                    Json::Arr(vec![
                        Json::Num(at),
                        Json::Num(s as f64),
                        Json::Num(ws as f64),
                    ])
                })
                .collect();
            top.push((
                "replans",
                Json::obj(vec![
                    ("replans", Json::Num(r.replans as f64)),
                    ("finer", Json::Num(r.finer as f64)),
                    ("coarser", Json::Num(r.coarser as f64)),
                    ("events", Json::Arr(events)),
                ]),
            ));
        }
        top.extend(vec![
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::Num(self.cache.hits as f64)),
                    ("misses", Json::Num(self.cache.misses as f64)),
                    ("evictions", Json::Num(self.cache.evictions as f64)),
                    ("bytes_loaded", Json::Num(self.cache.bytes_loaded as f64)),
                    ("bytes_resident", Json::Num(self.cache.bytes_resident as f64)),
                    ("cold_load_ms", Json::Num(self.cache.cold_load_ms)),
                ]),
            ),
            ("events", Json::Num(self.events as f64)),
            ("assignments", Json::Arr(assignments)),
            ("arrivals", Json::Arr(arrivals)),
            ("timeline", Json::Arr(timeline)),
        ]);
        Json::obj(top)
    }
}
