//! Roofline-style latency cost model.
//!
//! Each op is priced as `max(compute time, memory time) + per-op
//! overhead`; a subgraph adds one dispatch (launch) overhead. This is the
//! standard analytical model for fixed-function accelerators and is the
//! level of fidelity the paper's scheduling decisions depend on: relative
//! processor speeds per op type, fallback transfer costs, and contention.

use super::{ProcessorSpec, SocSpec};
use crate::graph::{Graph, Node};
use crate::TimeMs;

/// Latency of one op on one processor at a DVFS scale factor in `(0, 1]`.
/// `None` if the processor does not support the op (fallback required).
pub fn op_latency_ms(g: &Graph, node: &Node, spec: &ProcessorSpec, freq_scale: f64) -> Option<TimeMs> {
    let eff = spec.support.efficiency_for(node.kind, g.dtype_bytes)?;
    if node.kind == crate::graph::OpKind::Input {
        return Some(0.0);
    }
    let compute_ms =
        node.flops as f64 / (spec.peak_gflops * 1e9 * eff * freq_scale) * 1e3;
    let in_bytes: u64 = node
        .inputs
        .iter()
        .map(|&i| g.nodes[i].out_bytes(g.dtype_bytes))
        .sum();
    let bytes = in_bytes + node.out_bytes(g.dtype_bytes) + node.param_bytes;
    // Memory bandwidth is largely frequency-independent (DRAM-bound), but
    // very low DVFS states do limit issue rate; model a soft floor.
    let bw_scale = freq_scale.max(0.6);
    let mem_ms = bytes as f64 / (spec.mem_bw_gbps * 1e9 * bw_scale) * 1e3;
    Some(compute_ms.max(mem_ms) + spec.op_overhead_ms)
}

/// Latency of a set of ops executed as one subgraph on one processor:
/// per-op costs plus a single dispatch overhead. Returns `None` if any op
/// is unsupported.
pub fn subgraph_latency_ms(
    g: &Graph,
    op_ids: &[usize],
    spec: &ProcessorSpec,
    freq_scale: f64,
) -> Option<TimeMs> {
    let mut total = spec.launch_overhead_ms;
    for &id in op_ids {
        total += op_latency_ms(g, &g.nodes[id], spec, freq_scale)?;
    }
    Some(total)
}

/// Marginal-cost fraction of each additional batched request on one
/// processor — the `eff(p)` of the batch-latency curve. Fixed-function
/// tensor engines amortize weight fetch and pipeline fill across a fused
/// batch almost perfectly (an NPU's systolic array is width-bound, not
/// request-bound: a batch of 8 costs ≈ 2× a single), GPUs batch well
/// once occupancy is paid (batch-8 ≈ 2.8×), vector DSPs less so
/// (≈ 4.2×), and CPU kernels are already throughput-bound per request,
/// so an extra batched request costs most of a full one there
/// (batch-8 ≈ 5.9×). Values are in `(0, 1]`: 1.0 would mean batching
/// buys only the amortized dispatch setup.
///
/// Note the interplay with `parallel_slots`/`contention_mult`: on SoCs
/// whose accelerators run concurrent models nearly for free (Dimensity
/// NPU: 4 models at +27 %), slot parallelism already captures most of
/// the fused batch's win, and group dispatch is roughly throughput-
/// neutral; where concurrency collapses the processor (Kirin 970 NPU at
/// 6×, Hexagon DSP at 13× — the paper's Table 2), a fused group
/// occupying ONE slot as ONE resident execution sidesteps the collapse
/// entirely, which is where the `copies` bench shows batching's ≥ 1.5×
/// request-throughput win.
pub fn batch_marginal_frac(spec: &ProcessorSpec) -> f64 {
    match spec.kind {
        super::ProcKind::Npu => 0.15,
        super::ProcKind::Gpu => 0.25,
        super::ProcKind::Dsp => 0.45,
        super::ProcKind::Cpu => 0.70,
    }
}

/// Latency of a fused batch of `b` identical unit subgraphs on one
/// processor: `latency(b) = setup + b_marginal(b) · marginal`, where
/// `setup` is the per-dispatch launch overhead, `marginal` the remaining
/// single-request cost, and each request past the first adds
/// [`batch_marginal_frac`]`(p)` of `marginal`. Calibrated so `b = 1`
/// returns `unit_ms` *bit-exactly* — the current [`subgraph_latency_ms`]
/// pricing — which is what makes `--batch-max 1` a provable no-op.
pub fn batch_latency_ms(spec: &ProcessorSpec, unit_ms: TimeMs, b: usize) -> TimeMs {
    if b <= 1 {
        return unit_ms;
    }
    let setup = spec.launch_overhead_ms.min(unit_ms);
    let marginal = unit_ms - setup;
    setup + marginal * (1.0 + (b - 1) as f64 * batch_marginal_frac(spec))
}

/// Cost of cold-loading `bytes` of model weights from flash storage into a
/// processor's residency domain: one I/O issue overhead plus bytes over
/// the storage sequential-read bandwidth. Calibrated so zero bytes cost
/// *exactly* nothing — shards of pure elementwise/shape ops carry no
/// weights, and pricing them at 0.0 keeps the unbudgeted path bit-exact.
pub fn cold_load_ms(soc: &SocSpec, bytes: u64) -> TimeMs {
    if bytes == 0 {
        return 0.0;
    }
    soc.storage.base_ms + bytes as f64 / (soc.storage.read_gbps * 1e9) * 1e3
}

/// Cost of moving `bytes` between two processors (via shared DRAM). Zero
/// when source and destination are the same processor.
pub fn transfer_ms(soc: &SocSpec, from: usize, to: usize, bytes: u64) -> TimeMs {
    if from == to {
        return 0.0;
    }
    soc.transfer.base_ms + bytes as f64 / (soc.transfer.dram_gbps * 1e9) * 1e3
}

/// Boundary bytes crossing into a subgraph: outputs of ops outside the
/// set consumed by ops inside it (the tensors that must be transferred
/// when the producer ran on a different processor).
pub fn boundary_in_bytes(g: &Graph, op_ids: &[usize]) -> u64 {
    let inside: std::collections::HashSet<usize> = op_ids.iter().copied().collect();
    let mut seen = std::collections::HashSet::new();
    let mut bytes = 0;
    for &id in op_ids {
        for &inp in &g.nodes[id].inputs {
            if !inside.contains(&inp) && seen.insert(inp) {
                bytes += g.nodes[inp].out_bytes(g.dtype_bytes);
            }
        }
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::soc::presets::dimensity9000;

    fn toy() -> Graph {
        let mut b = GraphBuilder::new("toy", 4);
        let x = b.input([1, 56, 56, 64]);
        let c = b.conv2d(x, 64, 3, 1);
        let q = b.quantize(c);
        b.relu(q);
        b.finish()
    }

    #[test]
    fn unsupported_op_returns_none() {
        let g = toy();
        let soc = dimensity9000();
        let npu = &soc.processors[soc.proc_by_kind(crate::soc::ProcKind::Npu).unwrap()];
        // Quantize is not in the NPU support set.
        assert!(op_latency_ms(&g, &g.nodes[2], npu, 1.0).is_none());
        assert!(op_latency_ms(&g, &g.nodes[1], npu, 1.0).is_some());
        assert!(subgraph_latency_ms(&g, &[1, 2, 3], npu, 1.0).is_none());
        assert!(subgraph_latency_ms(&g, &[1, 3], npu, 1.0).is_some());
    }

    #[test]
    fn lower_frequency_is_slower() {
        let g = toy();
        let soc = dimensity9000();
        let cpu = &soc.processors[soc.cpu_id()];
        let fast = subgraph_latency_ms(&g, &[1, 2, 3], cpu, 1.0).unwrap();
        let slow = subgraph_latency_ms(&g, &[1, 2, 3], cpu, 0.33).unwrap();
        assert!(slow > fast * 1.5, "fast={fast} slow={slow}");
    }

    #[test]
    fn transfer_scales_with_bytes_and_is_zero_on_same_proc() {
        let soc = dimensity9000();
        assert_eq!(transfer_ms(&soc, 1, 1, 1 << 20), 0.0);
        let small = transfer_ms(&soc, 0, 1, 1 << 10);
        let large = transfer_ms(&soc, 0, 1, 64 << 20);
        assert!(large > small);
        assert!(small >= soc.transfer.base_ms);
    }

    #[test]
    fn batch_curve_is_identity_at_one_and_sublinear_beyond() {
        let soc = dimensity9000();
        for spec in &soc.processors {
            let unit = 4.0_f64;
            // b = 1 must be bit-exact with the unbatched price.
            assert_eq!(batch_latency_ms(spec, unit, 1), unit);
            assert_eq!(batch_latency_ms(spec, unit, 0), unit);
            let b4 = batch_latency_ms(spec, unit, 4);
            let b8 = batch_latency_ms(spec, unit, 8);
            // Strictly more work than one request, strictly less than
            // running the batch serially, and monotone in b.
            assert!(b4 > unit, "{}: batch of 4 not slower than 1", spec.name);
            assert!(b4 < 4.0 * unit, "{}: batching bought nothing", spec.name);
            assert!(b8 > b4, "{}: batch curve not monotone", spec.name);
            // Per-request latency improves with batching.
            assert!(b8 / 8.0 < b4 / 4.0, "{}: no per-request amortization", spec.name);
        }
        // The NPU amortizes better than the CPU (calibration ordering).
        let npu = &soc.processors[soc.proc_by_kind(crate::soc::ProcKind::Npu).unwrap()];
        let cpu = &soc.processors[soc.cpu_id()];
        assert!(batch_marginal_frac(npu) < batch_marginal_frac(cpu));
    }

    #[test]
    fn cold_load_is_free_at_zero_bytes_and_scales_linearly() {
        let soc = dimensity9000();
        assert_eq!(cold_load_ms(&soc, 0), 0.0);
        let small = cold_load_ms(&soc, 1 << 20);
        let large = cold_load_ms(&soc, 64 << 20);
        assert!(small >= soc.storage.base_ms);
        assert!(large > small);
        // Past the fixed issue cost, 64× the bytes ≈ 64× the stream time.
        let stream = |ms: f64| ms - soc.storage.base_ms;
        assert!((stream(large) / stream(small) - 64.0).abs() < 1e-6);
    }

    #[test]
    fn boundary_bytes_counts_external_inputs_once() {
        let g = toy();
        // Subgraph {quantize, relu}: boundary input is the conv output.
        let b = boundary_in_bytes(&g, &[2, 3]);
        assert_eq!(b, g.nodes[1].out_bytes(4));
        // Whole graph: boundary is empty (input op produces internally).
        assert_eq!(boundary_in_bytes(&g, &[0, 1, 2, 3]), 0);
    }
}
