//! Heterogeneous mobile SoC model.
//!
//! The paper's testbed is three Android phones; this module is the
//! calibrated analytical substitute (DESIGN.md §1). Each SoC exposes a
//! set of [`ProcessorSpec`]s — CPU cluster, GPU, DSP, NPU — with:
//!
//! * a per-[`OpKind`](crate::graph::OpKind) support/efficiency table
//!   (paper Fig 2: op support varies sharply across accelerators);
//! * a roofline-style latency cost model ([`cost`]), calibrated so
//!   MobileNetV1 single-model latencies reproduce Table 2's first column;
//! * a concurrency-contention curve calibrated to Table 2's 2- and
//!   4-model columns (the Hexagon DSP's 13× collapse vs the MediaTek
//!   NPU's 1.27×);
//! * DVFS ladders and lumped-RC thermal parameters driving the
//!   throttling dynamics of Fig 12 (68 °C throttle threshold);
//! * a power model (idle + dynamic) for the Table 6 / Fig 11 energy
//!   reproductions.

pub mod support;
pub mod cost;
pub mod presets;

pub use cost::{cold_load_ms, op_latency_ms, subgraph_latency_ms, transfer_ms};
pub use presets::{dimensity9000, kirin970, snapdragon835, soc_by_name, SOC_NAMES};
pub use support::SupportTable;

/// Processor class. One SoC may carry several processors of different
/// kinds; scheduling treats each as an independent execution resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ProcKind {
    Cpu,
    Gpu,
    Dsp,
    Npu,
}

impl ProcKind {
    pub fn label(self) -> &'static str {
        match self {
            ProcKind::Cpu => "CPU",
            ProcKind::Gpu => "GPU",
            ProcKind::Dsp => "DSP",
            ProcKind::Npu => "NPU",
        }
    }
    pub const ALL: [ProcKind; 4] = [ProcKind::Cpu, ProcKind::Gpu, ProcKind::Dsp, ProcKind::Npu];
}

/// Index of a processor within its [`SocSpec`].
pub type ProcId = usize;

/// Static description of one processor.
#[derive(Debug, Clone)]
pub struct ProcessorSpec {
    pub name: String,
    pub kind: ProcKind,
    /// Peak compute at the highest DVFS state, in GFLOPS (fp32-equivalent;
    /// quantized throughput is folded into per-op efficiency).
    pub peak_gflops: f64,
    /// Sustained memory bandwidth available to this processor, GB/s.
    pub mem_bw_gbps: f64,
    /// Fixed cost to dispatch one subgraph (driver/delegate invoke).
    pub launch_overhead_ms: f64,
    /// Per-op scheduling overhead inside a subgraph, in ms.
    pub op_overhead_ms: f64,
    /// DVFS frequency ladder in MHz, descending (index 0 = fastest).
    pub freqs_mhz: Vec<f64>,
    /// Concurrent execution contexts. Mobile accelerators timeslice or
    /// truly parallelize several resident models (paper Table 2: the
    /// MediaTek NPU runs 4 concurrent MobileNets with only 27 % latency
    /// inflation — impossible under serial queueing).
    pub parallel_slots: usize,
    /// Which ops run here and at what fraction of peak.
    pub support: SupportTable,
    /// Concurrency contention: executing while `n` sessions share this
    /// processor multiplies service time by `1 + c·(n−1)^p`
    /// (calibrated per processor from Table 2).
    pub contention_c: f64,
    pub contention_p: f64,
    /// Lumped thermal resistance junction→ambient, K/W.
    pub thermal_r: f64,
    /// Lumped thermal capacitance, J/K.
    pub thermal_c: f64,
    /// Power draw at full utilization and max frequency, W.
    pub tdp_w: f64,
    /// Idle power, W.
    pub idle_w: f64,
    /// Governor begins stepping frequency down above this temperature.
    pub throttle_temp_c: f64,
    /// Hard cutoff: the processor is taken offline above this (GPUs on the
    /// paper's testbed shut down entirely — Fig 12).
    pub critical_temp_c: f64,
    /// Weight-residency domain capacity, bytes: how much delegate-prepared
    /// model weight data can stay resident for this processor (NNAPI/TFLite
    /// delegates keep a per-accelerator compiled copy). The weight cache
    /// ([`crate::weights`]) evicts against this when a run sets a memory
    /// budget; unbudgeted runs never consult it.
    pub weight_mem_bytes: u64,
}

impl ProcessorSpec {
    pub fn max_freq(&self) -> f64 {
        self.freqs_mhz[0]
    }
    pub fn min_freq(&self) -> f64 {
        *self.freqs_mhz.last().unwrap()
    }
    /// Frequency scale factor for a DVFS level.
    pub fn freq_scale(&self, level: usize) -> f64 {
        self.freqs_mhz[level.min(self.freqs_mhz.len() - 1)] / self.max_freq()
    }
    /// Contention multiplier for `n` concurrently-resident sessions.
    pub fn contention_mult(&self, n_sessions: usize) -> f64 {
        if n_sessions <= 1 {
            1.0
        } else {
            1.0 + self.contention_c * ((n_sessions - 1) as f64).powf(self.contention_p)
        }
    }
}

/// Inter-processor tensor transfer model: all processors share DRAM; a
/// handoff costs a fixed driver round-trip plus bytes over the memory bus.
#[derive(Debug, Clone)]
pub struct TransferModel {
    pub base_ms: f64,
    pub dram_gbps: f64,
}

/// Flash-storage read path: cold-loading model weights costs a fixed I/O
/// issue overhead plus bytes over the UFS/eMMC sequential-read bandwidth.
/// This is the storage-bandwidth term behind [`cost::cold_load_ms`].
#[derive(Debug, Clone)]
pub struct StorageModel {
    pub base_ms: f64,
    pub read_gbps: f64,
}

/// One system-on-chip: a named set of processors plus shared-memory
/// transfer characteristics and an ambient operating temperature.
#[derive(Debug, Clone)]
pub struct SocSpec {
    pub name: String,
    pub device: String,
    pub processors: Vec<ProcessorSpec>,
    pub transfer: TransferModel,
    pub storage: StorageModel,
    pub ambient_c: f64,
}

impl SocSpec {
    pub fn num_processors(&self) -> usize {
        self.processors.len()
    }

    pub fn proc_by_kind(&self, kind: ProcKind) -> Option<ProcId> {
        self.processors.iter().position(|p| p.kind == kind)
    }

    pub fn cpu_id(&self) -> ProcId {
        self.proc_by_kind(ProcKind::Cpu)
            .expect("every SoC has a CPU")
    }

    /// Structural fingerprint: FNV-1a over every cost-model-relevant
    /// property — processor kinds, compute/bandwidth/overhead numbers,
    /// DVFS ladders, slot counts, support/efficiency tables, contention
    /// and thermal/power parameters, the transfer model, and the ambient
    /// temperature. The plan and tuner memo tables key on this alongside
    /// `name`, mirroring [`crate::graph::Graph::fingerprint`] on the
    /// model side: two custom SoC definitions that share a name but
    /// differ structurally can never be served each other's cached
    /// partitioning. Display names (`name`, `device`, processor names)
    /// are deliberately excluded — they don't affect plans or costs.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn mix(h: &mut u64, x: u64) {
            for b in x.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(PRIME);
            }
        }
        fn mixf(h: &mut u64, x: f64) {
            mix(h, x.to_bits());
        }
        let mut h = OFFSET;
        mixf(&mut h, self.ambient_c);
        mixf(&mut h, self.transfer.base_ms);
        mixf(&mut h, self.transfer.dram_gbps);
        mixf(&mut h, self.storage.base_ms);
        mixf(&mut h, self.storage.read_gbps);
        mix(&mut h, self.processors.len() as u64);
        for p in &self.processors {
            mix(&mut h, p.kind as u64);
            mixf(&mut h, p.peak_gflops);
            mixf(&mut h, p.mem_bw_gbps);
            mixf(&mut h, p.launch_overhead_ms);
            mixf(&mut h, p.op_overhead_ms);
            mix(&mut h, p.freqs_mhz.len() as u64);
            for &f in &p.freqs_mhz {
                mixf(&mut h, f);
            }
            mix(&mut h, p.parallel_slots as u64);
            mixf(&mut h, p.support.fp32_factor);
            for (k, e) in p.support.entries() {
                mix(&mut h, k as u64);
                mixf(&mut h, e);
            }
            mixf(&mut h, p.contention_c);
            mixf(&mut h, p.contention_p);
            mixf(&mut h, p.thermal_r);
            mixf(&mut h, p.thermal_c);
            mixf(&mut h, p.tdp_w);
            mixf(&mut h, p.idle_w);
            mixf(&mut h, p.throttle_temp_c);
            mixf(&mut h, p.critical_temp_c);
            mix(&mut h, p.weight_mem_bytes);
        }
        h
    }

    /// The accelerator a vanilla TFLite delegate would pick: the non-CPU
    /// processor with the highest peak compute.
    pub fn best_accelerator(&self) -> Option<ProcId> {
        self.processors
            .iter()
            .enumerate()
            .filter(|(_, p)| p.kind != ProcKind::Cpu)
            .max_by(|a, b| a.1.peak_gflops.partial_cmp(&b.1.peak_gflops).unwrap())
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_cpu_and_accelerators() {
        for name in SOC_NAMES {
            let soc = soc_by_name(name).unwrap();
            assert!(soc.num_processors() >= 3, "{name}");
            let cpu = &soc.processors[soc.cpu_id()];
            assert_eq!(cpu.kind, ProcKind::Cpu);
            assert!(soc.best_accelerator().is_some());
            assert!(soc.storage.read_gbps > 0.0);
            for p in &soc.processors {
                assert!(p.peak_gflops > 0.0);
                assert!(p.weight_mem_bytes > 0);
                assert!(!p.freqs_mhz.is_empty());
                assert!(p.tdp_w > p.idle_w);
                assert!(p.critical_temp_c > p.throttle_temp_c);
                // Ladder must be descending.
                for w in p.freqs_mhz.windows(2) {
                    assert!(w[0] > w[1], "{}: ladder not descending", p.name);
                }
            }
        }
    }

    #[test]
    fn contention_mult_matches_table2_calibration() {
        // Hexagon 682 DSP: 46.77 → 277.14 (×5.93) → 609.44 (×13.03).
        let soc = snapdragon835();
        let dsp = &soc.processors[soc.proc_by_kind(ProcKind::Dsp).unwrap()];
        assert!((dsp.contention_mult(2) - 5.93).abs() < 0.4);
        assert!((dsp.contention_mult(4) - 13.0).abs() < 1.0);
        // MediaTek NPU: 1.88 → 2.13 (×1.13) → 2.39 (×1.27).
        let soc = dimensity9000();
        let npu = &soc.processors[soc.proc_by_kind(ProcKind::Npu).unwrap()];
        assert!((npu.contention_mult(2) - 1.13).abs() < 0.06);
        assert!((npu.contention_mult(4) - 1.27).abs() < 0.08);
    }

    #[test]
    fn freq_scale_is_monotone() {
        let soc = dimensity9000();
        let cpu = &soc.processors[soc.cpu_id()];
        assert_eq!(cpu.freq_scale(0), 1.0);
        let mut last = 1.0;
        for l in 1..cpu.freqs_mhz.len() {
            let s = cpu.freq_scale(l);
            assert!(s < last);
            last = s;
        }
        // Out-of-range levels clamp to the slowest state.
        assert_eq!(cpu.freq_scale(99), cpu.min_freq() / cpu.max_freq());
    }

    #[test]
    fn fingerprint_tracks_structure_not_names() {
        let a = dimensity9000();
        // Renaming the SoC, device, or a processor changes nothing
        // structural.
        let mut renamed = a.clone();
        renamed.name = "custom_soc".into();
        renamed.device = "Bench Phone".into();
        renamed.processors[0].name = "renamed-cluster".into();
        assert_eq!(a.fingerprint(), renamed.fingerprint());
        // Any cost-relevant edit changes it: peak compute, support
        // tables, DVFS ladder, thermal parameters, transfer model.
        let mut peak = a.clone();
        peak.processors[1].peak_gflops *= 1.5;
        assert_ne!(a.fingerprint(), peak.fingerprint());
        let mut support = a.clone();
        support.processors[1].support =
            support.processors[1].support.clone().without(&[crate::graph::OpKind::Add]);
        assert_ne!(a.fingerprint(), support.fingerprint());
        let mut ladder = a.clone();
        ladder.processors[0].freqs_mhz.pop();
        assert_ne!(a.fingerprint(), ladder.fingerprint());
        let mut thermal = a.clone();
        thermal.processors[2].throttle_temp_c += 1.0;
        assert_ne!(a.fingerprint(), thermal.fingerprint());
        let mut xfer = a.clone();
        xfer.transfer.dram_gbps *= 2.0;
        assert_ne!(a.fingerprint(), xfer.fingerprint());
        let mut storage = a.clone();
        storage.storage.read_gbps *= 2.0;
        assert_ne!(a.fingerprint(), storage.fingerprint());
        let mut mem = a.clone();
        mem.processors[1].weight_mem_bytes /= 2;
        assert_ne!(a.fingerprint(), mem.fingerprint());
        // Presets are mutually distinct.
        assert_ne!(dimensity9000().fingerprint(), kirin970().fingerprint());
        assert_ne!(dimensity9000().fingerprint(), snapdragon835().fingerprint());
    }

    #[test]
    fn contention_is_identity_for_single_session() {
        for name in SOC_NAMES {
            for p in &soc_by_name(name).unwrap().processors {
                assert_eq!(p.contention_mult(1), 1.0);
                assert!(p.contention_mult(2) >= 1.0);
            }
        }
    }
}
