//! SoC presets calibrated to the paper's testbed (Table 4 specs; latency
//! and contention calibrated to Table 2; thermal behaviour to Fig 12).

use super::support::{cpu_support, dsp_support, gpu_support, npu_support};
use super::{ProcKind, ProcessorSpec, SocSpec, StorageModel, TransferModel};

const MIB: u64 = 1 << 20;

pub const SOC_NAMES: [&str; 3] = ["dimensity9000", "kirin970", "snapdragon835"];

pub fn soc_by_name(name: &str) -> Option<SocSpec> {
    Some(match name {
        "dimensity9000" => dimensity9000(),
        "kirin970" => kirin970(),
        "snapdragon835" => snapdragon835(),
        _ => return None,
    })
}

/// MediaTek Dimensity 9000 (Redmi K50 Pro). Table 4: 1×X2 + 3×A710 +
/// 4×A510, Mali-G710 MP10 @ 850 MHz (1632 GFLOPS), MediaTek APU 590,
/// LPDDR5X 60 Gbit/s ⇒ ~60 GB/s effective DRAM bandwidth, 4 W TDP.
pub fn dimensity9000() -> SocSpec {
    SocSpec {
        name: "dimensity9000".into(),
        device: "Redmi K50 Pro".into(),
        ambient_c: 25.0,
        transfer: TransferModel { base_ms: 0.15, dram_gbps: 60.0 },
        // UFS 3.1 sequential read (~2 GB/s) behind the cold-load path.
        storage: StorageModel { base_ms: 0.25, read_gbps: 2.0 },
        processors: vec![
            ProcessorSpec {
                name: "Cortex-X2/A710/A510".into(),
                kind: ProcKind::Cpu,
                peak_gflops: 140.0,
                mem_bw_gbps: 20.0,
                launch_overhead_ms: 0.05,
                op_overhead_ms: 0.012,
                freqs_mhz: vec![3050.0, 2850.0, 2600.0, 2200.0, 1800.0, 1400.0, 1000.0],
                parallel_slots: 4,
                support: cpu_support(0.20),
                contention_c: 0.5,
                contention_p: 0.8,
                thermal_r: 15.0,
                thermal_c: 8.0,
                tdp_w: 4.0,
                idle_w: 0.5,
                throttle_temp_c: 68.0,
                critical_temp_c: 85.0,
                weight_mem_bytes: 1024 * MIB,
            },
            ProcessorSpec {
                name: "Mali-G710 MP10".into(),
                kind: ProcKind::Gpu,
                peak_gflops: 1632.0, // Table 4
                mem_bw_gbps: 40.0,
                launch_overhead_ms: 0.20,
                op_overhead_ms: 0.010,
                freqs_mhz: vec![850.0, 750.0, 650.0, 550.0, 450.0],
                parallel_slots: 4,
                support: gpu_support(0.21, true),
                contention_c: 1.16, // Table 2: 3.65 → 7.88 → 9.09 ms
                contention_p: 0.228,
                thermal_r: 12.0,
                thermal_c: 6.0,
                tdp_w: 3.0,
                idle_w: 0.3,
                throttle_temp_c: 68.0,
                critical_temp_c: 75.0,
                weight_mem_bytes: 512 * MIB,
            },
            ProcessorSpec {
                name: "MediaTek APU 5.0".into(),
                kind: ProcKind::Dsp,
                peak_gflops: 450.0,
                mem_bw_gbps: 30.0,
                launch_overhead_ms: 0.15,
                op_overhead_ms: 0.010,
                freqs_mhz: vec![1000.0, 800.0, 600.0],
                parallel_slots: 4,
                support: dsp_support(0.35),
                contention_c: 0.30, // Table 2: 8.24 → 10.71 → 16.97 ms
                contention_p: 1.148,
                thermal_r: 18.0,
                thermal_c: 4.0,
                tdp_w: 2.0,
                idle_w: 0.2,
                throttle_temp_c: 70.0,
                critical_temp_c: 90.0,
                weight_mem_bytes: 256 * MIB,
            },
            ProcessorSpec {
                name: "MediaTek NPU".into(),
                kind: ProcKind::Npu,
                peak_gflops: 1600.0,
                mem_bw_gbps: 45.0,
                launch_overhead_ms: 0.10,
                op_overhead_ms: 0.008,
                freqs_mhz: vec![900.0, 750.0, 600.0],
                parallel_slots: 4,
                support: npu_support(0.50, true),
                contention_c: 0.13, // Table 2: 1.88 → 2.13 → 2.39 ms
                contention_p: 0.645,
                thermal_r: 18.0,
                thermal_c: 4.0,
                tdp_w: 1.8,
                idle_w: 0.15,
                throttle_temp_c: 70.0,
                critical_temp_c: 90.0,
                weight_mem_bytes: 256 * MIB,
            },
        ],
    }
}

/// HiSilicon Kirin 970 (Huawei P20). Table 4: 4×A73 + 4×A53, Mali-G72
/// MP12 @ 768 MHz (331.8 GFLOPS), first-generation dual-core NPU,
/// LPDDR4X ~29.8 GB/s, 9 W TDP, 10 nm. Old delegates: many fallback ops
/// (the paper's Fig 3 shows multi-processor *slower* than CPU here).
pub fn kirin970() -> SocSpec {
    SocSpec {
        name: "kirin970".into(),
        device: "Huawei P20".into(),
        ambient_c: 25.0,
        transfer: TransferModel { base_ms: 0.30, dram_gbps: 29.8 },
        // UFS 2.1-era flash: ~0.85 GB/s sequential read.
        storage: StorageModel { base_ms: 0.40, read_gbps: 0.85 },
        processors: vec![
            ProcessorSpec {
                name: "Cortex-A73/A53".into(),
                kind: ProcKind::Cpu,
                peak_gflops: 70.0,
                mem_bw_gbps: 12.0,
                launch_overhead_ms: 0.05,
                op_overhead_ms: 0.020,
                freqs_mhz: vec![2360.0, 2100.0, 1800.0, 1500.0, 1200.0, 900.0],
                parallel_slots: 4,
                support: cpu_support(0.15),
                contention_c: 0.6,
                contention_p: 0.8,
                thermal_r: 8.0,
                thermal_c: 10.0,
                tdp_w: 5.0,
                idle_w: 0.6,
                throttle_temp_c: 68.0,
                critical_temp_c: 85.0,
                weight_mem_bytes: 768 * MIB,
            },
            ProcessorSpec {
                name: "Mali-G72 MP12".into(),
                kind: ProcKind::Gpu,
                peak_gflops: 331.8, // Table 4
                mem_bw_gbps: 18.0,
                launch_overhead_ms: 0.50,
                op_overhead_ms: 0.025,
                freqs_mhz: vec![768.0, 650.0, 550.0, 450.0],
                parallel_slots: 4,
                support: gpu_support(0.09, false),
                contention_c: 0.69, // Table 2: 45.35 → 76.77 → 114.88 ms
                contention_p: 0.726,
                thermal_r: 10.0,
                thermal_c: 7.0,
                tdp_w: 4.0,
                idle_w: 0.5,
                throttle_temp_c: 68.0,
                critical_temp_c: 75.0,
                weight_mem_bytes: 384 * MIB,
            },
            ProcessorSpec {
                name: "HiSilicon DSP".into(),
                kind: ProcKind::Dsp,
                peak_gflops: 80.0,
                mem_bw_gbps: 10.0,
                launch_overhead_ms: 0.40,
                op_overhead_ms: 0.020,
                freqs_mhz: vec![800.0, 600.0],
                parallel_slots: 4,
                support: dsp_support(0.25),
                contention_c: 1.5,
                contention_p: 0.9,
                thermal_r: 14.0,
                thermal_c: 5.0,
                tdp_w: 1.5,
                idle_w: 0.2,
                throttle_temp_c: 70.0,
                critical_temp_c: 90.0,
                weight_mem_bytes: 192 * MIB,
            },
            ProcessorSpec {
                name: "Dual-core NPU".into(),
                kind: ProcKind::Npu,
                peak_gflops: 400.0,
                mem_bw_gbps: 12.0,
                launch_overhead_ms: 0.60, // first-gen NNAPI driver
                op_overhead_ms: 0.030,
                freqs_mhz: vec![960.0, 720.0],
                parallel_slots: 4,
                support: npu_support(0.043, false),
                contention_c: 2.14, // Table 2: 70.15 → 220.07 → 429.1 ms
                contention_p: 0.793,
                thermal_r: 14.0,
                thermal_c: 5.0,
                tdp_w: 2.0,
                idle_w: 0.25,
                throttle_temp_c: 70.0,
                critical_temp_c: 90.0,
                weight_mem_bytes: 192 * MIB,
            },
        ],
    }
}

/// Qualcomm Snapdragon 835 (Xiaomi 6): 4×Kryo 280 Gold + 4×Silver,
/// Adreno 540, Hexagon 682 DSP. No NPU. The DSP exhibits the paper's
/// most dramatic contention collapse (Table 2: 13× at 4 models).
pub fn snapdragon835() -> SocSpec {
    SocSpec {
        name: "snapdragon835".into(),
        device: "Xiaomi 6".into(),
        ambient_c: 25.0,
        transfer: TransferModel { base_ms: 0.25, dram_gbps: 28.0 },
        // UFS 2.1 flash: ~0.75 GB/s sequential read.
        storage: StorageModel { base_ms: 0.40, read_gbps: 0.75 },
        processors: vec![
            ProcessorSpec {
                name: "Kryo 280".into(),
                kind: ProcKind::Cpu,
                peak_gflops: 60.0,
                mem_bw_gbps: 12.0,
                launch_overhead_ms: 0.05,
                op_overhead_ms: 0.018,
                freqs_mhz: vec![2450.0, 2200.0, 1900.0, 1600.0, 1200.0, 900.0],
                parallel_slots: 4,
                support: cpu_support(0.16),
                contention_c: 0.6,
                contention_p: 0.8,
                thermal_r: 9.0,
                thermal_c: 9.0,
                tdp_w: 4.5,
                idle_w: 0.5,
                throttle_temp_c: 68.0,
                critical_temp_c: 85.0,
                weight_mem_bytes: 768 * MIB,
            },
            ProcessorSpec {
                name: "Adreno 540".into(),
                kind: ProcKind::Gpu,
                peak_gflops: 567.0,
                mem_bw_gbps: 22.0,
                launch_overhead_ms: 0.25,
                op_overhead_ms: 0.012,
                freqs_mhz: vec![710.0, 600.0, 500.0, 400.0],
                parallel_slots: 4,
                support: gpu_support(0.30, false),
                contention_c: 0.009, // Table 2: 7.89 → 7.96 → 8.1 ms
                contention_p: 1.0,
                thermal_r: 11.0,
                thermal_c: 6.5,
                tdp_w: 3.5,
                idle_w: 0.4,
                throttle_temp_c: 68.0,
                critical_temp_c: 75.0,
                weight_mem_bytes: 384 * MIB,
            },
            ProcessorSpec {
                name: "Hexagon 682".into(),
                kind: ProcKind::Dsp,
                peak_gflops: 90.0,
                mem_bw_gbps: 9.0,
                launch_overhead_ms: 0.35,
                op_overhead_ms: 0.020,
                freqs_mhz: vec![800.0, 600.0],
                parallel_slots: 4,
                support: dsp_support(0.30),
                contention_c: 4.93, // Table 2: 46.77 → 277.14 → 609.44 ms
                contention_p: 0.81,
                thermal_r: 13.0,
                thermal_c: 5.0,
                tdp_w: 1.8,
                idle_w: 0.2,
                throttle_temp_c: 70.0,
                critical_temp_c: 90.0,
                weight_mem_bytes: 192 * MIB,
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::cost::subgraph_latency_ms;
    use crate::zoo::mobilenet_v1_quant;

    /// Full-model single-processor latency for MobileNetV1, as the vanilla
    /// delegate measures it (one subgraph containing all supported ops —
    /// here we price the whole graph, which only the CPU fully supports;
    /// accelerators are priced over their supported subset, matching the
    /// paper's delegate-resident measurement).
    fn model_latency(soc: &SocSpec, kind: ProcKind) -> f64 {
        let g = mobilenet_v1_quant();
        let p = &soc.processors[soc.proc_by_kind(kind).unwrap()];
        let ids: Vec<usize> = g
            .nodes
            .iter()
            .filter(|n| p.support.supports(n.kind))
            .map(|n| n.id)
            .collect();
        subgraph_latency_ms(&g, &ids, p, 1.0).unwrap()
    }

    /// Paper Table 2 column 1 (single-model MobileNetV1 latency): the cost
    /// model must land within ±35 % of each measured value.
    #[test]
    fn mobilenet_latency_calibration_matches_table2() {
        let cases: [(&str, ProcKind, f64); 7] = [
            ("dimensity9000", ProcKind::Gpu, 3.65),
            ("dimensity9000", ProcKind::Dsp, 8.24),
            ("dimensity9000", ProcKind::Npu, 1.88),
            ("kirin970", ProcKind::Gpu, 45.35),
            ("kirin970", ProcKind::Npu, 70.15),
            ("snapdragon835", ProcKind::Gpu, 7.89),
            ("snapdragon835", ProcKind::Dsp, 46.77),
        ];
        for (soc_name, kind, paper_ms) in cases {
            let soc = soc_by_name(soc_name).unwrap();
            let ours = model_latency(&soc, kind);
            let ratio = ours / paper_ms;
            assert!(
                (0.65..1.35).contains(&ratio),
                "{soc_name}/{}: ours {ours:.2} ms vs paper {paper_ms} ms (ratio {ratio:.2})",
                kind.label()
            );
        }
    }

    /// Fig 3: on Dimensity 9000 the NPU runs MobileNet far faster than the
    /// CPU (up to ~23×); on Kirin 970 accelerators barely beat the CPU.
    #[test]
    fn accelerator_speedups_match_fig3_shape() {
        let dim = dimensity9000();
        let cpu = model_latency(&dim, ProcKind::Cpu);
        let npu = model_latency(&dim, ProcKind::Npu);
        let speedup = cpu / npu;
        assert!(speedup > 10.0, "Dim9000 NPU speedup only {speedup:.1}×");

        let kir = kirin970();
        let cpu = model_latency(&kir, ProcKind::Cpu);
        let npu = model_latency(&kir, ProcKind::Npu);
        let ratio = cpu / npu;
        assert!((0.8..2.5).contains(&ratio), "Kirin NPU/CPU ratio {ratio:.2}");
    }

    #[test]
    fn vanilla_delegate_picks_fastest_accelerator() {
        let soc = dimensity9000();
        let best = soc.best_accelerator().unwrap();
        // Mali-G710 (1632 GFLOPS) edges out the NPU (1600) on paper peak.
        assert_eq!(soc.processors[best].kind, ProcKind::Gpu);
    }
}
