//! Per-processor op support and efficiency tables (paper Fig 2).
//!
//! Accelerator cores are fixed-function designs optimized for a limited
//! op set (paper §2.1: Edge TPU systolic arrays, Da Vinci 3D cubes);
//! unsupported ops must fall back to the CPU. Each entry here is either
//! unsupported or an efficiency in `(0, 1]` — the fraction of the
//! processor's peak achieved on that op type.

use crate::graph::OpKind;
use std::collections::BTreeMap;

/// Op → efficiency map for one processor. Missing = unsupported.
#[derive(Debug, Clone)]
pub struct SupportTable {
    eff: BTreeMap<OpKind, f64>,
    /// Efficiency multiplier for float32 graphs. Fixed-function NPUs and
    /// integer DSPs hit their quoted throughput only on quantized models;
    /// NNAPI runs fp32 graphs through a relaxed-fp16 path at a fraction
    /// of it. 1.0 for CPU/GPU (fp32-native).
    pub fp32_factor: f64,
}

impl Default for SupportTable {
    fn default() -> Self {
        SupportTable { eff: BTreeMap::new(), fp32_factor: 1.0 }
    }
}

impl SupportTable {
    pub fn new(entries: &[(OpKind, f64)]) -> Self {
        let mut eff = BTreeMap::new();
        for &(k, e) in entries {
            assert!(e > 0.0 && e <= 1.0, "{:?}: efficiency {} out of (0,1]", k, e);
            eff.insert(k, e);
        }
        // Input pseudo-ops are free everywhere.
        eff.insert(OpKind::Input, 1.0);
        SupportTable { eff, fp32_factor: 1.0 }
    }

    /// Builder: set the fp32 down-rating (see `fp32_factor`).
    pub fn with_fp32_factor(mut self, f: f64) -> Self {
        assert!(f > 0.0 && f <= 1.0);
        self.fp32_factor = f;
        self
    }

    /// Efficiency for an op in a graph of the given activation width
    /// (1 = int8-quantized, 4 = float32).
    pub fn efficiency_for(&self, kind: OpKind, dtype_bytes: u64) -> Option<f64> {
        let base = self.eff.get(&kind).copied()?;
        Some(if dtype_bytes > 1 { base * self.fp32_factor } else { base })
    }

    pub fn supports(&self, kind: OpKind) -> bool {
        self.eff.contains_key(&kind)
    }

    pub fn efficiency(&self, kind: OpKind) -> Option<f64> {
        self.eff.get(&kind).copied()
    }

    /// Number of supported op kinds (for the Fig 2 support census).
    pub fn num_supported(&self) -> usize {
        self.eff.len() - 1 // exclude the Input pseudo-op
    }

    /// Remove support for the listed kinds (builder-style restriction).
    pub fn without(mut self, kinds: &[OpKind]) -> Self {
        for k in kinds {
            self.eff.remove(k);
        }
        self
    }

    /// Override an efficiency (builder-style).
    pub fn with(mut self, kind: OpKind, e: f64) -> Self {
        assert!(e > 0.0 && e <= 1.0);
        self.eff.insert(kind, e);
        self
    }

    /// Deterministic (BTreeMap-ordered) iteration over the support
    /// entries — the input [`crate::soc::SocSpec::fingerprint`] folds
    /// into its structural hash.
    pub fn entries(&self) -> impl Iterator<Item = (OpKind, f64)> + '_ {
        self.eff.iter().map(|(&k, &e)| (k, e))
    }
}

/// CPU: supports every op. `conv_eff` is low because TFLite's CPU kernels
/// reach a small fraction of NEON peak on convolutions; memory-bound ops
/// run at higher relative efficiency.
pub fn cpu_support(conv_eff: f64) -> SupportTable {
    let mut entries: Vec<(OpKind, f64)> = Vec::new();
    for k in OpKind::ALL {
        let e = match k {
            OpKind::Input => continue,
            OpKind::Conv2d | OpKind::FullyConnected => conv_eff,
            OpKind::DilatedConv2d | OpKind::TransposeConv2d => conv_eff * 0.8,
            OpKind::DepthwiseConv2d => conv_eff * 0.45,
            _ => 0.5,
        };
        entries.push((k, e));
    }
    SupportTable::new(&entries)
}

/// GPU: float-friendly op set. Modern delegates (Mali-G710, Adreno) cover
/// most ops; `modern = false` models older delegates (Mali-G72) that lack
/// dilated/transposed convolutions and bilinear resize — the fallback ops
/// the paper observed dominating Kirin 970 runs.
pub fn gpu_support(conv_eff: f64, modern: bool) -> SupportTable {
    let mut t = SupportTable::new(&[
        (OpKind::Conv2d, conv_eff),
        (OpKind::DepthwiseConv2d, conv_eff * 0.25),
        (OpKind::FullyConnected, conv_eff * 0.7),
        (OpKind::Add, 0.6),
        (OpKind::Sub, 0.6),
        (OpKind::Mul, 0.6),
        (OpKind::Div, 0.5),
        (OpKind::Relu, 0.7),
        (OpKind::Relu6, 0.7),
        (OpKind::Logistic, 0.5),
        (OpKind::Tanh, 0.5),
        (OpKind::HardSwish, 0.5),
        (OpKind::Softmax, 0.4),
        (OpKind::MaxPool2d, 0.6),
        (OpKind::AvgPool2d, 0.6),
        // No Mean: GPU delegates handle reductions poorly and reject the
        // axis combinations the zoo models use (global spatial mean).
        (OpKind::Concat, 0.5),
        (OpKind::Reshape, 0.5),
        (OpKind::Squeeze, 0.5),
        (OpKind::Pad, 0.5),
        (OpKind::BatchNorm, 0.5),
    ]);
    if modern {
        t = t
            .with(OpKind::DilatedConv2d, conv_eff * 0.7)
            .with(OpKind::TransposeConv2d, conv_eff * 0.6)
            .with(OpKind::ResizeBilinear, 0.5)
            .with(OpKind::StridedSlice, 0.4)
            .with(OpKind::Split, 0.4);
    }
    t
}

/// DSP (Hexagon / MediaTek APU): integer-oriented vector engine. Strong on
/// quantized conv/elementwise, no support for the geometry/float-special
/// ops (resize, softmax over large axes, dilated convs...).
pub fn dsp_support(conv_eff: f64) -> SupportTable {
    SupportTable::new(&[
        (OpKind::Conv2d, conv_eff),
        (OpKind::DepthwiseConv2d, conv_eff * 0.6),
        (OpKind::FullyConnected, conv_eff * 0.8),
        (OpKind::Add, 0.7),
        (OpKind::Mul, 0.7),
        (OpKind::Relu, 0.8),
        (OpKind::Relu6, 0.8),
        (OpKind::Logistic, 0.4),
        (OpKind::MaxPool2d, 0.7),
        (OpKind::AvgPool2d, 0.7),
        (OpKind::Concat, 0.5),
        (OpKind::Reshape, 0.5),
        (OpKind::BatchNorm, 0.5), // per-channel scale+shift vectorizes well
        (OpKind::Quantize, 0.8),
        (OpKind::Dequantize, 0.8),
    ])
    .with_fp32_factor(0.55)
}

/// NPU: fixed-function tensor cores. Excellent on convolution-shaped work,
/// nothing else. `mature = false` models first-generation NPUs (Kirin 970)
/// with an even narrower op set (no concat / mean / pooling fusion).
pub fn npu_support(conv_eff: f64, mature: bool) -> SupportTable {
    let mut t = SupportTable::new(&[
        (OpKind::Conv2d, conv_eff),
        (OpKind::DepthwiseConv2d, conv_eff * 0.5),
        (OpKind::FullyConnected, conv_eff * 0.9),
        (OpKind::Add, 0.8),
        (OpKind::Relu, 0.9),
        (OpKind::Relu6, 0.9),
        (OpKind::MaxPool2d, 0.7),
        (OpKind::AvgPool2d, 0.7),
    ]);
    if mature {
        t = t
            .with(OpKind::Mul, 0.7)
            .with(OpKind::Logistic, 0.5)
            .with(OpKind::Mean, 0.6)
            .with(OpKind::Concat, 0.6)
            .with(OpKind::Reshape, 0.5)
            .with(OpKind::BatchNorm, 0.6);
    }
    // NPUs are int8-first: fp32 graphs run via the relaxed-fp16 path.
    t.with_fp32_factor(0.30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_supports_everything() {
        let t = cpu_support(0.3);
        for k in OpKind::ALL {
            assert!(t.supports(k), "{k:?} unsupported on CPU");
        }
        assert_eq!(t.num_supported(), OpKind::ALL.len() - 1);
    }

    #[test]
    fn npu_narrower_than_dsp_narrower_than_gpu() {
        let gpu = gpu_support(0.3, true);
        let dsp = dsp_support(0.4);
        let npu = npu_support(0.5, false);
        assert!(gpu.num_supported() > dsp.num_supported());
        assert!(dsp.num_supported() > npu.num_supported());
    }

    #[test]
    fn old_gpu_lacks_dilated_and_resize() {
        let old = gpu_support(0.3, false);
        assert!(!old.supports(OpKind::DilatedConv2d));
        assert!(!old.supports(OpKind::ResizeBilinear));
        let new = gpu_support(0.3, true);
        assert!(new.supports(OpKind::DilatedConv2d));
        assert!(new.supports(OpKind::ResizeBilinear));
    }

    #[test]
    fn without_removes_support() {
        let t = cpu_support(0.3).without(&[OpKind::Softmax]);
        assert!(!t.supports(OpKind::Softmax));
    }

    #[test]
    #[should_panic]
    fn zero_efficiency_rejected() {
        SupportTable::new(&[(OpKind::Add, 0.0)]);
    }
}
