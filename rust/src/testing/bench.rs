//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//! ```no_run
//! use adms::testing::bench::Bench;
//! let mut b = Bench::new("analyzer");
//! b.bench("partition/mobilenet_v1", || {
//!     /* work under measurement */
//! });
//! b.finish();
//! ```
//!
//! Reports min / median / mean / p95 over timed iterations after a
//! warm-up phase, criterion-style, and records results for the
//! EXPERIMENTS.md §Perf log.

use std::time::Instant;

pub struct Bench {
    group: String,
    /// Target per-measurement time budget.
    budget_ms: f64,
    results: Vec<(String, Stats)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // Honor a time budget override for CI smoke runs.
        let budget_ms = std::env::var("ADMS_BENCH_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(300.0);
        println!("\n== bench group: {group} ==");
        Bench { group: group.to_string(), budget_ms, results: Vec::new() }
    }

    /// Time a closure: warm up, then measure batches until the budget is
    /// spent (at least 10 samples).
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> Stats {
        // Warm-up and batch sizing: aim for ≥ 100 µs per sample.
        let t0 = Instant::now();
        f();
        let single = t0.elapsed().as_secs_f64() * 1e9;
        let batch = (1e5 / single.max(1.0)).ceil().max(1.0) as u64;
        let mut samples: Vec<f64> = Vec::new();
        let deadline = Instant::now();
        let mut iters = 0u64;
        while (deadline.elapsed().as_secs_f64() * 1e3) < self.budget_ms || samples.len() < 10 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() * 1e9 / batch as f64);
            iters += batch;
            if samples.len() >= 2_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let stats = Stats {
            iters,
            min_ns: samples[0],
            median_ns: samples[n / 2],
            mean_ns: samples.iter().sum::<f64>() / n as f64,
            p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        };
        println!(
            "{:<44} {:>12} median  {:>12} mean  {:>12} p95  ({} iters)",
            format!("{}/{}", self.group, name),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            stats.iters
        );
        self.results.push((name.to_string(), stats));
        stats
    }

    pub fn finish(self) {
        println!("== {} done ({} benches) ==", self.group, self.results.len());
    }
}

