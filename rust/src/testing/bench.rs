//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//! ```no_run
//! use adms::testing::bench::Bench;
//! let mut b = Bench::new("analyzer");
//! b.bench("partition/mobilenet_v1", || {
//!     /* work under measurement */
//! });
//! b.finish();
//! ```
//!
//! Reports min / median / mean / p95 over timed iterations after a
//! warm-up phase, criterion-style, and records results for the
//! EXPERIMENTS.md §Perf log.

use std::time::Instant;

pub struct Bench {
    group: String,
    /// Target per-measurement time budget.
    budget_ms: f64,
    results: Vec<(String, Stats)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // Honor a time budget override for CI smoke runs (ADMS_BENCH_MS).
        let budget_ms = crate::util::env::bench_budget_ms(300.0);
        println!("\n== bench group: {group} ==");
        Bench { group: group.to_string(), budget_ms, results: Vec::new() }
    }

    /// The per-measurement time budget this harness runs under
    /// (`ADMS_BENCH_MS` or the 300 ms default).
    pub fn budget_ms(&self) -> f64 {
        self.budget_ms
    }

    /// Time a closure: warm up, then measure batches until the budget is
    /// spent (at least 10 samples).
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> Stats {
        // Warm-up and batch sizing: aim for ≥ 100 µs per sample.
        let t0 = Instant::now();
        f();
        let single = t0.elapsed().as_secs_f64() * 1e9;
        let batch = (1e5 / single.max(1.0)).ceil().max(1.0) as u64;
        let mut samples: Vec<f64> = Vec::new();
        let deadline = Instant::now();
        let mut iters = 0u64;
        while (deadline.elapsed().as_secs_f64() * 1e3) < self.budget_ms || samples.len() < 10 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() * 1e9 / batch as f64);
            iters += batch;
            if samples.len() >= 2_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let stats = Stats {
            iters,
            min_ns: samples[0],
            median_ns: samples[n / 2],
            mean_ns: samples.iter().sum::<f64>() / n as f64,
            p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        };
        println!(
            "{:<44} {:>12} median  {:>12} mean  {:>12} p95  ({} iters)",
            format!("{}/{}", self.group, name),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            stats.iters
        );
        self.results.push((name.to_string(), stats));
        stats
    }

    pub fn finish(self) {
        println!("== {} done ({} benches) ==", self.group, self.results.len());
    }
}

/// One measured entry of the simulator throughput suite.
#[derive(Debug, Clone)]
pub struct SimSuiteEntry {
    pub name: String,
    pub stats: Stats,
    /// Simulated horizon covered by one measured run, ms.
    pub sim_ms: f64,
    /// Backend events the driver processed in one run.
    pub events: u64,
    /// Requests completed in one run (the batching rows' acceptance
    /// evidence: batched `copies` must complete ≥ 1.5× the unbatched row
    /// at equal horizon).
    pub completed: u64,
    /// Simulated devices per run — nonzero only for fleet rows, whose
    /// headline figure is [`devices_per_sec`](SimSuiteEntry::devices_per_sec).
    pub devices: u64,
}

impl SimSuiteEntry {
    /// Simulated milliseconds advanced per wall-clock second — the
    /// headline throughput figure the perf gate tracks (EXPERIMENTS.md
    /// §Perf; the ISSUE-3 acceptance bar is ≥3× the pre-refactor value).
    pub fn sim_ms_per_wall_s(&self) -> f64 {
        self.sim_ms * 1e9 / self.stats.median_ns
    }

    /// Driver events processed per wall-clock second.
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 * 1e9 / self.stats.median_ns
    }

    /// Devices simulated per wall-clock second (fleet rows only — the
    /// fleet-scale throughput figure EXPERIMENTS.md §Population tracks).
    pub fn devices_per_sec(&self) -> f64 {
        self.devices as f64 * 1e9 / self.stats.median_ns
    }
}

/// The `bench_sim` measurement suite, shared by the `cargo bench` target
/// and the `adms bench` subcommand: full simulated seconds per wall
/// second across the three framework arms on the FRS workload, plus
/// stress-mix scaling (the Table 7 path). Returns the measured entries;
/// progress prints criterion-style as it runs.
pub fn run_sim_suite() -> (f64, Vec<SimSuiteEntry>) {
    use crate::experiments::common::{run_framework, Framework};
    use crate::exec::SimConfig;
    use crate::soc::dimensity9000;
    use crate::workload::{frs, stress_mix};

    use std::cell::Cell;

    let soc = dimensity9000();
    let mut b = Bench::new("sim");
    let budget = b.budget_ms();
    let mut entries = Vec::new();
    for fw in Framework::ALL {
        let cfg = SimConfig { duration_ms: 2_000.0, ..Default::default() };
        let name = format!("frs_2s/{}", fw.label());
        // The event census rides along inside the timed closure (it is
        // identical every run — the sim is seed-deterministic), so no
        // extra untimed run is needed.
        let events = Cell::new(0u64);
        let completed = Cell::new(0u64);
        let stats = b.bench(&name, || {
            let r = run_framework(&soc, fw, frs(), cfg.clone());
            events.set(r.events);
            completed.set(r.total_completed());
            std::hint::black_box(&r);
        });
        entries.push(SimSuiteEntry {
            name,
            stats,
            sim_ms: 2_000.0,
            events: events.get(),
            completed: completed.get(),
            devices: 0,
        });
    }
    // Scaling with concurrency (the Table 7 stress path).
    for n in [4usize, 8] {
        let cfg = SimConfig { duration_ms: 1_000.0, ..Default::default() };
        let name = format!("stress_1s/{n}_models");
        let events = Cell::new(0u64);
        let completed = Cell::new(0u64);
        let stats = b.bench(&name, || {
            let r = run_framework(&soc, Framework::Adms, stress_mix(n), cfg.clone());
            events.set(r.events);
            completed.set(r.total_completed());
            std::hint::black_box(&r);
        });
        entries.push(SimSuiteEntry {
            name,
            stats,
            sim_ms: 1_000.0,
            events: events.get(),
            completed: completed.get(),
            devices: 0,
        });
    }
    // Batching throughput (ISSUE 5): 8 closed-loop copies of one model,
    // unbatched vs group-dispatched, same horizon and seed. The
    // `completed` column is the acceptance evidence that group dispatch
    // raises popular-app throughput (batched must complete ≥ 1.5× the
    // unbatched row — pinned deterministically by `exec_backends::
    // batched_copies_throughput_wins_on_contention_bound_soc`). Measured
    // on the Kirin 970, whose accelerators collapse under concurrency
    // (Table 2: NPU 6× at 4 models) — exactly the regime group dispatch
    // targets; on slot-rich low-contention SoCs it is roughly
    // throughput-neutral (see `soc::cost::batch_marginal_frac`).
    {
        use crate::exec::Server;
        use crate::soc::kirin970;
        use crate::workload::concurrent_copies;
        let kirin = kirin970();
        for (suffix, batch_max, window) in [("", 1usize, 0.0), (" batched", 8, 10.0)] {
            let cfg = SimConfig {
                duration_ms: 1_000.0,
                batch_max,
                batch_window_ms: window,
                ..Default::default()
            };
            let name = format!("copies_1s/8{suffix}");
            let events = Cell::new(0u64);
            let completed = Cell::new(0u64);
            let stats = b.bench(&name, || {
                let r = Server::new(kirin.clone())
                    .scheduler_name("adms")
                    .apps(concurrent_copies("mobilenet_v1", 8))
                    .config(cfg.clone())
                    .run_sim()
                    .expect("copies bench run");
                events.set(r.events);
                completed.set(r.total_completed());
                std::hint::black_box(&r);
            });
            entries.push(SimSuiteEntry {
                name,
                stats,
                sim_ms: 1_000.0,
                events: events.get(),
                completed: completed.get(),
                devices: 0,
            });
        }
    }
    // Weight-residency churn (ISSUE 6): the model_churn scenario under a
    // constrained per-processor budget, so every measured run exercises
    // manifest lookup, cold-load pricing, pin/unpin, and eviction on the
    // hot path. Gated by `adms bench --check` like every other row — the
    // residency layer is not allowed to quietly tax the simulator.
    {
        use crate::exec::Server;
        use crate::scenario::model_churn;
        let (apps, events_list) = model_churn().compile().expect("model_churn compiles");
        let cfg = SimConfig {
            duration_ms: 1_000.0,
            mem_budget_bytes: 64 << 20,
            ..Default::default()
        };
        let name = "churn_1s/mem".to_string();
        let events = Cell::new(0u64);
        let completed = Cell::new(0u64);
        let stats = b.bench(&name, || {
            let r = Server::new(soc.clone())
                .scheduler_name("adms")
                .apps(apps.clone())
                .events(events_list.clone())
                .config(cfg.clone())
                .run_sim()
                .expect("churn mem bench run");
            events.set(r.events);
            completed.set(r.total_completed());
            std::hint::black_box(&r);
        });
        entries.push(SimSuiteEntry {
            name,
            stats,
            sim_ms: 1_000.0,
            events: events.get(),
            completed: completed.get(),
            devices: 0,
        });
    }
    // Lookahead rollout cost (ISSUE 7): the same churn scenario under
    // `lookahead` over the adms base, rollouts live. Rollouts are charged
    // ZERO in-model decision overhead (see `sched::lookahead`), so this
    // row is where their real cost shows up: the wall-clock price of
    // forking the sim and rolling candidate placements at every decision,
    // directly comparable to the base-policy `churn_1s/mem` row above.
    {
        use crate::exec::Server;
        use crate::scenario::model_churn;
        let (apps, events_list) = model_churn().compile().expect("model_churn compiles");
        let cfg = SimConfig {
            duration_ms: 1_000.0,
            lookahead_horizon: 2,
            lookahead_beam: 3,
            ..Default::default()
        };
        let name = "churn_1s/lookahead".to_string();
        let events = Cell::new(0u64);
        let completed = Cell::new(0u64);
        let stats = b.bench(&name, || {
            let r = Server::new(soc.clone())
                .scheduler_name("lookahead")
                .apps(apps.clone())
                .events(events_list.clone())
                .config(cfg.clone())
                .run_sim()
                .expect("churn lookahead bench run");
            events.set(r.events);
            completed.set(r.total_completed());
            std::hint::black_box(&r);
        });
        entries.push(SimSuiteEntry {
            name,
            stats,
            sim_ms: 1_000.0,
            events: events.get(),
            completed: completed.get(),
            devices: 0,
        });
    }
    // Fault-layer churn (ISSUE 8): the same churn scenario under a heavy
    // seeded fault profile with the dispatch-timeout sweep and retries
    // live, so every measured run exercises fault-plan playback, health
    // overlay, abort/re-enqueue, and backoff timers on the hot path —
    // directly comparable to the fault-free `churn_1s/mem` row above.
    {
        use crate::exec::Server;
        use crate::faults::FaultProfile;
        use crate::scenario::model_churn;
        let (apps, events_list) = model_churn().compile().expect("model_churn compiles");
        let cfg = SimConfig {
            duration_ms: 1_000.0,
            dispatch_timeout_mult: 4.0,
            fault_profile: Some(FaultProfile::heavy()),
            fault_seed: Some(7),
            ..Default::default()
        };
        let name = "churn_1s/faults".to_string();
        let events = Cell::new(0u64);
        let completed = Cell::new(0u64);
        let stats = b.bench(&name, || {
            let r = Server::new(soc.clone())
                .scheduler_name("adms")
                .apps(apps.clone())
                .events(events_list.clone())
                .config(cfg.clone())
                .run_sim()
                .expect("churn faults bench run");
            events.set(r.events);
            completed.set(r.total_completed());
            std::hint::black_box(&r);
        });
        entries.push(SimSuiteEntry {
            name,
            stats,
            sim_ms: 1_000.0,
            events: events.get(),
            completed: completed.get(),
            devices: 0,
        });
    }
    // Adaptive re-partitioning (ISSUE 9): the phase_shift scenario with
    // the reactive granularity controller live, so every measured run
    // exercises pressure sampling, the EMA, safe-boundary checks, and
    // (when pressure crosses the threshold) the plan swap itself on the
    // hot path — directly comparable to a static run of the same scenario.
    {
        use crate::exec::{AdaptivePlan, Server};
        use crate::scenario::phase_shift;
        let (apps, events_list) = phase_shift().compile().expect("phase_shift compiles");
        let cfg = SimConfig {
            duration_ms: 1_000.0,
            adaptive_plan: AdaptivePlan::Reactive,
            replan_cooldown_ms: 200.0,
            ..Default::default()
        };
        let name = "phase_1s/adaptive".to_string();
        let events = Cell::new(0u64);
        let completed = Cell::new(0u64);
        let stats = b.bench(&name, || {
            let r = Server::new(soc.clone())
                .scheduler_name("adms")
                .apps(apps.clone())
                .events(events_list.clone())
                .config(cfg.clone())
                .run_sim()
                .expect("phase adaptive bench run");
            events.set(r.events);
            completed.set(r.total_completed());
            std::hint::black_box(&r);
        });
        entries.push(SimSuiteEntry {
            name,
            stats,
            sim_ms: 1_000.0,
            events: events.get(),
            completed: completed.get(),
            devices: 0,
        });
    }
    // Fleet throughput: a sharded device population per measured run
    // (`sim_ms` is summed over devices, so the headline figure stays
    // simulated-ms per wall-second — now aggregated across shards).
    {
        use crate::fleet::{run_fleet, ArmSpec, FleetSpec};
        let (devices, workers) = (6usize, 2usize);
        let spec = FleetSpec {
            arms: vec![ArmSpec::new("dimensity9000", "adms", "frs")],
            devices,
            seed: 42,
            cfg: SimConfig { duration_ms: 500.0, ..Default::default() },
            population: None,
            envelope: None,
        };
        let name = format!("fleet_0.5s/{devices}dev_{workers}w");
        let events = Cell::new(0u64);
        let completed = Cell::new(0u64);
        let stats = b.bench(&name, || {
            let r = run_fleet(&spec, workers).expect("fleet bench run");
            events.set(r.total.events);
            completed.set(r.total.completed);
            std::hint::black_box(&r);
        });
        entries.push(SimSuiteEntry {
            name,
            stats,
            sim_ms: devices as f64 * 500.0,
            events: events.get(),
            completed: completed.get(),
            devices: devices as u64,
        });
    }
    // Fleet at scale: one timed 10k-device streaming run. Single-shot —
    // this is a macro row whose headline is devices per wall-second (the
    // figure the CI fleet smoke tracks), and batching a multi-second run
    // under the micro budget would only repeat the same deterministic
    // work. Per-device work is cut to one request over a short horizon so
    // the row measures fleet machinery (claiming, streaming fold), not
    // raw sim depth.
    {
        use crate::fleet::{run_fleet, ArmSpec, FleetSpec};
        let (devices, workers) = (10_000usize, 2usize);
        let spec = FleetSpec {
            arms: vec![ArmSpec::new("dimensity9000", "adms", "frs")],
            devices,
            seed: 42,
            cfg: SimConfig {
                duration_ms: 100.0,
                max_requests: Some(1),
                ..Default::default()
            },
            population: None,
            envelope: None,
        };
        let name = format!("fleet_10k/{workers}w");
        let t = Instant::now();
        let r = run_fleet(&spec, workers).expect("fleet 10k bench run");
        let ns = t.elapsed().as_secs_f64() * 1e9;
        let stats = Stats { iters: 1, min_ns: ns, median_ns: ns, mean_ns: ns, p95_ns: ns };
        println!(
            "{:<44} {:>12} single-shot  ({} devices)",
            format!("sim/{name}"),
            fmt_ns(ns),
            devices
        );
        entries.push(SimSuiteEntry {
            name,
            stats,
            sim_ms: devices as f64 * 100.0,
            events: r.total.events,
            completed: r.total.completed,
            devices: devices as u64,
        });
    }
    b.finish();
    (budget, entries)
}

/// Render the suite's headline figures (one line per entry) — shared by
/// the `cargo bench` target and `adms bench` so their reports can't
/// drift apart.
pub fn print_sim_suite(entries: &[SimSuiteEntry]) {
    for e in entries {
        let devs = if e.devices > 0 {
            format!("   {:.0} dev/s", e.devices_per_sec())
        } else {
            String::new()
        };
        println!(
            "{:<28} {:>12.0} sim-ms/wall-s   {:>12.0} events/s   {:>8} completed{devs}",
            e.name,
            e.sim_ms_per_wall_s(),
            e.events_per_sec(),
            e.completed
        );
    }
}

/// Serialize a sim-suite run for `BENCH_sim.json` (the tracked perf
/// trajectory — CI uploads it as an artifact).
pub fn sim_suite_json(budget_ms: f64, entries: &[SimSuiteEntry]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let rows = entries
        .iter()
        .map(|e| {
            let mut pairs = vec![
                ("name", Json::Str(e.name.clone())),
                ("iters", Json::Num(e.stats.iters as f64)),
                ("median_ns", Json::Num(e.stats.median_ns)),
                ("mean_ns", Json::Num(e.stats.mean_ns)),
                ("p95_ns", Json::Num(e.stats.p95_ns)),
                ("sim_ms", Json::Num(e.sim_ms)),
                ("sim_ms_per_wall_s", Json::Num(e.sim_ms_per_wall_s())),
                ("events", Json::Num(e.events as f64)),
                ("events_per_sec", Json::Num(e.events_per_sec())),
                ("completed", Json::Num(e.completed as f64)),
            ];
            // Only fleet rows count devices; other rows keep their bytes.
            if e.devices > 0 {
                pairs.push(("devices", Json::Num(e.devices as f64)));
                pairs.push(("devices_per_sec", Json::Num(e.devices_per_sec())));
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![
        ("group", Json::Str("sim".into())),
        ("budget_ms", Json::Num(budget_ms)),
        ("entries", Json::Arr(rows)),
    ])
}

