//! Minimal property-based testing framework (proptest is unavailable
//! offline). Provides seedable generators and a check-runner with bounded
//! shrinking for the coordinator / analyzer / scheduler invariant tests.

pub mod prop;
pub mod bench;

pub use prop::{check, Gen};
