//! Property-based check runner.
//!
//! Usage (`no_run`: doctest executables don't inherit the rpath to
//! libxla_extension's bundled libstdc++ in this offline environment):
//! ```no_run
//! use adms::testing::prop::{check, Gen};
//! check("reverse twice is identity", 200, |g| {
//!     let v = g.vec(0..=32, |g| g.u64(0..100));
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```
//!
//! On failure the runner reports the failing case number and the seed that
//! reproduces it (re-run with `ADMS_PROP_SEED=<seed>` to replay), then
//! retries the property at a handful of "smaller" derived seeds to give a
//! roughly-shrunk reproduction. Full structural shrinking is out of scope;
//! deterministic replay covers the debugging need.

use crate::util::rng::Pcg32;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Value generator handed to properties. Wraps a deterministic PRNG with
/// convenience constructors; the *size* parameter grows over the run so
/// early cases are small.
pub struct Gen {
    rng: Pcg32,
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Pcg32::seeded(seed), size }
    }

    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        assert!(range.start < range.end);
        range.start + self.rng.below(range.end - range.start)
    }

    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Probability-`p` true.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    pub fn pick<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        self.rng.choose(options)
    }

    /// A vector whose length is drawn from `len`, scaled down for small
    /// `size` so early cases are simple.
    pub fn vec<T>(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let lo = *len.start();
        let hi = (*len.end()).min(lo + self.size.max(1));
        let n = self.usize(lo..hi + 1);
        (0..n).map(|_| f(self)).collect()
    }

    /// Raw RNG access for distributions not covered above.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Iteration count for a property suite: the `PROP_ITERS` environment
/// variable when set (CI's nightly fuzz job raises it far beyond the
/// in-PR default), else `default`. See [`crate::util::env`].
pub fn iters(default: u64) -> u64 {
    crate::util::env::prop_iters(default)
}

/// Run `prop` against `cases` generated inputs. Panics (failing the test)
/// on the first violated property with a replayable seed.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base_seed = crate::util::env::prop_seed();
    if let Some(seed) = base_seed {
        // Replay mode: a single case at the exact seed.
        let mut g = Gen::new(seed, 64);
        prop(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = 0x9e3779b9u64
            .wrapping_mul(case + 1)
            .wrapping_add(name.len() as u64);
        // Size ramps from 1 to 64 over the first half of the run.
        let size = 1 + (case as usize * 63 / (cases.max(2) as usize / 2)).min(63);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            // Crude shrink: try the same seed at smaller sizes and report
            // the smallest size that still fails.
            let mut min_fail_size = size;
            for s in 1..size {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    let mut g = Gen::new(seed, s);
                    prop(&mut g);
                }));
                if r.is_err() {
                    min_fail_size = s;
                    break;
                }
            }
            panic!(
                "property '{name}' failed at case {case} (seed={seed}, size={size}, \
                 min failing size={min_fail_size}).\nReplay: ADMS_PROP_SEED={seed}\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("sum is commutative", 50, |g| {
            let a = g.f64(-1e6, 1e6);
            let b = g.f64(-1e6, 1e6);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = catch_unwind(|| {
            check("always fails on long vecs", 50, |g| {
                let v = g.vec(0..=16, |g| g.u64(0..10));
                assert!(v.len() < 3, "vector too long: {}", v.len());
            });
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("ADMS_PROP_SEED="), "message: {msg}");
    }

    #[test]
    fn iters_is_positive_with_or_without_env() {
        // Cannot assert the exact value: the nightly fuzz job sets
        // PROP_ITERS for the whole test process.
        assert!(iters(7) >= 1);
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1, 16);
        for _ in 0..1000 {
            let x = g.u64(5..9);
            assert!((5..9).contains(&x));
            let y = g.usize(0..3);
            assert!(y < 3);
        }
    }

    #[test]
    fn vec_respects_bounds() {
        let mut g = Gen::new(2, 4);
        for _ in 0..100 {
            let v = g.vec(2..=64, |g| g.bool());
            assert!(v.len() >= 2 && v.len() <= 7); // lo + size.max(1) + 1
        }
    }
}
