//! Lumped-RC thermal model with a throttling governor (paper Fig 12,
//! Table 7).
//!
//! Each processor is a single thermal node: `C·dT/dt = P − (T − T_amb)/R`.
//! A governor ticks periodically: above the throttle threshold it steps
//! the DVFS ladder down; with hysteresis headroom it steps back up; above
//! the critical temperature the processor is taken offline until it cools
//! (the paper observed the Redmi GPU "completely shutting down at several
//! points" under TFLite).

use crate::soc::ProcessorSpec;
use crate::TimeMs;

/// Dynamic thermal/DVFS state for one processor.
#[derive(Debug, Clone)]
pub struct ThermalState {
    /// Junction temperature, °C.
    pub temp_c: f64,
    /// Current DVFS ladder index (0 = fastest).
    pub level: usize,
    /// Offline due to critical temperature (cooling down).
    pub offline: bool,
    /// Count of governor-initiated frequency reductions (Table 7 metric).
    pub throttle_events: u64,
    /// Sim time when throttling first began, if ever.
    pub first_throttle_ms: Option<TimeMs>,
}

impl ThermalState {
    pub fn new(ambient_c: f64) -> Self {
        ThermalState {
            temp_c: ambient_c,
            level: 0,
            offline: false,
            throttle_events: 0,
            first_throttle_ms: None,
        }
    }

    /// Integrate the RC node over `dt_ms` given average power `p_watts`.
    pub fn integrate(&mut self, spec: &ProcessorSpec, ambient_c: f64, p_watts: f64, dt_ms: f64) {
        let dt_s = dt_ms / 1e3;
        // Exact solution of the linear ODE over the step (unconditionally
        // stable for any dt): T → T_ss + (T − T_ss)·exp(−dt/RC).
        let t_ss = ambient_c + p_watts * spec.thermal_r;
        let tau = spec.thermal_r * spec.thermal_c;
        self.temp_c = t_ss + (self.temp_c - t_ss) * (-dt_s / tau).exp();
    }

    /// Governor step with 5 °C hysteresis. Returns true if the DVFS level
    /// or the online state changed.
    pub fn govern(&mut self, spec: &ProcessorSpec, now_ms: TimeMs) -> bool {
        let mut changed = false;
        if self.offline {
            // Come back online once well below throttle temperature.
            if self.temp_c < spec.throttle_temp_c - 8.0 {
                self.offline = false;
                self.level = spec.freqs_mhz.len() - 1;
                changed = true;
            }
            return changed;
        }
        if self.temp_c >= spec.critical_temp_c {
            self.offline = true;
            self.throttle_events += 1;
            self.first_throttle_ms.get_or_insert(now_ms);
            return true;
        }
        if self.temp_c >= spec.throttle_temp_c {
            if self.level + 1 < spec.freqs_mhz.len() {
                self.level += 1;
                changed = true;
            }
            self.throttle_events += 1;
            self.first_throttle_ms.get_or_insert(now_ms);
        } else if self.temp_c < spec.throttle_temp_c - 5.0 && self.level > 0 {
            self.level -= 1;
            changed = true;
        }
        changed
    }

    /// Current frequency in MHz.
    pub fn freq_mhz(&self, spec: &ProcessorSpec) -> f64 {
        if self.offline {
            0.0
        } else {
            spec.freqs_mhz[self.level.min(spec.freqs_mhz.len() - 1)]
        }
    }

    /// Frequency scale factor in `(0, 1]` for the cost model.
    pub fn freq_scale(&self, spec: &ProcessorSpec) -> f64 {
        spec.freq_scale(self.level)
    }

    /// Thermal headroom before throttling, °C (used by the ADMS scheduler
    /// to steer work away from hot processors).
    pub fn headroom_c(&self, spec: &ProcessorSpec) -> f64 {
        spec.throttle_temp_c - self.temp_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::dimensity9000;

    fn cpu_spec() -> ProcessorSpec {
        dimensity9000().processors[0].clone()
    }

    #[test]
    fn heats_toward_steady_state() {
        let spec = cpu_spec();
        let mut st = ThermalState::new(25.0);
        // Full power for a long time → T_ss = 25 + 4·15 = 85 °C.
        for _ in 0..10_000 {
            st.integrate(&spec, 25.0, spec.tdp_w, 100.0);
        }
        assert!((st.temp_c - 85.0).abs() < 0.5, "T={}", st.temp_c);
    }

    #[test]
    fn cools_back_to_ambient() {
        let spec = cpu_spec();
        let mut st = ThermalState::new(25.0);
        st.temp_c = 80.0;
        for _ in 0..10_000 {
            st.integrate(&spec, 25.0, spec.idle_w, 100.0);
        }
        assert!(st.temp_c < 35.0, "T={}", st.temp_c);
    }

    #[test]
    fn integration_is_stable_for_large_steps() {
        let spec = cpu_spec();
        let mut st = ThermalState::new(25.0);
        st.integrate(&spec, 25.0, spec.tdp_w, 3_600_000.0); // one hour step
        assert!((st.temp_c - 85.0).abs() < 1e-6);
        assert!(st.temp_c.is_finite());
    }

    #[test]
    fn governor_throttles_and_recovers() {
        let spec = cpu_spec();
        let mut st = ThermalState::new(25.0);
        st.temp_c = 70.0;
        assert!(st.govern(&spec, 1000.0));
        assert_eq!(st.level, 1);
        assert_eq!(st.first_throttle_ms, Some(1000.0));
        st.temp_c = 71.0;
        st.govern(&spec, 2000.0);
        assert_eq!(st.level, 2);
        assert_eq!(st.throttle_events, 2);
        // Cooling below hysteresis band steps back up.
        st.temp_c = 60.0;
        assert!(st.govern(&spec, 3000.0));
        assert_eq!(st.level, 1);
        assert_eq!(st.first_throttle_ms, Some(1000.0)); // sticky
    }

    #[test]
    fn critical_temp_takes_processor_offline() {
        let spec = cpu_spec();
        let mut st = ThermalState::new(25.0);
        st.temp_c = spec.critical_temp_c + 1.0;
        assert!(st.govern(&spec, 0.0));
        assert!(st.offline);
        assert_eq!(st.freq_mhz(&spec), 0.0);
        // Recovers only after cooling well below the throttle threshold.
        st.temp_c = spec.throttle_temp_c - 2.0;
        assert!(!st.govern(&spec, 0.0));
        assert!(st.offline);
        st.temp_c = spec.throttle_temp_c - 10.0;
        assert!(st.govern(&spec, 0.0));
        assert!(!st.offline);
    }

    #[test]
    fn time_to_throttle_order_minutes_at_full_load() {
        // Sanity check against the paper's TFLite observation: sustained
        // full load throttles within minutes (~2.5 min on the CPU).
        let spec = cpu_spec();
        let mut st = ThermalState::new(25.0);
        let mut t_ms = 0.0;
        while st.temp_c < spec.throttle_temp_c && t_ms < 3.6e6 {
            st.integrate(&spec, 25.0, spec.tdp_w, 1000.0);
            t_ms += 1000.0;
        }
        let minutes = t_ms / 60_000.0;
        assert!(
            (1.0..6.0).contains(&minutes),
            "time to throttle {minutes:.1} min"
        );
    }
}
