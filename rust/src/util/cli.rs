//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// Declarative option description used for help text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected a number, got '{v}'")),
        }
    }
    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected an integer, got '{v}'")),
        }
    }
    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected an integer, got '{v}'")),
        }
    }
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Parse `argv` (without the program name) against the given option specs.
/// Unknown `--options` are an error; positionals are collected in order.
pub fn parse(argv: &[String], specs: &[OptSpec]) -> anyhow::Result<Args> {
    let mut args = Args::default();
    // Seed defaults.
    for s in specs {
        if let Some(d) = s.default {
            args.opts.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(rest) = a.strip_prefix("--") {
            let (key, inline_val) = match rest.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (rest.to_string(), None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == key)
                .ok_or_else(|| anyhow::anyhow!("unknown option --{key}"))?;
            if spec.takes_value {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| anyhow::anyhow!("--{key} requires a value"))?
                    }
                };
                args.opts.insert(key, val);
            } else {
                if inline_val.is_some() {
                    anyhow::bail!("--{key} does not take a value");
                }
                args.flags.push(key);
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render help text for a subcommand.
pub fn render_help(usage: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("usage: {usage}\n\noptions:\n");
    for s in specs {
        let val = if s.takes_value { " <value>" } else { "" };
        let dfl = s
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        out.push_str(&format!("  --{}{}\n      {}{}\n", s.name, val, s.help, dfl));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "soc", takes_value: true, help: "target SoC", default: Some("dimensity9000") },
            OptSpec { name: "seed", takes_value: true, help: "rng seed", default: None },
            OptSpec { name: "verbose", takes_value: false, help: "chatty", default: None },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kinds() {
        let a = parse(&sv(&["run", "--soc=kirin970", "--seed", "42", "--verbose", "x"]), &specs()).unwrap();
        assert_eq!(a.positional, vec!["run", "x"]);
        assert_eq!(a.get("soc"), Some("kirin970"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get("soc"), Some("dimensity9000"));
        assert_eq!(a.get("seed"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(parse(&sv(&["--nope"]), &specs()).is_err());
        assert!(parse(&sv(&["--seed"]), &specs()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &specs()).is_err());
        assert!(parse(&sv(&["--seed=abc"]), &specs())
            .unwrap()
            .get_u64("seed", 0)
            .is_err());
    }

    #[test]
    fn help_mentions_options() {
        let h = render_help("adms test", &specs());
        assert!(h.contains("--soc"));
        assert!(h.contains("default: dimensity9000"));
    }
}
