//! Central registry of environment variables the crate recognizes.
//!
//! Hot paths must not call `std::env::var*` per event (it takes a
//! process-global lock), and scattered ad-hoc reads made the recognized
//! set undiscoverable. Every variable is read through here; the full
//! table lives in README.md §Environment variables.
//!
//! | variable          | effect                                              |
//! |-------------------|-----------------------------------------------------|
//! | `ADMS_SIM_DEBUG`      | any value: periodic driver-loop progress to stderr  |
//! | `ADMS_BENCH_MS`       | per-measurement time budget for `testing::bench`    |
//! | `PROP_ITERS`          | overrides every property suite's iteration count    |
//! | `ADMS_PROP_SEED`      | replay a single property case at this exact seed    |
//! | `ADMS_FLEET_WORKERS`  | default worker-thread count for `adms fleet`        |

/// Any value enables periodic dispatch-loop progress lines on stderr.
pub const SIM_DEBUG: &str = "ADMS_SIM_DEBUG";
/// Per-measurement bench budget in milliseconds (CI smoke runs set 20).
pub const BENCH_MS: &str = "ADMS_BENCH_MS";
/// Property-suite iteration override (nightly fuzz sets 1000).
pub const PROP_ITERS: &str = "PROP_ITERS";
/// Single-seed property replay (printed by failing property runs).
pub const PROP_SEED: &str = "ADMS_PROP_SEED";
/// Default `adms fleet` worker count when `--workers` is 0/auto.
pub const FLEET_WORKERS: &str = "ADMS_FLEET_WORKERS";

/// `ADMS_SIM_DEBUG` — read once per run by the driver, never per event.
pub fn sim_debug() -> bool {
    std::env::var_os(SIM_DEBUG).is_some()
}

/// `ADMS_BENCH_MS`, else `default` (the bench harness's 300 ms).
pub fn bench_budget_ms(default: f64) -> f64 {
    std::env::var(BENCH_MS)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// `PROP_ITERS` when set and positive, else `default`.
pub fn prop_iters(default: u64) -> u64 {
    std::env::var(PROP_ITERS)
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// `ADMS_PROP_SEED` when set and parseable.
pub fn prop_seed() -> Option<u64> {
    std::env::var(PROP_SEED).ok().and_then(|s| s.parse::<u64>().ok())
}

/// `ADMS_FLEET_WORKERS` when set and positive. Worker count never
/// affects fleet *results* (the merge is device-ordered), only wall
/// time, so an env default is safe.
pub fn fleet_workers() -> Option<usize> {
    std::env::var(FLEET_WORKERS)
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Env mutation is process-global, so these only exercise the
    // default paths (CI may set the real variables for the whole
    // process).
    #[test]
    fn defaults_flow_through() {
        assert!(bench_budget_ms(300.0) > 0.0);
        assert!(prop_iters(7) >= 1);
    }
}
