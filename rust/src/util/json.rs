//! Minimal JSON parser and writer.
//!
//! Used for the artifact manifest written by `python/compile/aot.py`, for
//! tuned window-size configuration files, and for experiment result dumps.
//! Implements the full JSON grammar (RFC 8259) minus `\u` surrogate-pair
//! edge cases beyond the BMP; numbers are parsed as `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with two-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (may be multi-byte).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"m":[{"x":1.5},{"y":[true,false,null]}],"s":"q\"uote"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        let v = parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café");
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.25).to_string(), "1.25");
    }

    #[test]
    fn get_on_missing_key_is_null() {
        let v = parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(Json::Null.get("deep").get("deeper"), &Json::Null);
    }
}
